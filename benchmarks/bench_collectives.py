"""Collective-schedule races on the SPMD mesh path + the staged-overlap
coreset engine (``BENCH_collectives.json`` at the repo root is the CI
artifact; DESIGN.md Sec. 17 documents how to read it).

Two sections:

* **Mesh races** -- {all_gather, neighbor_rounds, torus_2d} x axis sizes
  {8, 16} x {kmeans, kmedian} on forced-host-device subprocess meshes
  (``benchmarks/run.py`` imports jax long before flags could be set, so
  each axis size gets its own subprocess, same idiom as the SPMD tests).
  Each row carries the analytic sequential hop depth per phase
  (``hops_round1``/``hops_round2`` via
  :func:`repro.core.message_passing.collective_hops`: one gather in
  Round 1, two in Round 2), the *measured* per-phase collective ledger
  from compiled HLO (``ppermutes_round1`` etc. via
  :func:`repro.roofline.hlo.collective_phase_analysis` -- the cross-check
  that the schedule compiled to exactly its claimed hop count), measured
  per-phase wall-clock (``wall_round1_us``/``wall_round2_us``: the phase's
  gather primitives timed at the phase's exact payload shapes), end-to-end
  wall, and a ``centers_bit_equal`` flag against the all_gather oracle.
  On a single-core CPU host the wall columns measure dispatch+copy, not
  ICI -- the hop columns are the hardware-relevant ranking; torus_2d's
  (R-1)+(C-1) must be strictly below the ring's N-1 for every N >= 16.

* **Staged overlap** -- the host engine raced lockstep
  (:func:`repro.core.coreset.distributed_coreset`) vs staged
  (:func:`repro.core.coreset.staged_distributed_coreset`) on a skewed
  partition: ``strict`` mode (bit-parity flag vs lockstep) and ``overlap``
  mode (per-site power-of-two bucketing + convergence early-exit, the
  wall-clock win; draws differ by construction, so quality is reported
  as the coreset-solve cost ratio instead of bit-equality).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import json_row
from repro.core import clustering
from repro.core.coreset import distributed_coreset, staged_distributed_coreset
from repro.core.distributed import _solve_on_coreset
from repro.core.partition import pad_partition, partition_indices

AXIS_SIZES = (8, 16)
MODES = ("all_gather", "neighbor_rounds", "torus_2d")
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_MESH_SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + str(%(n)d))
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core import spmd_distributed_kmeans
    from repro.core.distributed import spmd_distributed_kmeans_fn
    from repro.core.message_passing import (collective_hops,
                                            neighbor_rounds_gather,
                                            torus_mesh_shape,
                                            torus_rounds_gather)
    from repro.core.partition import partition_indices, pad_partition
    from repro.roofline.hlo import collective_phase_analysis

    N, scale, n_runs = %(n)d, %(scale)f, %(n_runs)d
    rng = np.random.default_rng(0)
    k, d = 4, 8
    per = max(int(400 * scale), 60)
    c0 = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate([c0[i] + 0.15 * rng.standard_normal((per, d))
                          for i in range(k)]).astype(np.float32)
    idx = partition_indices(pts, N, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    mesh = jax.make_mesh((N,), ("sites",))
    t = 256
    t_buffer = max(4 * t // N, 64)
    key = jax.random.PRNGKey(0)

    def phase_wall(shapes, mode, mesh_shape, reps):
        def g(x):
            if mode == "all_gather":
                return jax.lax.all_gather(x, "sites")
            if mode == "torus_2d":
                return torus_rounds_gather(x, "sites", mesh_shape)
            return neighbor_rounds_gather(x, "sites", N)
        def dev(*xs):
            return tuple(g(x[0])[None] for x in xs)
        args = [jnp.zeros((N,) + s, jnp.float32) for s in shapes]
        f = jax.jit(shard_map(dev, mesh=mesh,
                              in_specs=tuple(P("sites") for _ in args),
                              out_specs=tuple(P("sites") for _ in args)))
        jax.block_until_ready(f(*args))
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f(*args))
        return (time.perf_counter() - t0) / reps * 1e6

    out, oracle = [], {}
    for mode in ("all_gather", "neighbor_rounds", "torus_2d"):
        mesh_shape = torus_mesh_shape(N) if mode == "torus_2d" else None
        hops = collective_hops(mode, N, mesh_shape)
        # measured per-phase collective ledger from compiled HLO
        fn = spmd_distributed_kmeans_fn("sites", N, k, t, t_buffer,
                                        collectives=mode,
                                        mesh_shape=mesh_shape)
        def device_fn(key, p, m):
            return fn(key, p.reshape(-1, p.shape[-1]), m.reshape(-1))
        hlo = jax.jit(shard_map(
            device_fn, mesh=mesh,
            in_specs=(P(), P("sites"), P("sites")),
            out_specs=(P(), P("sites"), P("sites")),
        )).lower(key, sp, sm).compile().as_text()
        ph = collective_phase_analysis(hlo)
        def counts(phase):
            a = ph[phase]
            return (int(a.collective_counts.get("collective-permute", 0)),
                    int(sum(a.collective_counts.values())),
                    float(a.ici_collective_bytes
                          + a.dcn_collective_bytes))
        pp1, cc1, by1 = counts("round1")
        pp2, cc2, by2 = counts("round2")
        w1 = phase_wall([()], mode, mesh_shape, reps=max(4 * n_runs, 8))
        w2 = phase_wall([(t_buffer + k, d), (t_buffer + k,)], mode,
                        mesh_shape, reps=max(4 * n_runs, 8))
        for objective in ("kmeans", "kmedian"):
            def run():
                return spmd_distributed_kmeans(
                    mesh, "sites", key, sp, sm, k, t=t,
                    objective=objective, collectives=mode,
                    mesh_shape=mesh_shape)
            c, lc, ti = run()
            jax.block_until_ready(c)
            t0 = time.perf_counter()
            for _ in range(n_runs):
                jax.block_until_ready(run()[0])
            e2e = (time.perf_counter() - t0) / n_runs * 1e6
            if mode == "all_gather":
                oracle[objective] = np.asarray(c)
            out.append(dict(
                mode=mode, objective=objective, axis_size=N,
                mesh_shape=list(mesh_shape) if mesh_shape else None,
                hops_round1=hops, hops_round2=2 * hops,
                ppermutes_round1=pp1, ppermutes_round2=pp2,
                collectives_round1=cc1, collectives_round2=cc2,
                link_bytes_round1=by1, link_bytes_round2=by2,
                wall_round1_us=w1, wall_round2_us=w2, e2e_us=e2e,
                centers_bit_equal=bool(
                    (np.asarray(c) == oracle[objective]).all()),
            ))
    print("BENCH_JSON:" + json.dumps(out))
""")


def _mesh_rows(rows: List[str], axis_size: int, scale: float,
               n_runs: int) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO_ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env.pop("XLA_FLAGS", None)
    script = _MESH_SCRIPT % dict(n=axis_size, scale=scale, n_runs=n_runs)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=1800,
                         cwd=_REPO_ROOT)
    payload = [ln for ln in out.stdout.splitlines()
               if ln.startswith("BENCH_JSON:")]
    if not payload:
        raise RuntimeError(
            f"collectives mesh bench (N={axis_size}) produced no rows:\n"
            + out.stdout + out.stderr)
    for rec in json.loads(payload[0][len("BENCH_JSON:"):]):
        name = (f"collectives/{rec['mode']}/{rec['objective']}"
                f"/n{rec['axis_size']}")
        json_row(rows, name, rec.pop("e2e_us"), **rec)


def _staged_data(scale: float):
    """A deliberately skewed partition (weighted ~ |N(0,1)| site shares):
    the lockstep vmap pads every site to the largest site's slot count,
    which is exactly the FLOP waste the bucketed staged path recovers."""
    rng = np.random.default_rng(7)
    k, d = 4, 32
    per = max(int(40000 * scale), 6000)
    c0 = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate([c0[i] + 0.15 * rng.standard_normal((per, d))
                          for i in range(k)]).astype(np.float32)
    idx = partition_indices(pts, 8, "weighted", seed=3)
    sp, sm = pad_partition(pts, idx)
    return jnp.asarray(pts), jnp.asarray(sp), jnp.asarray(sm), k


def _staged_rows(rows: List[str], scale: float, n_runs: int) -> None:
    pts, sp, sm, k = _staged_data(scale)
    t, lloyd_iters = 256, 8
    key = jax.random.PRNGKey(0)
    kw = dict(k=k, t=t, lloyd_iters=lloyd_iters)

    def time_run(fn):
        res = fn()                  # warm-up (compiles every bucket)
        jax.block_until_ready(jax.tree_util.tree_leaves(res)[0])
        t0 = time.perf_counter()
        for _ in range(n_runs):
            res = fn()              # keep the last warm result: its
            jax.block_until_ready(  # StagedDetail walls are compile-free
                jax.tree_util.tree_leaves(res)[0])
        return res, (time.perf_counter() - t0) / n_runs * 1e6

    def quality(dc):
        centers = _solve_on_coreset(jax.random.fold_in(key, 1),
                                    dc.flatten(), k, "kmeans", 10)
        return float(clustering.cost(pts, centers))

    lock, lock_us = time_run(
        lambda: distributed_coreset(key, sp, sm, **kw))
    base_cost = quality(lock)

    variants = {
        "strict": dict(tol=0.0, site_buckets=False),
        "overlap": dict(tol=1e-3, site_buckets=True),
    }
    json_row(rows, "collectives/staged/lockstep", lock_us,
             variant="lockstep", n_sites=int(sp.shape[0]),
             site_slots=int(sp.shape[1]), t=t, lloyd_iters=lloyd_iters,
             cost_ratio=1.0, bit_equal_lockstep=True,
             speedup_vs_lockstep=1.0)
    for variant, knobs in variants.items():
        (dc, det), us = time_run(
            lambda kn=knobs: staged_distributed_coreset(key, sp, sm, **kw,
                                                        **kn))
        bit_eq = all(
            np.array_equal(np.asarray(getattr(dc, f)),
                           np.asarray(getattr(lock, f)))
            for f in ("points", "weights", "t_i", "local_costs"))
        json_row(
            rows, f"collectives/staged/{variant}", us,
            variant=variant, n_sites=int(sp.shape[0]),
            site_slots=int(sp.shape[1]),
            site_lengths=list(det.site_lengths),
            iters_run=[int(x) for x in np.asarray(det.iters_run)],
            t=t, lloyd_iters=lloyd_iters, **knobs,
            wall_round1_us=det.wall_round1_s * 1e6,
            wall_round2_us=det.wall_round2_s * 1e6,
            cost_ratio=quality(dc) / base_cost,
            bit_equal_lockstep=bit_eq,
            speedup_vs_lockstep=lock_us / us)


def run(scale: float = 1.0, n_runs: int = 2,
        out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    for axis_size in AXIS_SIZES:
        _mesh_rows(rows, axis_size, scale, n_runs)
    _staged_rows(rows, scale, max(n_runs, 3))
    return rows


if __name__ == "__main__":
    from benchmarks.common import write_json_rows
    out: List[str] = []
    run(scale=0.05, out_rows=out)
    write_json_rows(os.path.join(_REPO_ROOT, "BENCH_collectives.json"), out)
