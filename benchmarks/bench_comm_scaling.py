"""Theorem 2/3 communication-cost comparison (Sec. 4.2): total points
transmitted to reach a fixed summary quality (fixed coreset sample budget t)
as the network grows, for ours vs COMBINE vs Zhang et al.

Analytic from the exact ledgers (no clustering needed):
  ours (graph):    2m * n scalars  +  2m * (t + nk) points
  combine (graph): 2m * n * (t/n + k) points    [local coresets flooded]
  zhang (tree):    (n-1) * (s_h + k) points, s_h = t * h^2 (k-median scaling
                   of the eps/h accuracy split; h^4 for k-means -- we report
                   the quadratic variant, the favourable case for [26])
  ours (tree):     sum_v depth_v * (t_v + k) points

The grid family makes the diameter effect visible: h = Theta(sqrt(n)).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.comm import flood_cost, tree_up_cost
from repro.core.topology import bfs_spanning_tree, erdos_renyi, grid, preferential


def run(out_rows: List[str] | None = None, t: int = 1000, k: int = 10,
        d: int = 32) -> List[str]:
    rows = out_rows if out_rows is not None else []
    for topo, maker, ns in [
        ("random", lambda n: erdos_renyi(n, 0.3, seed=1), (16, 36, 64, 100)),
        ("grid", lambda n: grid(int(np.sqrt(n)), int(np.sqrt(n))),
         (16, 36, 64, 100)),
        ("preferential", lambda n: preferential(n, 2, seed=1),
         (16, 36, 64, 100)),
    ]:
        for n in ns:
            g = maker(n)
            tree = bfs_spanning_tree(g, root=0)
            h = max(tree.height, 1)
            ours_graph = flood_cost(g, n, unit_points=(t + n * k) / n,
                                    dim=d).points
            combine_graph = flood_cost(g, n, unit_points=t / n + k,
                                       dim=d).points
            ours_tree = tree_up_cost(tree, [(t / n) + k] * n, dim=d).points
            zhang_tree = (n - 1) * (t * h * h / n + k)
            rows.append(
                f"comm_scaling/{topo}/n={n}/h={h},0,"
                f"ours_graph={ours_graph:.0f};combine_graph={combine_graph:.0f};"
                f"ours_tree={ours_tree:.0f};zhang_tree={zhang_tree:.0f};"
                f"ratio_tree={zhang_tree/max(ours_tree,1):.2f}")
            print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
