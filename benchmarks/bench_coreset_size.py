"""Theorem 1: epsilon-coreset quality vs size t, distributed (Algorithm 1)
vs centralized [10] construction -- the distributed construction should track
the centralized one at equal t (the paper's core claim: topology-independent
coreset size), for both k-means and k-median.

Quality metric: max over random center sets of |coreset cost / true cost -1|.

Also includes a backend A/B of the *end-to-end* distributed construction
(jnp / jnp_chunked / pallas through the dispatch layer): same key, per-
backend wall time + quality + max weight deviation from the jnp reference,
one JSON row per backend.
"""
from __future__ import annotations

import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core.coreset import build_coreset, distributed_coreset
from repro.core.partition import pad_partition, partition_indices
from repro.data.synthetic import paper_dataset


def _max_rel_err(cs_pts, cs_w, pts, k, objective, n_probe=6, seed=0):
    errs = []
    for i in range(n_probe):
        key = jax.random.PRNGKey(seed + i)
        # probe with perturbed real solutions + random centers
        if i % 2 == 0:
            x = jax.random.normal(key, (k, pts.shape[1]))
        else:
            idx = jax.random.randint(key, (k,), 0, pts.shape[0])
            x = pts[idx] + 0.1 * jax.random.normal(key, (k, pts.shape[1]))
        t = float(clustering.cost(pts, x, objective=objective))
        c = float(clustering.cost(cs_pts, x, weights=cs_w,
                                  objective=objective))
        errs.append(abs(c / t - 1.0))
    return float(np.max(errs))


def run_backend_ab(sp, sm, pts, k, t=200, backends=None,
                   out_rows: List[str] | None = None) -> List[str]:
    """End-to-end Algorithm 1 through each dispatch backend: wall time,
    coreset quality, and weight deviation vs the jnp reference. The chunked
    entrant's chunk sits below the per-site point count so the lax.map path
    actually executes (the registry default of 65536 would fall through to
    dense code at these sizes)."""
    rows = out_rows if out_rows is not None else []
    if backends is None:
        backends = ("jnp",
                    backend_mod.register_backend(backend_mod.JnpChunkedBackend(
                        max(int(sp.shape[1]) // 4, 1),
                        name="jnp_chunked_bench")),
                    "pallas")
    key = jax.random.PRNGKey(0)
    ref_backend = backend_mod.resolve_name(backends[0])
    ref_w = None
    for backend in backends:
        name = backend_mod.resolve_name(backend)
        # warm up once (trace + compile), then time the cached executable
        dc = distributed_coreset(key, sp, sm, k, t, backend=backend)
        dc.weights.block_until_ready()
        t0 = time.time()
        dc = distributed_coreset(key, sp, sm, k, t, backend=backend)
        dc.weights.block_until_ready()
        wall_us = (time.time() - t0) * 1e6
        cs = dc.flatten()
        err = _max_rel_err(cs.points, cs.weights, pts, k, "kmeans")
        w = np.asarray(dc.weights)
        if ref_w is None:
            ref_w = w
        payload = {
            "backend": name, "t": t, "n_sites": int(sp.shape[0]),
            "chunk": getattr(backend, "chunk", None),
            "wall_us": round(wall_us, 1), "dist_err": round(err, 4),
            "ref_backend": ref_backend,
            "max_weight_dev_vs_ref": float(np.max(np.abs(w - ref_w))),
        }
        rows.append(f"coreset_backend_ab/{name}/t={t},{wall_us:.0f},"
                    f"json={json.dumps(payload)}")
        print(rows[-1], flush=True)
    return rows


def run(scale: float = 0.05, out_rows: List[str] | None = None,
        sizes=(100, 200, 400, 800)) -> List[str]:
    rows = out_rows if out_rows is not None else []
    pts_np, k = paper_dataset("pendigits", scale=max(scale * 10, 0.5))
    pts = jnp.asarray(pts_np)
    idx = partition_indices(pts_np, 10, "weighted", seed=1)
    sp, sm = pad_partition(pts_np, idx)
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    for objective in ("kmeans", "kmedian"):
        for t in sizes:
            central = build_coreset(jax.random.PRNGKey(0), pts, k, t,
                                    objective=objective)
            e_central = _max_rel_err(central.points, central.weights, pts, k,
                                     objective)
            dc = distributed_coreset(jax.random.PRNGKey(0), sp, sm, k, t,
                                     objective=objective)
            cs = dc.flatten()
            e_dist = _max_rel_err(cs.points, cs.weights, pts, k, objective)
            rows.append(f"coreset_size/{objective}/t={t},0,"
                        f"central_err={e_central:.4f};dist_err={e_dist:.4f}")
            print(rows[-1], flush=True)
    run_backend_ab(sp, sm, pts, k, out_rows=rows)
    return rows


if __name__ == "__main__":
    run()
