"""WAN fault-injection benchmarks (``BENCH_faults.json`` is the CI
artifact).

Two curves, each asserted-while-measured (every row carries the
quiescence-certification flags, so a regression in the runtime shows up
as a flipped boolean in the artifact, not just a moved number):

* ``faults/staleness/*`` -- staleness vs link heterogeneity: per-edge
  clock mode on ``wan_clusters`` with the cross-rack cost swept 1x..16x.
  The period of an edge is its cost ratio, so the mean staleness (excess
  rounds past each node's lossless-flood eccentricity) climbs with the
  cost spread while the cost-weighted ledger stays schedule-independent
  (send-once relay: the same transmissions happen, later).

* ``faults/quiesce/*`` -- drop-rate vs rounds-to-quiesce: seeded fault
  plans of increasing edge-drop fraction (plus one churn outage) on
  three topologies, mode ``"full"``. Reported rounds are certified
  against the ``horizon + surviving-diameter`` bound.

``faults/cert/*`` rows run the full certificate (completion bound,
quiescence, duplicate idempotence, and -- at ``--full`` scale --
engine-vs-restricted-oracle bit-identity) once per activation mode on a
churn-under-duplication plan.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import json_row
from repro.core import topology
from repro.core.partition import pad_partition, partition_indices
from repro.wan.faults import FaultPlan, random_fault_plan
from repro.wan.quiesce import certify_quiescence
from repro.wan.runtime import wan_flood_exec

CROSS_COSTS = (1.0, 2.0, 4.0, 8.0, 16.0)
DROP_FRACS = (0.0, 0.1, 0.2, 0.3)


def _quiesce_topologies():
    return {
        "grid": topology.grid(3, 3),
        "er": topology.erdos_renyi(12, 0.35, seed=3),
        "wan": topology.wan_clusters(3, 3, cross_cost=16.0, cross_links=2,
                                     seed=0),
    }


def _payload(n: int) -> jnp.ndarray:
    return jnp.arange(n, dtype=jnp.float32)[:, None] * 100.0 + 3.0


def run(scale: float = 1.0, n_runs: int = 1,
        out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    del n_runs  # wall times come from the runtime's own wall_s column

    # -- staleness vs link-cost heterogeneity (clock mode, fault-free) ------
    for cc in CROSS_COSTS:
        g = topology.wan_clusters(3, 3, cross_cost=cc, cross_links=2, seed=0)
        _, res = wan_flood_exec(g, _payload(g.n), mode="clock",
                                unit_scalars=1.0)
        d = res.ledger.as_dict()
        json_row(
            rows, f"faults/staleness/wan/cross_{cc:g}", res.wall_s * 1e6,
            topology="wan", mode="clock", cross_cost=cc,
            n_sites=g.n, m_edges=g.m, diameter=topology.diameter(g),
            max_period=int(np.rint(cc)),
            staleness=d["staleness"],
            rounds_to_complete=res.rounds_to_complete,
            rounds_to_quiesce=res.rounds_to_quiesce,
            link_cost=d["link_cost"], messages=d["messages"],
        )

    # -- drop rate vs rounds to quiesce (full mode, certified) --------------
    for name, g in _quiesce_topologies().items():
        sync_rounds = topology.diameter(g)
        for df in DROP_FRACS:
            plan = random_fault_plan(g, seed=7, drop_frac=df, n_churn=1,
                                     churn_window=(1, 3))
            cert = certify_quiescence(g, plan, mode="full", seed=2)
            _, res = wan_flood_exec(g, _payload(g.n), mode="full",
                                    faults=plan, unit_scalars=1.0, seed=2)
            json_row(
                rows, f"faults/quiesce/{name}/drop_{df:g}",
                res.wall_s * 1e6,
                topology=name, mode="full", drop_frac=df,
                edges_dropped=len(plan.drop), n_churn=len(plan.churn),
                horizon=plan.horizon(),
                sync_rounds=sync_rounds,
                surviving_diameter=cert.surviving_diameter,
                bound=cert.bound,
                rounds_to_complete=res.rounds_to_complete,
                rounds_to_quiesce=res.rounds_to_quiesce,
                staleness=res.ledger.staleness,
                messages=res.ledger.as_dict()["messages"],
                cert_ok=cert.ok,
            )

    # -- full certificates, one per activation mode -------------------------
    g = topology.wan_clusters(3, 4, cross_links=2, seed=0)
    plan = FaultPlan(drop=((0, 1),), churn=((5, 1, 3), (9, 0, -1)),
                     dup_rate=0.2, seed=3)
    clustering_kw = {}
    if scale >= 1.0:
        rng = np.random.default_rng(2)
        pts = np.concatenate(
            [c + 0.2 * rng.standard_normal((140, 5)) for c in
             3.0 * rng.standard_normal((3, 5))]).astype(np.float32)
        sp, sm = pad_partition(pts, partition_indices(pts, g.n, "weighted",
                                                      seed=1))
        clustering_kw = dict(check_clustering=True,
                             key=jax.random.PRNGKey(17),
                             site_points=jnp.asarray(sp),
                             site_mask=jnp.asarray(sm), k=3, t=48)
    for mode in ("full", "clock", "random"):
        cert = certify_quiescence(g, plan, mode=mode, seed=4,
                                  **clustering_kw)
        json_row(
            rows, f"faults/cert/{mode}", 0.0,
            topology="wan", mode=mode,
            horizon=cert.horizon,
            surviving_diameter=cert.surviving_diameter,
            max_period=cert.max_period,
            rounds_to_complete=cert.rounds_to_complete,
            rounds_to_quiesce=cert.rounds_to_quiesce,
            bound=cert.bound,
            completed_within_bound=cert.completed_within_bound,
            quiesced=cert.quiesced,
            duplicates_idempotent=cert.duplicates_idempotent,
            duplicate_messages_extra=cert.duplicate_messages_extra,
            centers_match=cert.centers_match,
            staleness=cert.staleness_mean,
            cert_ok=cert.ok,
        )
    return rows


if __name__ == "__main__":
    run(scale=0.1, n_runs=1)
