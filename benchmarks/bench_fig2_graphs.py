"""Paper Figure 2 (and Figures 4-5): k-means cost ratio vs communication on
general graphs, ours vs COMBINE, across topologies and partition skews.

The communication budget axis is the total points transmitted; for a given
budget both algorithms get the same sample total t (they then flood the same
number of points, so equal budget -- Sec. 5 methodology). Expectation from
the paper: ~equal on uniform/similarity partitions, ours 2-5% better cost
(10-20%+ communication savings) on skewed (weighted/degree) partitions.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import (Setting, avg_over_runs, baseline_cost,
                               load_setting, run_combine, run_ours)


SETTINGS = [
    Setting("synthetic", "random", "uniform", 25),
    Setting("synthetic", "random", "weighted", 25),
    Setting("pendigits", "random", "uniform", 10),
    Setting("pendigits", "random", "weighted", 10),
    Setting("letter", "grid", "weighted", 9),
    Setting("colorhistogram", "preferential", "degree", 25),
    Setting("yearpredictionmsd", "random", "weighted", 100),
    Setting("yearpredictionmsd", "grid", "weighted", 100),
]


def run(scale: float = 0.05, n_runs: int = 2, budgets=(3, 6),
        out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    ci = scale < 0.5
    if ci:
        budgets = budgets[:1]
    for st in SETTINGS:
        # CI scale: cap the 100-site settings at 36 sites (6x6 grids)
        n_sites = min(st.n_sites, 36) if ci else st.n_sites
        st = Setting(st.dataset, st.topology, st.partition, n_sites,
                     scale=scale, seed=0)
        pts, k, g, sp, sm = load_setting(st)
        import jax.numpy as jnp
        base = baseline_cost(jax.random.PRNGKey(7), jnp.asarray(pts), k)
        for mult in budgets:
            t = int(mult * k * g.n)     # budget in samples: mult*(k*n)
            t0 = time.time()
            ours = avg_over_runs(
                lambda kk: run_ours(kk, sp, sm, k, t, jnp.asarray(pts)),
                n_runs)
            comb = avg_over_runs(
                lambda kk: run_combine(kk, sp, sm, k, t, jnp.asarray(pts)),
                n_runs)
            dt = (time.time() - t0) / (2 * n_runs) * 1e6
            rows.append(
                f"fig2/{st.dataset}/{st.topology}/{st.partition}/t={t},"
                f"{dt:.0f},ours={ours/base:.4f};combine={comb/base:.4f}")
            print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
