"""Paper Figure 3 (and Figures 6-7): ours vs Zhang et al. on BFS spanning
trees of the communication graphs, at equal communication budgets.

Budget accounting (points over tree edges): ours moves each site's portion
depth(v) edges to the root: sum_v depth_v * (t_v + k). Zhang moves one
(s + k)-point coreset per non-root edge: (n-1)(s+k). Given a budget B we
solve each method's size parameter to match B. Expectation: ours ~10-30%
better cost ratio (error accumulation hits Zhang, Sec. 5 Results).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Setting, avg_over_runs, baseline_cost,
                               load_setting, run_ours, run_zhang)
from repro.core.topology import bfs_spanning_tree

SETTINGS = [
    Setting("synthetic", "random", "weighted", 25),
    Setting("pendigits", "random", "weighted", 10),
    Setting("letter", "grid", "weighted", 9),
    Setting("yearpredictionmsd", "grid", "weighted", 100),
]


def run(scale: float = 0.05, n_runs: int = 2, budgets=(4,),
        out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    ci = scale < 0.5
    if ci:
        budgets = budgets[:1]
    for st in SETTINGS:
        n_sites = min(st.n_sites, 25) if ci else st.n_sites
        st = Setting(st.dataset, st.topology, st.partition, n_sites,
                     scale=scale, seed=0)
        pts, k, g, sp, sm = load_setting(st)
        tree = bfs_spanning_tree(g, root=0)
        mean_depth = float(np.mean(tree.depth))
        base = baseline_cost(jax.random.PRNGKey(7), jnp.asarray(pts), k)
        for mult in budgets:
            budget = int(mult * k * g.n * max(tree.height, 1))
            # ours: sum_v depth_v*(t_v+k) ~ mean_depth*(t + nk) = budget
            t = max(int(budget / max(mean_depth, 1e-9) - g.n * k), k)
            # zhang: (n-1)*(s+k) = budget
            s = max(int(budget / (g.n - 1) - k), k)
            t0 = time.time()
            ours = avg_over_runs(
                lambda kk: run_ours(kk, sp, sm, k, t, jnp.asarray(pts)),
                n_runs)
            zh = avg_over_runs(
                lambda kk: run_zhang(kk, sp, sm, tree, k, s,
                                     jnp.asarray(pts)), n_runs)
            dt = (time.time() - t0) / (2 * n_runs) * 1e6
            rows.append(
                f"fig3/{st.dataset}/{st.topology}/h={tree.height}/B={budget},"
                f"{dt:.0f},ours={ours/base:.4f};zhang={zh/base:.4f}")
            print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
