"""Accuracy-vs-communication frontier across coreset strategies
(``BENCH_frontier.json`` at the repo root is the CI artifact).

For each registered :class:`~repro.core.strategy.CoresetStrategy` x
topology pair, sweep the sample budget ``t`` and record the (bytes,
cost-ratio) curve of one full Algorithm-2 run on the sim engine: bytes is
the analytic :class:`~repro.core.comm.CommLedger` total for the round
(Theorem-2 flood pricing for exchange strategies; the single
tree-shuffle for ``"mapreduce"``), cost-ratio is the solution's k-means
cost on the *full* data normalized by a restarted central solve (the
paper's Fig. 2 metric). Each row also reports the distance to the
communication lower bound of Zhang-Xiao-Liu (arXiv 1507.00026):
Omega(s * k) points must move for any O(1)-approximation over ``s``
sites, priced here as ``lb_bytes = s * k * 4(d+1)`` -- the
``bytes_over_lb`` column is how far each strategy sits above the
information-theoretic floor, so the communication/accuracy tradeoff
regresses visibly per PR.

The ``frontier/undercut/wan`` row certifies the mapreduce claim on the
heterogeneous WAN topology: its single shuffle strictly undercuts
Algorithm 1's two diameter floods in both raw bytes and cost-weighted
link bytes.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import json_row
from repro.core import clustering, strategy, topology
from repro.core.distributed import graph_distributed_kmeans
from repro.core.partition import pad_partition, partition_indices

N_SITES = 9
K, D = 4, 8


def _topologies():
    return {
        "ring": topology.ring(N_SITES),
        "er": topology.erdos_renyi(N_SITES, 0.3, seed=3),
        "wan": topology.wan_clusters(3, 3, cross_cost=16.0, cross_links=2,
                                     seed=0),
    }


def _site_data(scale: float):
    rng = np.random.default_rng(0)
    per = max(int(400 * scale), 60)
    centers = 3.0 * rng.standard_normal((K, D))
    pts = np.concatenate(
        [centers[i] + 0.15 * rng.standard_normal((per, D))
         for i in range(K)]).astype(np.float32)
    idx = partition_indices(pts, N_SITES, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    return jnp.asarray(pts), jnp.asarray(sp), jnp.asarray(sm)


def run(scale: float = 0.05, n_runs: int = 2,
        out_rows: List[str] = None) -> None:
    pts, sp, sm = _site_data(scale)
    key = jax.random.PRNGKey(0)
    _, central = clustering.solve(jax.random.PRNGKey(1), pts, K, restarts=4)
    central = float(central)
    budgets = (48, 96, 192)
    lb_bytes = N_SITES * K * 4.0 * (D + 1)   # Zhang et al. Omega(s k) floor

    wan_bytes = {}
    for topo_name, g in _topologies().items():
        for name in strategy.available_strategies():
            for t in budgets:
                t0 = time.time()
                r = graph_distributed_kmeans(key, sp, sm, K, t, graph=g,
                                             engine="sim", strategy=name)
                jax.block_until_ready(r.centers)
                us = (time.time() - t0) * 1e6
                ratio = float(clustering.cost(pts, r.centers)) / central
                by = float(r.ledger.bytes)
                if topo_name == "wan" and t == budgets[-1]:
                    wan_bytes[name] = by
                json_row(out_rows, f"frontier/{name}/{topo_name}/t{t}", us,
                         strategy=name, topology=topo_name, t=t,
                         cost_ratio=round(ratio, 4), bytes=by,
                         link_cost=round(float(r.ledger.link_cost), 1),
                         lb_bytes=lb_bytes,
                         bytes_over_lb=round(by / lb_bytes, 2))

    a, m = wan_bytes["algorithm1"], wan_bytes["mapreduce"]
    json_row(out_rows, "frontier/undercut/wan", 0.0,
             algorithm1_bytes=a, mapreduce_bytes=m,
             undercut=bool(m < a), ratio=round(m / a, 4))
