"""Kernel + backend-dispatch benchmarks.

Two sections:

1. **Backend A/B through the dispatch layer** -- the two primitive ops and
   an end-to-end weighted Lloyd solve routed through every registered
   backend (``jnp`` / ``jnp_chunked`` / ``pallas``). On this CPU container
   the pallas rows run in interpret mode (wall times are NOT TPU times);
   the same sweep on a TPU host measures the fused kernels for real. One
   JSON row per (op, backend, shape) so the perf trajectory can track
   backend speedups across PRs.

2. **Analytic TPU v5e roofline** for each kernel configuration:
       flops  = 2 n k d (distance matmul) [+ 2 n k d accumulate for lloyd]
       bytes  = 4(nd + kd + n(out))   HBM, fused (distance matrix never stored)
       naive  = + 4 n k               HBM for the materialized matrix
   The fused kernel's arithmetic intensity flops/bytes rises by ~k/2 vs
   naive.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import json_row
from repro.core import backend as backend_mod
from repro.core import clustering, objective
from repro.kernels import ops, ref

PEAK = 197e12
BW = 819e9

# the chunked entrant uses a chunk *below* the sweep sizes so the lax.map
# path actually runs (the registry default of 65536 would fall through to
# the dense code at benchmark n)
BENCH_CHUNK = 1024


def dispatch_entrants():
    chunked = backend_mod.register_backend(
        backend_mod.JnpChunkedBackend(BENCH_CHUNK, name="jnp_chunked_bench"))
    return (("jnp", backend_mod.get_backend("jnp")),
            ("jnp_chunked", chunked),
            ("pallas", backend_mod.get_backend("pallas")))


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / reps * 1e6


def _data(n, k, d, seed=0):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    ctr = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    w = jnp.asarray(np.abs(rng.standard_normal(n)).astype(np.float32))
    return pts, ctr, w


def run_dispatch(out_rows: List[str] | None = None,
                 shapes=((4096, 64, 32), (16384, 50, 16))) -> List[str]:
    """A/B the registered backends on the primitive ops and an end-to-end
    weighted Lloyd solve, all through the dispatch layer. One row per
    (objective, backend, shape): the k-means rows time ``lloyd_stats``, the
    k-median rows time the fused ``weiszfeld_stats`` primitive, and the
    trimmed rows time the two-pass robust update (``min_dist_argmin`` for
    the residual trim mask, then ``lloyd_stats`` on the masked weights) --
    all objectives are peers of the dispatch layer."""
    rows = out_rows if out_rows is not None else []
    interpreted = jax.default_backend() != "tpu"
    for n, k, d in shapes:
        pts, ctr, w = _data(n, k, d)
        for name, b in dispatch_entrants():
            t_mda = _time(jax.jit(lambda p, c: b.min_dist_argmin(p, c)),
                          pts, ctr)
            t_ls = _time(jax.jit(lambda p, c, ww: b.lloyd_stats(p, c, ww)),
                         pts, ctr, w)
            t_e2e = _time(
                lambda p, c, ww: clustering.lloyd(p, c, weights=ww, iters=2,
                                                  backend=b),
                pts, ctr, w, reps=1)

            json_row(
                rows, f"backend_dispatch/{name}/n={n}/k={k}/d={d}", t_ls,
                backend=name,
                objective="kmeans",
                interpret=bool(interpreted and name == "pallas"),
                chunk=getattr(b, "chunk", None),
                n=n, k=k, d=d,
                min_dist_argmin_us=round(t_mda, 1),
                lloyd_stats_us=round(t_ls, 1),
                lloyd2_e2e_us=round(t_e2e, 1),
            )

            t_ws = _time(
                jax.jit(lambda p, c, ww: b.weiszfeld_stats(p, c, ww)),
                pts, ctr, w)
            t_e2e_med = _time(
                lambda p, c, ww: clustering.lloyd(p, c, weights=ww, iters=2,
                                                  objective="kmedian",
                                                  backend=b),
                pts, ctr, w, reps=1)
            json_row(
                rows,
                f"backend_dispatch_kmedian/{name}/n={n}/k={k}/d={d}", t_ws,
                backend=name,
                objective="kmedian",
                interpret=bool(interpreted and name == "pallas"),
                chunk=getattr(b, "chunk", None),
                n=n, k=k, d=d,
                weiszfeld_stats_us=round(t_ws, 1),
                lloyd2_e2e_us=round(t_e2e_med, 1),
            )

            # trimmed robust update: pass 1 residuals (min_dist_argmin),
            # pass 2 lloyd_stats with the top-t residual weights zeroed --
            # never an (n, k) materialization
            trimmed = objective.kmeans_trimmed(max(n // 20, 1))
            t_trim = _time(
                jax.jit(lambda p, c, ww: trimmed.update(b, p, ww, c)),
                pts, ctr, w)
            t_e2e_trim = _time(
                lambda p, c, ww: clustering.lloyd(p, c, weights=ww, iters=2,
                                                  objective=trimmed.name,
                                                  backend=b),
                pts, ctr, w, reps=1)
            json_row(
                rows,
                f"backend_dispatch_trimmed/{name}/n={n}/k={k}/d={d}",
                t_trim,
                backend=name,
                objective=trimmed.name,
                interpret=bool(interpreted and name == "pallas"),
                chunk=getattr(b, "chunk", None),
                n=n, k=k, d=d,
                trimmed_update_us=round(t_trim, 1),
                lloyd2_e2e_us=round(t_e2e_trim, 1),
                overhead_vs_lloyd_stats=round(t_trim / t_ls, 2),
            )
    return rows


def run_roofline(out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    shapes = [(4096, 64, 128), (16384, 256, 128), (65536, 50, 128)]
    for n, k, d in shapes:
        pts, ctr, w = _data(n, k, d)

        t_ref = _time(jax.jit(ref.min_dist_argmin_ref), pts, ctr)
        t_pal = _time(lambda p, c: ops.min_dist_argmin(p, c), pts, ctr)

        flops = 2.0 * n * k * d
        fused_bytes = 4.0 * (n * d + k * d + 2 * n)
        naive_bytes = fused_bytes + 4.0 * n * k
        t_compute = flops / PEAK
        t_fused = max(t_compute, fused_bytes / BW)
        t_naive = max(t_compute, naive_bytes / BW)
        rows.append(
            f"kernel_distance_argmin/n={n}/k={k}/d={d},{t_pal:.0f},"
            f"ref_us={t_ref:.0f};interp_us={t_pal:.0f};"
            f"tpu_fused_us={t_fused*1e6:.1f};tpu_naive_us={t_naive*1e6:.1f};"
            f"tpu_speedup={t_naive/t_fused:.2f}")
        print(rows[-1], flush=True)

        t_ref2 = _time(jax.jit(ref.lloyd_stats_ref), pts, ctr, w)
        t_pal2 = _time(lambda p, c, ww: ops.lloyd_stats(p, c, ww), pts, ctr,
                       w)
        flops2 = 4.0 * n * k * d
        fused2 = 4.0 * (n * d + 2 * k * d + k + n)
        naive2 = fused2 + 8.0 * n * k
        tf = max(flops2 / PEAK, fused2 / BW)
        tn = max(flops2 / PEAK, naive2 / BW)
        rows.append(
            f"kernel_lloyd_stats/n={n}/k={k}/d={d},{t_pal2:.0f},"
            f"ref_us={t_ref2:.0f};interp_us={t_pal2:.0f};"
            f"tpu_fused_us={tf*1e6:.1f};tpu_naive_us={tn*1e6:.1f};"
            f"tpu_speedup={tn/tf:.2f}")
        print(rows[-1], flush=True)
    return rows


def run(out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    run_dispatch(out_rows=rows)
    run_roofline(out_rows=rows)
    return rows


if __name__ == "__main__":
    run()
