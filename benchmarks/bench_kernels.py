"""Kernel micro-benchmarks: fused Pallas kernels (interpret mode on this CPU
container -- wall times are NOT TPU times) vs the jnp oracle, plus the
ANALYTIC TPU v5e roofline for each kernel configuration.

Analytic model per (n, k, d) tile sweep:
    flops  = 2 n k d (distance matmul) [+ 2 n k d accumulate for lloyd]
    bytes  = 4(nd + kd + n(out))   HBM, fused (distance matrix never stored)
    naive  = + 4 n k               HBM for the materialized matrix
The fused kernel's arithmetic intensity flops/bytes rises by ~k/2 vs naive.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

PEAK = 197e12
BW = 819e9


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run(out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    shapes = [(4096, 64, 128), (16384, 256, 128), (65536, 50, 128)]
    for n, k, d in shapes:
        rng = np.random.default_rng(0)
        pts = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
        ctr = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
        w = jnp.ones((n,), jnp.float32)

        t_ref = _time(jax.jit(ref.min_dist_argmin_ref), pts, ctr)
        t_pal = _time(lambda p, c: ops.min_dist_argmin(p, c), pts, ctr)

        flops = 2.0 * n * k * d
        fused_bytes = 4.0 * (n * d + k * d + 2 * n)
        naive_bytes = fused_bytes + 4.0 * n * k
        t_compute = flops / PEAK
        t_fused = max(t_compute, fused_bytes / BW)
        t_naive = max(t_compute, naive_bytes / BW)
        rows.append(
            f"kernel_distance_argmin/n={n}/k={k}/d={d},{t_pal:.0f},"
            f"ref_us={t_ref:.0f};interp_us={t_pal:.0f};"
            f"tpu_fused_us={t_fused*1e6:.1f};tpu_naive_us={t_naive*1e6:.1f};"
            f"tpu_speedup={t_naive/t_fused:.2f}")
        print(rows[-1], flush=True)

        t_ref2 = _time(jax.jit(ref.lloyd_stats_ref), pts, ctr, w)
        t_pal2 = _time(lambda p, c, ww: ops.lloyd_stats(p, c, ww), pts, ctr,
                       w)
        flops2 = 4.0 * n * k * d
        fused2 = 4.0 * (n * d + 2 * k * d + k + n)
        naive2 = fused2 + 8.0 * n * k
        tf = max(flops2 / PEAK, fused2 / BW)
        tn = max(flops2 / PEAK, naive2 / BW)
        rows.append(
            f"kernel_lloyd_stats/n={n}/k={k}/d={d},{t_pal2:.0f},"
            f"ref_us={t_ref2:.0f};interp_us={t_pal2:.0f};"
            f"tpu_fused_us={tf*1e6:.1f};tpu_naive_us={tn*1e6:.1f};"
            f"tpu_speedup={tn/tf:.2f}")
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
