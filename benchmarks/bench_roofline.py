"""Aggregate the dry-run JSONs (experiments/dryrun/) into the roofline table
consumed by EXPERIMENTS.md Sec. Roofline. Emits one CSV row per cell."""
from __future__ import annotations

import glob
import json
import os
from typing import List


def load_reports(dry_dir: str = "experiments/dryrun"):
    reps = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        d["_file"] = os.path.basename(path)
        reps.append(d)
    return reps


def run(out_rows: List[str] | None = None,
        dry_dir: str = "experiments/dryrun") -> List[str]:
    rows = out_rows if out_rows is not None else []
    reps = load_reports(dry_dir)
    if not reps:
        rows.append("roofline/none,0,no dry-run artifacts found; run "
                    "python -m repro.launch.dryrun --all --mesh both")
        print(rows[-1])
        return rows
    for d in reps:
        if d.get("status") != "ok":
            rows.append(f"roofline/{d['_file']},0,status=FAIL")
            print(rows[-1], flush=True)
            continue
        step_s = max(d["compute_s"], d["memory_s"], d["collective_s"])
        rows.append(
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']},"
            f"{step_s*1e6:.0f},"
            f"compute_s={d['compute_s']:.3e};memory_s={d['memory_s']:.3e};"
            f"collective_s={d['collective_s']:.3e};"
            f"bottleneck={d['bottleneck']};"
            f"useful={d['useful_flop_ratio']:.3f};"
            f"roofline_frac={d['roofline_fraction']:.3f};"
            f"peak_gb={d['peak_memory_bytes']/1e9:.2f};"
            f"fits={d.get('fits_hbm')}")
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
