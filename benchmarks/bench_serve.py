"""Multi-tenant serving benchmarks: fused stacked-center dispatch vs a
per-tenant serial loop, as JSON rows (``BENCH_serve.json`` in CI).

For each backend and tenant count T, register T tenants (k centers in R^d
each) on one :class:`~repro.serve.cluster.ClusterServeEngine` and measure:

* **serial** QPS: the pre-engine serving model -- a Python loop issuing one
  ``query_assignments`` dispatch per tenant (all tenants share one compiled
  shape, so this is the *best case* for the serial path);
* **batched** QPS: enqueue every tenant's batch and drain with
  ``engine.run()`` -- the queue assembles full stacked batches and launches
  ``ceil(T / max_group)`` fused ``query_assignments_batched`` dispatches;
* **step-latency p50/p99**: a bursty loop (a random quarter of tenants
  enqueue per step) timing individual ``step()`` calls -- the tail a
  tenant's query waits behind everyone else's traffic.

On this CPU container the pallas rows run in interpret mode (a Python
interpreter per grid tile), so its tenant counts are clamped -- wall times
are NOT TPU times; jnp rows carry the cross-tenant scaling story here.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import json_row
from repro.core import backend as backend_mod
from repro.serve import ClusterServeEngine, StaticCenters

K, D, Q_PER_TENANT = 8, 16, 8
MAX_GROUP = 1024


def _make_tenants(T: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((T, K, D)).astype(np.float32)
    queries = rng.standard_normal((T, Q_PER_TENANT, D)).astype(np.float32)
    return centers, queries


def _serial_pass(backend: str, queries, centers) -> list:
    """One dispatch per tenant, identical shapes (one compile total). Like
    the engine's tickets, results are materialized host-side -- a serving
    loop hands assignments to the caller, it doesn't keep device handles."""
    outs = []
    for t in range(queries.shape[0]):
        a, dist = backend_mod.query_assignments(queries[t], centers[t],
                                                backend=backend)
        outs.append((np.asarray(a), np.asarray(dist)))
    return outs


def _bench_one(backend: str, T: int, n_runs: int, rows: List[str],
               burst_steps: int) -> None:
    centers, queries = _make_tenants(T)
    n_q = T * Q_PER_TENANT

    eng = ClusterServeEngine(backend=backend, max_group=MAX_GROUP)
    tids = [eng.add_tenant(StaticCenters(centers[t]), k=K, d=D)
            for t in range(T)]

    def batched_pass():
        tickets = [eng.enqueue(tid, queries[i])
                   for i, tid in enumerate(tids)]
        eng.run()
        return tickets

    # warm-up compiles both paths, and doubles as the parity check
    tickets = batched_pass()
    serial = _serial_pass(backend, queries, centers)
    agree = np.mean([np.array_equal(tk.assign, a)
                     for tk, (a, _) in zip(tickets, serial)])

    t_batched = min(_timed(batched_pass) for _ in range(n_runs))
    t_serial = min(_timed(lambda: _serial_pass(backend, queries, centers))
                   for _ in range(n_runs))

    # bursty step-latency: a random quarter of tenants arrives per step
    rng = np.random.default_rng(1)
    lat_ms = []
    for _ in range(burst_steps):
        for i in rng.choice(T, size=max(T // 4, 1), replace=False):
            eng.enqueue(tids[i], queries[i])
        t0 = time.perf_counter()
        while eng.pending_queries():
            eng.step()
        lat_ms.append((time.perf_counter() - t0) * 1e3)

    st = eng.stats
    json_row(rows, f"serve/{backend}/T={T}/k={K}/d={D}",
             t_batched / n_q * 1e6,
             tenants=T, n_queries=n_q,
             qps_batched=round(n_q / t_batched),
             qps_serial=round(n_q / t_serial),
             speedup=round(t_serial / t_batched, 2),
             p50_step_ms=round(float(np.percentile(lat_ms, 50)), 3),
             p99_step_ms=round(float(np.percentile(lat_ms, 99)), 3),
             dispatches_per_pass=-(-T // MAX_GROUP),
             compiled_shapes=len(eng.compiled_shapes),
             padded_frac=round(st.n_padded / (st.n_padded + st.n_queries),
                               4),
             parity=float(agree))


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(scale: float = 1.0, n_runs: int = 3,
        out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    interpreted = jax.default_backend() != "tpu"
    full = scale >= 1.0
    plans = [("jnp", (256, 1024) if full else (16, 64)),
             ("jnp_chunked", (256, 1024) if full else (16,)),
             # interpret mode pays a Python loop per grid tile: clamp T
             ("pallas", ((64,) if interpreted else (256, 1024))
              if full else (8,))]
    burst_steps = 30 if full else 5
    for backend, t_counts in plans:
        for T in t_counts:
            _bench_one(backend, T, n_runs, rows, burst_steps)
    return rows


if __name__ == "__main__":
    run(scale=0.05)
