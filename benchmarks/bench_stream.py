"""Streaming subsystem benchmarks: ingest throughput and query latency per
clustering backend, as JSON rows.

Per backend (``jnp`` / ``jnp_chunked`` / ``pallas``):

* **ingest**: push a drifting-mixture stream through a
  :class:`~repro.stream.tree.CoresetTree` (merge-and-reduce), report
  points/sec and the summary-size bound actually achieved;
* **query**: batched nearest-center queries through the service's fused
  path, report us/batch and points/sec;
* **parity**: fraction of query assignments agreeing with the ``jnp``
  reference on identical centers (the acceptance check that the pallas
  interpret kernels and XLA agree).

On this CPU container the pallas rows run in interpret mode (wall times are
NOT TPU times) -- the same sweep on a TPU host measures the fused kernels
for real.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import json_row
from repro.core import backend as backend_mod
from repro.data.synthetic import drifting_mixture_stream
from repro.stream import ClusterQueryService, StreamState, TreeConfig

BACKENDS = ("jnp", "jnp_chunked", "pallas")


def _ingest(backend: str, n_batches: int, batch_size: int, d: int, k: int,
            t: int) -> tuple:
    cfg = TreeConfig(k=k, t=t, d=d, batch_size=batch_size, levels=16,
                     backend=backend)
    stream = StreamState(cfg)
    batches = list(drifting_mixture_stream(n_batches, batch_size, d=d, k=k,
                                           seed=0))
    # warm-up: push 2 covers both jit specializations (push 1 compiles the
    # leaf build_coreset; push 2 compiles the (2*slot, d) merge -- every
    # later merge reuses that shape regardless of level)
    for b in batches[:2]:
        stream.push(b)
    jax.block_until_ready(stream.tree.summary().weights)
    t0 = time.time()
    for b in batches[2:]:
        stream.push(b)
    jax.block_until_ready(stream.tree.summary().weights)
    dt = time.time() - t0
    return stream, (n_batches - 2) * batch_size / max(dt, 1e-9), dt


def run(scale: float = 1.0, out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    interpreted = jax.default_backend() != "tpu"
    n_batches = max(int(50 * scale), 8)
    batch_size, d, k, t = 1024, 16, 8, 128
    q_batch = 512
    queries = jnp.asarray(np.random.default_rng(1).standard_normal(
        (q_batch, d)).astype(np.float32))

    ref_assign = None
    for backend in BACKENDS:
        stream, pts_per_sec, dt = _ingest(backend, n_batches, batch_size, d,
                                          k, t)
        svc = ClusterQueryService(stream, k=k, staleness_frac=None,
                                  backend=backend,
                                  key=jax.random.PRNGKey(7))
        svc.refresh()
        svc.query(queries)            # warm up the query compile
        t0 = time.time()
        reps = 10
        for _ in range(reps):
            assign, _ = svc.query(queries)
        jax.block_until_ready(assign)
        q_us = (time.time() - t0) / reps * 1e6

        # parity: assignments on identical centers must match the jnp
        # reference (centers differ per backend run; re-query on ref's)
        if ref_assign is None:
            ref_assign, ref_centers = assign, svc.centers()
            agree = 1.0
        else:
            a, _ = backend_mod.query_assignments(queries, ref_centers,
                                                 backend=backend)
            agree = float(np.mean(np.asarray(a) == np.asarray(ref_assign)))

        json_row(
            rows, f"stream/{backend}/b={batch_size}/d={d}/k={k}/t={t}",
            q_us,
            backend=backend,
            interpret=bool(interpreted and backend == "pallas"),
            n_ingested=n_batches * batch_size,
            ingest_pts_per_sec=round(pts_per_sec, 1),
            ingest_wall_s=round(dt, 3),
            summary_points=int(stream.tree.max_summary_points()),
            occupied_levels=stream.tree.occupied_levels(),
            query_batch=q_batch,
            query_us_per_batch=round(q_us, 1),
            query_pts_per_sec=round(q_batch / max(q_us * 1e-6, 1e-9), 1),
            assign_agree_vs_jnp=agree,
        )
    return rows


if __name__ == "__main__":
    run(scale=0.2)
