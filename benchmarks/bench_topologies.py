"""Topology execution engine benchmarks: engine-vs-simulation wall time and
ledger parity for Algorithm 2 across every topology generator, as JSON rows
(``BENCH_topologies.json`` at the repo root is the CI artifact).

Rows: {ring, star, grid, torus, er(p=0.3), preferential, wan} x {sim, exec}
x backend, each with ``routing`` and ``link_cost`` (cost-weighted bytes)
columns. Each row reports the wall time of one full Algorithm-2 run, the
communication ledger (measured for the exec engine, analytic for sim --
``ledger_match`` asserts they agree on every axis incl. link_cost), the
schedule's round count, and a centers-bit-parity flag against the sim
oracle.

The weighted-routing payoff section runs Algorithm 2 on ``wan_clusters``
(cheap intra-rack cliques, 16x cross-rack links) under ``routing="bfs"``
vs ``"min_cost"``: the min-cost tree pays for one cross link per attached
rack where BFS pays for every shallow entry point, so its cost-weighted
ledger is strictly lower -- the ``topo/wan/routing-ratio`` row reports the
ratio (dominated by the cross-rack traffic the two trees carry).

On this CPU container the pallas rows run in interpret mode (wall times
are NOT TPU times); the engine itself is backend-agnostic -- only the
local solves dispatch through the registry.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import json_row
from repro.core import topology
from repro.core.distributed import (distributed_kmeans_tree,
                                    graph_distributed_kmeans)
from repro.core.partition import pad_partition, partition_indices

BACKENDS = ("jnp", "pallas")
N_SITES = 9
LEDGER_UNITS = ("scalars", "points", "messages", "link_cost")


def _topologies():
    return {
        "ring": topology.ring(N_SITES),
        "star": topology.star(N_SITES),
        "grid": topology.grid(3, 3),
        "torus": topology.torus(3, 3),
        "er": topology.erdos_renyi(N_SITES, 0.3, seed=3),
        "preferential": topology.preferential(N_SITES, 2, seed=0),
        "wan": topology.wan_clusters(3, 3, cross_cost=16.0, cross_links=2,
                                     seed=0),
    }


def _site_data(scale: float):
    rng = np.random.default_rng(0)
    k, d = 4, 8
    per = max(int(400 * scale), 60)
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.15 * rng.standard_normal((per, d)) for i in range(k)]
    ).astype(np.float32)
    idx = partition_indices(pts, N_SITES, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    return jnp.asarray(sp), jnp.asarray(sm), k


def _time(fn, n_runs: int) -> tuple:
    out = fn()                      # warm-up + result for parity checks
    jax.block_until_ready(out.centers)
    t0 = time.time()
    for _ in range(n_runs):
        r = fn()
        jax.block_until_ready(r.centers)
    return out, (time.time() - t0) / n_runs * 1e6


def _ledger_match(a, b) -> bool:
    return all(getattr(a.ledger, u) == getattr(b.ledger, u)
               for u in LEDGER_UNITS)


def _phase_walls(res) -> dict:
    """Measured per-phase wall clock (us) of one executed run, from the
    engine's ExecResult.wall_s observability column; {} for sim results
    (the analytic path has no executed phases to time)."""
    detail = getattr(res, "exec_detail", None)
    if detail is None or not getattr(detail, "rounds", None):
        return {}
    walls = {f"wall_{name}_us": r.wall_s * 1e6
             for name, r in detail.rounds.items()}
    walls["wall_exec_total_us"] = sum(walls.values())
    return walls


def run(scale: float = 1.0, n_runs: int = 2,
        out_rows: List[str] | None = None) -> List[str]:
    rows = out_rows if out_rows is not None else []
    interpreted = jax.default_backend() != "tpu"
    sp, sm, k = _site_data(scale)
    t = 120
    key = jax.random.PRNGKey(0)
    topos = _topologies()

    for backend in BACKENDS:
        for name, g in topos.items():
            runs = {}
            for engine in ("sim", "exec"):
                res, us = _time(
                    lambda e=engine: graph_distributed_kmeans(
                        key, sp, sm, k, t=t, graph=g, backend=backend,
                        engine=e),
                    n_runs)
                runs[engine] = (res, us)
            sim_res, sim_us = runs["sim"]
            ex_res, ex_us = runs["exec"]
            ledger_match = _ledger_match(sim_res, ex_res)
            r1 = ex_res.exec_detail.rounds["round1"]
            for engine, (res, us) in runs.items():
                json_row(
                    rows, f"topo/{name}/{engine}/{backend}", us,
                    topology=name, engine=engine, backend=backend,
                    routing="flood",
                    interpret=bool(interpreted and backend == "pallas"),
                    n_sites=g.n, m_edges=g.m,
                    diameter=topology.diameter(g),
                    scalars=res.ledger.scalars, points=res.ledger.points,
                    messages=res.ledger.messages,
                    link_cost=res.ledger.link_cost,
                    exec_rounds=(r1.rounds if engine == "exec" else None),
                    ledger_match=ledger_match,
                    centers_bit_equal=bool(np.array_equal(
                        np.asarray(res.centers),
                        np.asarray(sim_res.centers))),
                    **_phase_walls(res),
                )

        # BFS tree over the ER graph (the paper's Zhang-et-al. setting)
        tree = topology.bfs_spanning_tree(topos["er"], root=0)
        tree_runs = {}
        for engine in ("sim", "exec"):
            res, us = _time(
                lambda e=engine: distributed_kmeans_tree(
                    key, sp, sm, k, t=t, tree=tree, backend=backend,
                    engine=e),
                n_runs)
            tree_runs[engine] = (res, us)
        sim_res = tree_runs["sim"][0]
        ledger_match = _ledger_match(sim_res, tree_runs["exec"][0])
        for engine, (res, us) in tree_runs.items():
            json_row(
                rows, f"topo/bfs-tree/{engine}/{backend}", us,
                topology="bfs-tree", engine=engine, backend=backend,
                routing="bfs",
                interpret=bool(interpreted and backend == "pallas"),
                n_sites=tree.n, height=tree.height,
                scalars=res.ledger.scalars, points=res.ledger.points,
                messages=res.ledger.messages,
                link_cost=res.ledger.link_cost,
                ledger_match=ledger_match,
                centers_bit_equal=bool(np.array_equal(
                    np.asarray(res.centers), np.asarray(sim_res.centers))),
                **_phase_walls(res),
            )

    # -- weighted routing payoff: min-cost vs BFS trees on WAN links --------
    g = topos["wan"]
    routing_link = {}
    for routing in ("bfs", "min_cost"):
        tree = topology.spanning_tree(g, routing=routing)
        runs = {}
        for engine in ("sim", "exec"):
            res, us = _time(
                lambda e=engine: graph_distributed_kmeans(
                    key, sp, sm, k, t=t, graph=g, backend="jnp",
                    routing=routing, engine=e),
                n_runs)
            runs[engine] = (res, us)
        sim_res = runs["sim"][0]
        ledger_match = _ledger_match(sim_res, runs["exec"][0])
        routing_link[routing] = sim_res.ledger.link_cost
        for engine, (res, us) in runs.items():
            json_row(
                rows, f"topo/wan/{routing}/{engine}", us,
                topology="wan", engine=engine, backend="jnp",
                routing=routing, n_sites=g.n, m_edges=g.m,
                height=tree.height,
                tree_edge_cost=tree.edge_cost_total(),
                scalars=res.ledger.scalars, points=res.ledger.points,
                messages=res.ledger.messages,
                link_cost=res.ledger.link_cost,
                ledger_match=ledger_match,
                centers_bit_equal=bool(np.array_equal(
                    np.asarray(res.centers), np.asarray(sim_res.centers))),
                **_phase_walls(res),
            )
    bfs_tree = topology.bfs_spanning_tree(g)
    mst_tree = topology.mst_spanning_tree(g)
    json_row(
        rows, "topo/wan/routing-ratio", 0.0,
        topology="wan", routing="min_cost_vs_bfs",
        link_cost_bfs=routing_link["bfs"],
        link_cost_min_cost=routing_link["min_cost"],
        link_ratio=routing_link["bfs"] / routing_link["min_cost"],
        tree_edge_cost_bfs=bfs_tree.edge_cost_total(),
        tree_edge_cost_min_cost=mst_tree.edge_cost_total(),
        cross_edge_ratio=(bfs_tree.edge_cost_total()
                          / mst_tree.edge_cost_total()),
        min_cost_wins=bool(routing_link["min_cost"] < routing_link["bfs"]),
    )
    return rows


if __name__ == "__main__":
    run(scale=0.1, n_runs=1)
