"""Shared experiment harness for the paper's evaluation (Sec. 5).

For a (dataset, topology, partition) triple and a communication budget, run
each algorithm, solve k-means on its summary, and report the cost of that
solution *on the full data*, normalized by the cost of solving on the full
data directly (the paper's "k-means cost ratio" vs the Lloyd baseline).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, clustering
from repro.core.coreset import distributed_coreset
from repro.core.distributed import _solve_on_coreset
from repro.core.partition import pad_partition, partition_indices
from repro.core.topology import (Graph, bfs_spanning_tree, erdos_renyi, grid,
                                 preferential)
from repro.data.synthetic import paper_dataset


@dataclasses.dataclass
class Setting:
    dataset: str
    topology: str          # "random" | "grid" | "preferential"
    partition: str         # "uniform" | "similarity" | "weighted" | "degree"
    n_sites: int
    scale: float = 1.0
    seed: int = 0


def make_graph(setting: Setting) -> Graph:
    n = setting.n_sites
    if setting.topology == "random":
        return erdos_renyi(n, 0.3, seed=setting.seed)
    if setting.topology == "grid":
        r = int(np.sqrt(n))
        assert r * r == n, "grid needs square n_sites"
        return grid(r, r)
    return preferential(n, 2, seed=setting.seed)


def load_setting(setting: Setting):
    pts, k = paper_dataset(setting.dataset, seed=setting.seed,
                           scale=setting.scale)
    g = make_graph(setting)
    idx = partition_indices(pts, g.n, setting.partition,
                            seed=setting.seed + 1, degrees=g.degrees())
    sp, sm = pad_partition(pts, idx)
    return pts, k, g, jnp.asarray(sp), jnp.asarray(sm)


def cost_on_full(pts: jnp.ndarray, centers: jnp.ndarray) -> float:
    return float(clustering.cost(pts, centers, chunk=65536))


def baseline_cost(key, pts, k, restarts=3, iters=12) -> float:
    _, c = clustering.solve(key, pts, k, lloyd_iters=iters,
                            restarts=restarts)
    return float(c)


def run_ours(key, sp, sm, k, t, pts) -> float:
    dc = distributed_coreset(key, sp, sm, k, t)
    cs = dc.flatten()
    centers = _solve_on_coreset(jax.random.fold_in(key, 1), cs, k,
                                "kmeans", 12)
    return cost_on_full(pts, centers)


def run_combine(key, sp, sm, k, t, pts) -> float:
    cs = baselines.combine(key, sp, sm, k, t_total=t)
    centers = _solve_on_coreset(jax.random.fold_in(key, 1), cs, k,
                                "kmeans", 12)
    return cost_on_full(pts, centers)


def run_zhang(key, sp, sm, tree, k, s, pts) -> float:
    cs, _ = baselines.zhang_tree(key, np.asarray(sp), np.asarray(sm), tree,
                                 k, s=s)
    centers = _solve_on_coreset(jax.random.fold_in(key, 1), cs, k,
                                "kmeans", 12)
    return cost_on_full(pts, centers)


def avg_over_runs(fn: Callable[[jax.Array], float], n_runs: int,
                  seed: int = 0) -> float:
    vals = [fn(jax.random.PRNGKey(seed + 100 * r)) for r in range(n_runs)]
    return float(np.mean(vals))


def json_row(rows: List[str], name: str, us_per_call: float,
             **payload) -> str:
    """Append one ``name,us_per_call,json={...}`` CSV row (the machine-
    readable format the perf trajectory parses; see bench_kernels /
    bench_stream) and echo it. Returns the row."""
    row = f"{name},{us_per_call:.0f},json={json.dumps(payload)}"
    rows.append(row)
    print(row, flush=True)
    return row


def write_json_rows(path: str, rows: List[str]) -> List[Dict]:
    """Materialize ``json_row`` output as a JSON artifact: parse every
    ``name,us_per_call,json={...}`` row into a record and dump the list to
    ``path`` (rows without an embedded json payload -- plain CSV rows like
    the roofline section's -- are skipped). This is the file CI uploads so
    the perf trajectory survives the run (see benchmarks/run.py)."""
    out: List[Dict] = []
    for row in rows:
        name, _, rest = row.partition(",")
        us, _, payload = rest.partition(",json=")
        if not payload:
            continue
        rec: Dict = {"name": name, "us_per_call": float(us)}
        rec.update(json.loads(payload))
        out.append(rec)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    return out
