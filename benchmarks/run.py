"""Benchmark entry point -- one section per paper table/figure plus the LM
roofline. Prints ``name,us_per_call,derived`` CSV rows; the kernels section
additionally writes its rows to ``BENCH_kernels.json`` at the repo root
(the CI perf-trajectory artifact).

    PYTHONPATH=src python -m benchmarks.run             # CI scale (~minutes)
    PYTHONPATH=src python -m benchmarks.run --full      # paper scale
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from benchmarks import (bench_collectives, bench_comm_scaling,
                        bench_coreset_size, bench_faults, bench_fig2_graphs,
                        bench_fig3_trees, bench_frontier, bench_kernels,
                        bench_roofline, bench_serve, bench_stream,
                        bench_topologies)
from benchmarks.common import write_json_rows

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets and run counts")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig2,fig3,comm,size,"
                         "kernels,roofline,serve,stream,topologies,faults,"
                         "frontier,collectives")
    args = ap.parse_args(argv)
    scale = 1.0 if args.full else 0.05
    n_runs = 5 if args.full else 2
    only = set(args.only.split(",")) if args.only else None

    rows = ["name,us_per_call,derived"]
    print(rows[0])
    t0 = time.time()
    if only is None or "fig2" in only:
        bench_fig2_graphs.run(scale=scale, n_runs=n_runs, out_rows=rows)
    if only is None or "fig3" in only:
        bench_fig3_trees.run(scale=scale, n_runs=n_runs, out_rows=rows)
    if only is None or "comm" in only:
        bench_comm_scaling.run(out_rows=rows)
    if only is None or "size" in only:
        bench_coreset_size.run(scale=scale, out_rows=rows)
    if only is None or "kernels" in only:
        kernel_rows: list = []
        bench_kernels.run(out_rows=kernel_rows)
        rows.extend(kernel_rows)
        out_json = os.path.join(_REPO_ROOT, "BENCH_kernels.json")
        write_json_rows(out_json, kernel_rows)
        print(f"# wrote {out_json}", file=sys.stderr)
    if only is None or "serve" in only:
        serve_rows: list = []
        bench_serve.run(scale=scale, n_runs=n_runs, out_rows=serve_rows)
        rows.extend(serve_rows)
        out_json = os.path.join(_REPO_ROOT, "BENCH_serve.json")
        write_json_rows(out_json, serve_rows)
        print(f"# wrote {out_json}", file=sys.stderr)
    if only is None or "stream" in only:
        bench_stream.run(scale=scale, out_rows=rows)
    if only is None or "topologies" in only:
        topo_rows: list = []
        bench_topologies.run(scale=scale, n_runs=n_runs, out_rows=topo_rows)
        rows.extend(topo_rows)
        out_json = os.path.join(_REPO_ROOT, "BENCH_topologies.json")
        write_json_rows(out_json, topo_rows)
        print(f"# wrote {out_json}", file=sys.stderr)
    if only is None or "faults" in only:
        fault_rows: list = []
        bench_faults.run(scale=scale, n_runs=n_runs, out_rows=fault_rows)
        rows.extend(fault_rows)
        out_json = os.path.join(_REPO_ROOT, "BENCH_faults.json")
        write_json_rows(out_json, fault_rows)
        print(f"# wrote {out_json}", file=sys.stderr)
    if only is None or "collectives" in only:
        coll_rows: list = []
        bench_collectives.run(scale=scale, n_runs=n_runs,
                              out_rows=coll_rows)
        rows.extend(coll_rows)
        out_json = os.path.join(_REPO_ROOT, "BENCH_collectives.json")
        write_json_rows(out_json, coll_rows)
        print(f"# wrote {out_json}", file=sys.stderr)
    if only is None or "frontier" in only:
        frontier_rows: list = []
        bench_frontier.run(scale=scale, n_runs=n_runs,
                           out_rows=frontier_rows)
        rows.extend(frontier_rows)
        out_json = os.path.join(_REPO_ROOT, "BENCH_frontier.json")
        write_json_rows(out_json, frontier_rows)
        print(f"# wrote {out_json}", file=sys.stderr)
    if only is None or "roofline" in only:
        bench_roofline.run(out_rows=rows)
    print(f"# total {time.time()-t0:.1f}s, {len(rows)-1} rows",
          file=sys.stderr)


if __name__ == "__main__":
    main()
