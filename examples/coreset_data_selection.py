"""The paper's technique in the training data plane: distributed
coreset-based data selection, then training on the selected subset.

Flow: candidate pool sharded across (simulated) data-parallel sites ->
mean-pooled embedding per example -> Algorithm 1 over the embedding space
(ONE scalar communicated per site) -> weighted representative subset ->
train. Compares against training on a uniform random subset of equal size.

    PYTHONPATH=src python examples/coreset_data_selection.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import BigramLM, embed_examples, gather_selected, select_coreset
from repro.models import init_params
from repro.optim import adamw
from repro.train import TrainConfig, make_train_step


def train_on(batches, cfg, steps=60, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw.init(params)
    tc = TrainConfig(peak_lr=1e-3, warmup_steps=5, total_steps=steps,
                     remat="none")
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    losses = []
    for s in range(steps):
        b = batches[s % len(batches)]
        params, opt, m = step_fn(params, opt, b, jnp.asarray(s, jnp.int32))
        losses.append(float(m["ce"]))
    return losses


def main():
    cfg = configs.get_reduced("llama3_8b")
    data = BigramLM(cfg.vocab_size, seed=0)
    n_sites, per_site, L, B = 4, 128, 64, 8

    pool = data.batch(0, n_sites * per_site, L)
    toks = np.asarray(pool["tokens"]).reshape(n_sites, per_site, L)
    labs = np.asarray(pool["labels"]).reshape(n_sites, per_site, L)

    # embed with a fresh model's embedding table (production would use the
    # current training state)
    params = init_params(jax.random.PRNGKey(0), cfg)
    emb = embed_examples(params["embed"]["table"], jnp.asarray(toks))
    sel = select_coreset(jax.random.PRNGKey(1), emb,
                         jnp.ones(emb.shape[:2], bool), k=8,
                         t=n_sites * per_site // 4)
    chosen = gather_selected(jnp.asarray(toks), sel)
    keep = np.asarray(chosen["weights"]) > 0
    sel_tok = np.asarray(chosen["tokens"])[keep]
    print(f"pool {n_sites * per_site} examples -> selected {keep.sum()} "
          f"(communication: {n_sites} scalars + the subset itself)")

    lab_of = {tuple(t): l for t, l in
              zip(toks.reshape(-1, L).tolist(), labs.reshape(-1, L).tolist())}
    sel_lab = np.asarray([lab_of[tuple(t)] for t in sel_tok.tolist()])
    n_b = max(len(sel_tok) // B, 1)
    sel_batches = [{"tokens": jnp.asarray(sel_tok[i*B:(i+1)*B]),
                    "labels": jnp.asarray(sel_lab[i*B:(i+1)*B])}
                   for i in range(n_b) if len(sel_tok[i*B:(i+1)*B]) == B]

    rng = np.random.default_rng(2)
    ridx = rng.choice(n_sites * per_site, size=len(sel_tok), replace=False)
    rt, rl = toks.reshape(-1, L)[ridx], labs.reshape(-1, L)[ridx]
    rand_batches = [{"tokens": jnp.asarray(rt[i*B:(i+1)*B]),
                     "labels": jnp.asarray(rl[i*B:(i+1)*B])}
                    for i in range(n_b) if len(rt[i*B:(i+1)*B]) == B]

    l_sel = train_on(sel_batches, cfg)
    l_rnd = train_on(rand_batches, cfg)
    print(f"final CE -- coreset-selected subset: {np.mean(l_sel[-10:]):.4f}"
          f"  vs uniform random subset: {np.mean(l_rnd[-10:]):.4f}")


if __name__ == "__main__":
    main()
