"""Paper Sec. 5 end to end on one dataset: all three algorithms (ours /
COMBINE / Zhang et al.) across three topologies at equal communication.

    PYTHONPATH=src python examples/distributed_clustering.py [--scale 0.1]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, clustering
from repro.core.coreset import distributed_coreset
from repro.core.distributed import _solve_on_coreset
from repro.core.partition import pad_partition, partition_indices
from repro.core.topology import bfs_spanning_tree, erdos_renyi, grid, preferential
from repro.data.synthetic import paper_dataset


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="colorhistogram")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--t", type=int, default=600)
    args = ap.parse_args(argv)

    pts_np, k = paper_dataset(args.dataset, scale=args.scale)
    pts = jnp.asarray(pts_np)
    key = jax.random.PRNGKey(0)
    _, base = clustering.solve(key, pts, k, restarts=4)
    print(f"{args.dataset}: {pts.shape} k={k} "
          f"baseline cost {float(base):.1f}\n")
    print(f"{'topology':14s} {'partition':12s} {'ours':>8s} {'combine':>8s} "
          f"{'zhang':>8s}")

    for topo_name, g, part in [
        ("random", erdos_renyi(25, 0.3, seed=2), "weighted"),
        ("grid", grid(5, 5), "weighted"),
        ("preferential", preferential(25, 2, seed=2), "degree"),
    ]:
        idx = partition_indices(pts_np, g.n, part, seed=3,
                                degrees=g.degrees())
        sp, sm = pad_partition(pts_np, idx)
        sp, sm = jnp.asarray(sp), jnp.asarray(sm)

        dc = distributed_coreset(key, sp, sm, k, args.t)
        ours = _solve_on_coreset(key, dc.flatten(), k, "kmeans", 12)
        r_ours = float(clustering.cost(pts, ours) / base)

        cs = baselines.combine(key, sp, sm, k, t_total=args.t)
        comb = _solve_on_coreset(key, cs, k, "kmeans", 12)
        r_comb = float(clustering.cost(pts, comb) / base)

        tree = bfs_spanning_tree(g, root=0)
        s = max(args.t // g.n, k)
        zh, _ = baselines.zhang_tree(key, np.asarray(sp), np.asarray(sm),
                                     tree, k, s=s)
        zc = _solve_on_coreset(key, zh, k, "kmeans", 12)
        r_zh = float(clustering.cost(pts, zc) / base)

        print(f"{topo_name:14s} {part:12s} {r_ours:8.4f} {r_comb:8.4f} "
              f"{r_zh:8.4f}")


if __name__ == "__main__":
    main()
