"""Quickstart: distributed k-means via coresets on a general topology.

Simulates 9 sites on a 3x3 grid network holding skewed shards of a Gaussian
mixture, builds the distributed coreset (Algorithm 1), clusters it
(Algorithm 2), and compares against centralized Lloyd on the full data --
while counting every transmitted point (Algorithm 3 ledger).

    PYTHONPATH=src python examples/quickstart.py [--backend jnp|jnp_chunked|pallas]

For the streaming counterpart -- merge-and-reduce ingestion, per-site
streams with periodic aggregation rounds, and live cluster queries -- see
``examples/streaming.py``.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (clustering, distributed_kmeans, grid,
                        bfs_spanning_tree, distributed_kmeans_tree)
from repro.core.partition import pad_partition, partition_indices


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="clustering backend: jnp | jnp_chunked | pallas "
                         "(default: auto)")
    args = ap.parse_args(argv)
    rng = np.random.default_rng(0)
    k, d = 5, 10
    centers = 3.0 * rng.standard_normal((k, d))
    data = np.concatenate(
        [c + 0.2 * rng.standard_normal((4000, d)) for c in centers]
    ).astype(np.float32)
    print(f"dataset: {data.shape[0]} points in R^{d}, k={k}")

    g = grid(3, 3)
    print(f"network: 3x3 grid, {g.n} sites, {g.m} edges")
    idx = partition_indices(data, g.n, "weighted", seed=1)
    sp, sm = pad_partition(data, idx)
    print("site sizes:", [len(i) for i in idx])

    key = jax.random.PRNGKey(0)
    res = distributed_kmeans(key, jnp.asarray(sp), jnp.asarray(sm), k,
                             t=400, graph=g, backend=args.backend)

    _, central_cost = clustering.solve(key, jnp.asarray(data), k,
                                       restarts=4, backend=args.backend)
    dist_cost = clustering.cost(jnp.asarray(data), res.centers)
    print(f"\ncentralized Lloyd cost : {float(central_cost):12.1f} "
          f"(ships {data.shape[0]} points)")
    print(f"distributed coreset cost: {float(dist_cost):12.1f} "
          f"(ratio {float(dist_cost/central_cost):.4f})")
    print(f"communication: {res.ledger.points:.0f} points + "
          f"{res.ledger.scalars:.0f} scalars "
          f"= {res.ledger.bytes/1e3:.1f} KB "
          f"vs {data.nbytes/1e3:.1f} KB raw")

    tree = bfs_spanning_tree(g, root=0)
    res_t = distributed_kmeans_tree(key, jnp.asarray(sp), jnp.asarray(sm),
                                    k, t=400, tree=tree,
                                    backend=args.backend)
    print(f"\nrooted-tree variant (h={tree.height}): "
          f"ratio {float(clustering.cost(jnp.asarray(data), res_t.centers)/central_cost):.4f}, "
          f"{res_t.ledger.points:.0f} points moved")

    # pluggable round protocols: same API, different communication shape
    print(f"\n{'strategy':<12} {'ratio':>8} {'KB':>8}")
    for name in ("algorithm1", "cohen_addad", "mapreduce"):
        r = distributed_kmeans(key, jnp.asarray(sp), jnp.asarray(sm), k,
                               t=400, graph=g, backend=args.backend,
                               strategy=name)
        ratio = float(clustering.cost(jnp.asarray(data), r.centers)
                      / central_cost)
        print(f"{name:<12} {ratio:>8.4f} {r.ledger.bytes/1e3:>8.1f}")


if __name__ == "__main__":
    main()
