"""Robust clustering demo: the trimmed objective on contaminated data.

Runs the contamination A/B that motivates the first-class objective layer
(DESIGN.md Sec. 15):

1. **Offline**: a Gaussian mixture with a few percent of far-field
   outliers. Plain ``kmeans`` spends centers chasing the contamination;
   ``kmeans_trimmed(t)`` excludes the top-t largest-residual points from
   every update and seeding step and recovers the true centers. Both run
   through the same registered descriptor machinery on the same backend.
2. **Streaming / distributed**: PR 7's ``contaminated_stream`` pushed
   round-robin into a :class:`DistributedStream` over a ring, aggregated
   with Algorithm 1. Recovered centers are scored on the *clean* stream
   (plain z=2 metric) -- the trimmed objective stays within a small factor
   of the uncontaminated run while plain k-means blows up by an order of
   magnitude.

    PYTHONPATH=src python examples/robust_outliers.py [--backend pallas] \
        [--outlier-frac 0.05] [--trim 0.08]

(On CPU the pallas backend runs the kernels in interpret mode.)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering, topology
from repro.core.coreset import build_coreset
from repro.data.synthetic import contaminated_stream, drifting_mixture_stream
from repro.stream import DistributedStream, TreeConfig


def offline_demo(args):
    rng = np.random.default_rng(0)
    k, d = 3, 2
    true_centers = np.array([[0, 0], [10, 0], [0, 10]], np.float32)
    inliers = np.concatenate(
        [c + 0.3 * rng.standard_normal((200, d)) for c in true_centers])
    n_out = int(args.outlier_frac / (1 - args.outlier_frac) * len(inliers))
    outliers = 100.0 * rng.standard_normal((n_out, d))
    pts = jnp.asarray(np.concatenate([inliers, outliers]).astype(np.float32))
    print(f"offline: {len(inliers)} inliers in {k} tight clusters + "
          f"{n_out} far-field outliers (|x| ~ 100)")

    key = jax.random.PRNGKey(0)
    inl = jnp.asarray(inliers)
    for obj in ("kmeans", f"kmeans_trimmed({n_out})"):
        c, _ = clustering.solve(key, pts, k, restarts=3, lloyd_iters=8,
                                objective=obj, backend=args.backend)
        inlier_cost = float(clustering.cost(inl, c, backend=args.backend))
        worst = float(jnp.abs(c).max())
        print(f"  {obj:22s} inlier cost {inlier_cost:10.1f}   "
              f"max |center| {worst:6.1f}"
              + ("   <- dragged into the far field" if worst > 20 else ""))

    # the trimmed objective also flows through coreset construction: the
    # excluded points carry zero sensitivity mass and zero sample weight
    cs = build_coreset(jax.random.PRNGKey(1), pts, k, 64,
                       objective=f"kmeans_trimmed({n_out})",
                       backend=args.backend)
    print(f"  trimmed coreset keeps weight {float(cs.weights.sum()):.0f} "
          f"of {pts.shape[0]} raw points ({n_out} excluded)")


def stream_demo(args):
    k, d, n_batches, bs = 5, 10, 12, 128
    g = topology.ring(4)

    def recover(objective, contaminated):
        cfg = TreeConfig(k=k, t=48, d=d, batch_size=bs, objective=objective,
                         backend=args.backend)
        ds = DistributedStream(g, cfg, key=jax.random.PRNGKey(3))
        gen = (contaminated_stream(n_batches, bs, d=d, k=k,
                                   outlier_frac=args.outlier_frac, seed=0)
               if contaminated else
               drifting_mixture_stream(n_batches, bs, d=d, k=k, seed=0))
        for i, b in enumerate(gen):
            ds.push(i % g.n, b)
        res = ds.aggregate(k, 40, engine=args.engine)
        clean = jnp.asarray(np.concatenate(
            list(drifting_mixture_stream(n_batches, bs, d=d, k=k, seed=0))))
        return float(clustering.cost(clean, res.centers,
                                     backend=args.backend))

    print(f"\nstream: {n_batches} batches x {bs} pts in R^{d} over a "
          f"{g.n}-node ring, {args.outlier_frac:.0%} far-field "
          f"contamination, engine={args.engine}")
    base = recover("kmeans", contaminated=False)
    plain = recover("kmeans", contaminated=True)
    trimmed = recover(f"kmeans_trimmed({args.trim:g})", contaminated=True)
    print(f"  clean-stream k-means cost of recovered centers:")
    print(f"    kmeans on clean stream         {base:10.1f}  (1.00x)")
    print(f"    kmeans on contaminated         {plain:10.1f}  "
          f"({plain / base:.2f}x)")
    print(f"    kmeans_trimmed({args.trim:g}) on same  {trimmed:10.1f}  "
          f"({trimmed / base:.2f}x)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="clustering backend: jnp | jnp_chunked | pallas")
    ap.add_argument("--outlier-frac", type=float, default=0.05)
    ap.add_argument("--trim", type=float, default=0.08,
                    help="trimmed fraction t for kmeans_trimmed(t)")
    ap.add_argument("--engine", default="sim", choices=["sim", "exec"])
    args = ap.parse_args(argv)
    offline_demo(args)
    stream_demo(args)


if __name__ == "__main__":
    main()
