"""Batched serving with the slot engine: more requests than slots,
continuous-batching style, on any assigned architecture.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma_2b
"""
import argparse

from repro.launch.serve import main as serve_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    args = ap.parse_args(argv)
    serve_main(["--arch", args.arch, "--requests", "6", "--slots", "3",
                "--max-new", "12", "--max-len", "48"])


if __name__ == "__main__":
    main()
