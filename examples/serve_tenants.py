"""Multi-tenant serving demo: many clustering models behind one engine.

Registers a mix of tenants on a shared :class:`ClusterServeEngine`:

* **static tenants** -- fixed center sets (offline-trained models, ragged
  k and d), the common read-only serving case;
* **live tenants** -- :class:`ClusterQueryService` streams whose centers
  go stale as data arrives and re-solve *through the engine's refresh
  budget*, so a re-solve never blocks other tenants' queries.

Each step the engine drains the admission queue, assembles same-shape
query chunks across tenants into stacked batches, and launches one fused
``query_assignments_batched`` dispatch per bucket (the Pallas
``distance_argmin_batched`` kernel on TPU) instead of one dispatch per
tenant.

    PYTHONPATH=src python examples/serve_tenants.py [--backend pallas] \
        [--tenants 64] [--steps 20] [--refresh-budget 1]

(On CPU the pallas backend runs the kernels in interpret mode.)
"""
import argparse

import numpy as np

from repro.data.synthetic import drifting_mixture_stream
from repro.serve import ClusterServeEngine, StaticCenters
from repro.stream import ClusterQueryService, StreamState, TreeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="clustering backend: jnp | jnp_chunked | pallas")
    ap.add_argument("--tenants", type=int, default=64,
                    help="static tenants (plus 2 live stream tenants)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--queries", type=int, default=8,
                    help="queries per active tenant per step")
    ap.add_argument("--refresh-budget", type=int, default=1,
                    help="max center re-solves per engine step")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    eng = ClusterServeEngine(backend=args.backend, max_bucket=256,
                             refresh_budget=args.refresh_budget)

    # static tenants: ragged k/d mix, as offline-trained models would be
    dims = {}
    for _ in range(args.tenants):
        k = int(rng.integers(2, 9))
        d = int(rng.choice([8, 16]))
        tid = eng.add_tenant(
            StaticCenters(rng.standard_normal((k, d)).astype(np.float32)),
            k=k, d=d)
        dims[tid] = d

    # live tenants: streams whose centers re-solve under the engine budget
    d_live, k_live = 8, 4
    cfg = TreeConfig(k=k_live, t=60, d=d_live, batch_size=200, levels=12,
                     backend=args.backend)
    live = []
    for seed in (1, 2):
        stream = StreamState(cfg)
        svc = ClusterQueryService(stream, k=k_live, staleness_frac=0.3,
                                  backend=args.backend, engine=eng)
        tid = eng.add_tenant(svc, k=k_live, d=d_live)
        dims[tid] = d_live
        live.append((svc, tid, seed))

    print(f"{len(dims)} tenants ({args.tenants} static + {len(live)} live) "
          f"on one engine, backend={eng.backend}, "
          f"refresh_budget={args.refresh_budget}")

    tids = list(dims)
    for step in range(args.steps):
        # live tenants ingest (their centers drift stale mid-run)
        for svc, _, seed in live:
            batch = next(iter(drifting_mixture_stream(
                1, cfg.batch_size, d=d_live, k=k_live,
                seed=100 * seed + step)))
            svc.push(batch)
        # a random half of the tenants sends a query burst
        active = rng.choice(tids, size=len(tids) // 2, replace=False)
        tickets = [eng.enqueue(t, rng.standard_normal(
            (args.queries, dims[t])).astype(np.float32)) for t in active]
        served = eng.run()
        assert all(t.done for t in tickets) and served == len(
            tickets) * args.queries

    st = eng.stats
    fused = st.n_tenant_dispatches / max(st.n_dispatches, 1)
    print(f"served {st.n_queries} queries in {st.n_steps} steps: "
          f"{st.n_dispatches} fused dispatches for "
          f"{st.n_tenant_dispatches} tenant-chunks "
          f"({fused:.1f} tenants/dispatch)")
    print(f"refreshes: {st.n_refreshes} run, {st.n_deferred_refreshes} "
          f"deferred past a step (stale tenants kept serving cached "
          f"centers)")
    print(f"compiled specializations: {len(eng.compiled_shapes)} "
          f"(bounded by the pow2 bucket grid)")
    print(f"padding overhead: {st.n_padded} padded rows "
          f"({st.n_padded / (st.n_padded + st.n_queries):.1%}); "
          f"phase wall-clock: refresh {st.refresh_s:.2f}s / "
          f"assign {st.assign_s:.2f}s")
    for svc, tid, _ in live:
        print(f"  live tenant {tid}: {svc.stats.n_refreshes} re-solves, "
              f"staleness at exit {svc.staleness():.0f} pts")


if __name__ == "__main__":
    main()
