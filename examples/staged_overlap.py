"""Staged-overlap coreset engine demo: Round 1 broken out of the lockstep
vmap (DESIGN.md Sec. 17).

Builds a deliberately skewed weighted partition (one dominant site, many
small ones -- exactly where the lockstep vmap wastes FLOPs padding every
site to the largest) and races three engines:

1. **lockstep** -- :func:`repro.core.coreset.distributed_coreset`, the
   batched Round-1 solve every site pays at the max pad length.
2. **staged strict** -- :func:`staged_distributed_coreset` with
   ``tol=0`` and no buckets: per-site dispatch with the Round-1 scalar
   exchange launched at each site's convergence, yet every output field
   bit-identical to lockstep (the parity contract).
3. **staged overlap** -- ``tol>0`` + ``site_buckets``: per-site
   power-of-two solve lengths and convergence early-exit; draws differ by
   construction, so it is scored by coreset quality instead.

    PYTHONPATH=src python examples/staged_overlap.py [--backend pallas] \
        [--sites 8] [--per 10000]

(On CPU the pallas backend runs the kernels in interpret mode; pass small
sizes there -- CI uses this as the staged-path interpret smoke.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering
from repro.core.coreset import distributed_coreset, staged_distributed_coreset
from repro.core.partition import pad_partition, partition_indices


def _skewed_sites(n_sites, per, d=32, k=4, seed=3):
    rng = np.random.default_rng(seed)
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.15 * rng.standard_normal((per, d)) for i in range(k)]
    ).astype(np.float32)
    idx = partition_indices(pts, n_sites, "weighted", seed=seed + 1)
    sp, sm = pad_partition(pts, idx)
    sizes = [len(i) for i in idx]
    return pts, jnp.asarray(sp), jnp.asarray(sm), k, sizes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="clustering backend: jnp | jnp_chunked | pallas")
    ap.add_argument("--sites", type=int, default=8)
    ap.add_argument("--per", type=int, default=10000,
                    help="points per mixture component")
    ap.add_argument("--t", type=int, default=256)
    args = ap.parse_args(argv)

    pts, sp, sm, k, sizes = _skewed_sites(args.sites, args.per)
    print(f"{len(pts)} points over {args.sites} sites, "
          f"sizes {min(sizes)}..{max(sizes)} (lockstep pads all to "
          f"{sp.shape[1]})")
    key = jax.random.PRNGKey(0)

    def timed(fn, reps=3):
        out = fn()                                   # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        return out, (time.perf_counter() - t0) / reps * 1e3

    base, ms_lock = timed(lambda: jax.block_until_ready(
        distributed_coreset(key, sp, sm, k, t=args.t,
                            backend=args.backend).weights))
    print(f"  lockstep vmap            {ms_lock:8.1f} ms")

    (strict, d_strict), ms_strict = timed(lambda: staged_distributed_coreset(
        key, sp, sm, k, t=args.t, backend=args.backend))
    bit = bool((np.asarray(strict.weights) == np.asarray(base)).all())
    print(f"  staged strict            {ms_strict:8.1f} ms   "
          f"bit_equal_lockstep={bit}")
    assert bit, "strict staged mode must be bit-identical to lockstep"

    (over, d_over), ms_over = timed(lambda: staged_distributed_coreset(
        key, sp, sm, k, t=args.t, backend=args.backend,
        tol=1e-3, site_buckets=True))
    flat = over.flatten()
    c, _ = clustering.solve(key, flat.points, k,
                            weights=jnp.maximum(flat.weights, 0.0),
                            restarts=3, backend=args.backend)
    _, full = clustering.solve(key, jnp.asarray(pts), k, restarts=3,
                               backend=args.backend)
    ratio = float(clustering.cost(jnp.asarray(pts), c,
                                  backend=args.backend) / full)
    print(f"  staged overlap           {ms_over:8.1f} ms   "
          f"speedup_vs_lockstep={ms_lock / ms_over:.2f}x   "
          f"cost_ratio={ratio:.4f}")
    print(f"    site solve lengths {d_over.site_lengths}")
    print(f"    refinement passes  {list(np.asarray(d_over.iters_run))} "
          f"(cap 5)")
    print(f"    round1 {d_over.wall_round1_s * 1e3:.1f} ms, "
          f"round2 {d_over.wall_round2_s * 1e3:.1f} ms")
    assert int(np.asarray(over.t_i).sum()) == args.t
    print("OK")


if __name__ == "__main__":
    main()
