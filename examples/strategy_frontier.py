"""Coreset strategies side by side: the accuracy-vs-bytes frontier.

Runs each registered round protocol -- ``algorithm1`` (the paper's
two-round choreography), ``cohen_addad`` ((1+eps) refined sensitivities,
same communication shape), and ``mapreduce`` (one shuffle, no scalar
exchange, no diameter floods) -- over the same sites on a heterogeneous
WAN topology, and prints one frontier line per strategy: k-means cost
ratio vs a centralized solve, raw bytes, and cost-weighted link bytes.

    PYTHONPATH=src python examples/strategy_frontier.py \
        [--backend jnp|jnp_chunked|pallas] [--t 200]

The full sweep (budget curves, three topologies, the Zhang et al. lower
bound column) is ``python -m benchmarks.run --only frontier``.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (available_strategies, clustering,
                        graph_distributed_kmeans, wan_clusters)
from repro.core.partition import pad_partition, partition_indices


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="clustering backend: jnp | jnp_chunked | pallas")
    ap.add_argument("--t", type=int, default=200, help="sample budget")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    k, d = 4, 8
    centers = 3.0 * rng.standard_normal((k, d))
    data = np.concatenate(
        [c + 0.2 * rng.standard_normal((900, d)) for c in centers]
    ).astype(np.float32)

    g = wan_clusters(3, 3, cross_cost=16.0, cross_links=2, seed=0)
    idx = partition_indices(data, g.n, "weighted", seed=1)
    sp, sm = pad_partition(data, idx)
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    print(f"dataset: {data.shape[0]} points in R^{d}, k={k}; "
          f"network: 3 racks x 3 (cross-rack links 16x), t={args.t}")

    key = jax.random.PRNGKey(0)
    _, central = clustering.solve(key, jnp.asarray(data), k, restarts=4,
                                  backend=args.backend)

    print(f"\n{'strategy':<12} {'cost ratio':>10} {'KB moved':>10} "
          f"{'link-KB':>10}")
    for name in available_strategies():
        r = graph_distributed_kmeans(key, sp, sm, k, t=args.t, graph=g,
                                     backend=args.backend, strategy=name)
        ratio = float(clustering.cost(jnp.asarray(data), r.centers) / central)
        print(f"{name:<12} {ratio:>10.4f} {r.ledger.bytes/1e3:>10.1f} "
              f"{r.ledger.link_cost/1e3:>10.1f}")
    print("\nmapreduce's single shuffle skips the scalar exchange and the "
          "diameter floods\nentirely -- same coreset weight mass, a "
          "fraction of the bytes.")


if __name__ == "__main__":
    main()
