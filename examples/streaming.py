"""Streaming demo: merge-and-reduce ingestion + live cluster queries.

Feeds a drifting Gaussian-mixture stream (the centers random-walk, so no
prefix is representative) through the streaming subsystem, three ways:

1. a single-site :class:`CoresetTree` -- bounded O(log n) memory, exact
   total-weight preservation;
2. a :class:`ClusterQueryService` on top -- staleness-bounded center
   refreshes while answering nearest-center queries mid-stream;
3. a :class:`DistributedStream` over a grid topology -- per-node trees plus
   periodic Algorithm-1 aggregation rounds, with the per-round
   communication ledger.

    PYTHONPATH=src python examples/streaming.py [--backend pallas] \
        [--batches 50] [--batch-size 1000]

(On CPU the pallas backend runs the kernels in interpret mode.)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering
from repro.core.coreset import build_coreset
from repro.core.topology import grid
from repro.data.synthetic import drifting_mixture_stream
from repro.stream import (ClusterQueryService, DistributedStream, StreamState,
                          TreeConfig)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="clustering backend: jnp | jnp_chunked | pallas")
    ap.add_argument("--batches", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=1000)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--dim", type=int, default=10)
    args = ap.parse_args(argv)

    k, d = args.k, args.dim
    cfg = TreeConfig(k=k, t=100, d=d, batch_size=args.batch_size, levels=20,
                     backend=args.backend)
    batches = list(drifting_mixture_stream(args.batches, args.batch_size,
                                           d=d, k=k, drift=0.08, seed=0))
    n_total = args.batches * args.batch_size
    print(f"stream: {args.batches} batches x {args.batch_size} pts in R^{d} "
          f"(drifting mixture), k={k}")

    # -- 1. single-site ingestion -------------------------------------------
    stream = StreamState(cfg)
    svc = ClusterQueryService(stream, k=k, staleness_frac=0.2,
                              key=jax.random.PRNGKey(1))
    probe = jnp.asarray(batches[0][:256])
    for i, b in enumerate(batches):
        svc.push(b)
        if (i + 1) % max(args.batches // 4, 1) == 0:
            assign, dist = svc.query(probe)   # live queries mid-stream
            print(f"  after batch {i+1:3d}: summary "
                  f"{stream.tree.max_summary_points():4d} pts in "
                  f"{stream.tree.occupied_levels()} buckets, "
                  f"refreshes={svc.stats.n_refreshes}, "
                  f"probe mean d^2={float(jnp.mean(dist)):.3f}")

    s = stream.summary()
    print(f"summary: {int(s.effective_size())} weighted points for "
          f"{n_total} ingested "
          f"(total weight {float(jnp.sum(s.weights)):.1f}); "
          f"bound {cfg.slot} * {stream.tree.occupied_levels()} buckets")

    # -- 2. streaming vs offline coreset quality ----------------------------
    full = jnp.asarray(np.concatenate(batches))
    centers_stream = svc.centers()
    stream_cost = float(clustering.cost(full, centers_stream,
                                        backend=args.backend))
    t_eq = max(int(s.effective_size()) - k, k + 1)
    off = build_coreset(jax.random.PRNGKey(2), full, k=k, t=t_eq,
                        backend=args.backend)
    c_off, _ = clustering.solve(jax.random.PRNGKey(3), off.points, k,
                                weights=off.weights, lloyd_iters=8,
                                restarts=2, backend=args.backend)
    off_cost = float(clustering.cost(full, c_off, backend=args.backend))
    print(f"k-means cost on full data: streaming {stream_cost:.1f} vs "
          f"offline coreset {off_cost:.1f} "
          f"(ratio {stream_cost / off_cost:.3f})")

    # -- 3. distributed streams over a topology -----------------------------
    g = grid(2, 2)
    ds = DistributedStream(g, cfg, key=jax.random.PRNGKey(4))
    agg_every = max(args.batches // (2 * g.n), 1) * g.n
    res = None
    for i, b in enumerate(batches):
        ds.push(i % g.n, b)                  # round-robin arrivals
        if (i + 1) % agg_every == 0:
            res = ds.aggregate(k=k, t=200)
    if res is None:
        res = ds.aggregate(k=k, t=200)
    dist_cost = float(clustering.cost(full, res.centers,
                                      backend=args.backend))
    led = ds.ledger.as_dict(by_phase=True)
    print(f"\ndistributed ({g.n} sites on a 2x2 grid, {ds.rounds} "
          f"aggregation rounds): cost ratio "
          f"{dist_cost / off_cost:.3f} vs offline")
    per_round = led["phases"][f"stream_round_{ds.rounds - 1}"]
    print(f"communication: {led['points']:.0f} points total "
          f"({per_round['points']:.0f} pts = {per_round['bytes']/1e3:.1f} KB "
          f"per round) vs {n_total} raw points/round for re-shipping")


if __name__ == "__main__":
    main()
