"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic bigram stream, with checkpointing and
heartbeat. On this CPU container a 25M-param proxy finishes in minutes; pass
--full-100m for the real thing (same code path, ~100M params).

    PYTHONPATH=src python examples/train_lm.py              # ~25M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --full-100m  # ~100M params
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args(argv)

    if args.full_100m:
        # 12 layers x d_model 768 + 128k vocab ~= 107M params
        argv = ["--arch", "llama3_8b", "--width", "768", "--layers", "12",
                "--steps", str(args.steps), "--batch", "8", "--seq", "256",
                "--microbatches", "2",
                "--ckpt-dir", "/tmp/repro_train_100m",
                "--ckpt-every", "100", "--log-every", "10"]
    else:
        # 8 layers x d_model 384 ~= 25M -- CI-speed proxy, same code path
        argv = ["--arch", "llama3_8b", "--width", "384", "--layers", "8",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128",
                "--ckpt-dir", "/tmp/repro_train_quick",
                "--ckpt-every", "100", "--log-every", "10"]
    log = train_main(argv)
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'no decrease'})")


if __name__ == "__main__":
    main()
