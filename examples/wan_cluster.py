"""Two-rack WAN demo: BFS vs min-cost routing for Algorithm 2, per phase.

Builds a ``wan_clusters`` topology -- two racks of cheap (cost-1)
intra-rack links joined by a handful of expensive (cost-16) cross-rack
links -- and runs the executed Algorithm-2 tree protocol under both
routing policies. Hop-count (BFS) routing enters the remote rack through
every shallow cross link it finds; min-cost (Prim) routing pays for
exactly one. The per-phase ledgers below show where that difference
lands: the gathers price each site's root path, the broadcasts price
every tree edge, and the ``link_cost`` column (cost-weighted bytes) is
what a WAN bill would charge.

    PYTHONPATH=src python examples/wan_cluster.py [--t 200] \
        [--rack-size 4] [--cross-links 3] [--cross-cost 16]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import graph_distributed_kmeans
from repro.core.partition import pad_partition, partition_indices
from repro.core.topology import spanning_tree, wan_clusters


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--t", type=int, default=200, help="coreset budget")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--rack-size", type=int, default=4)
    ap.add_argument("--cross-links", type=int, default=3)
    ap.add_argument("--cross-cost", type=float, default=16.0)
    ap.add_argument("--per-cluster", type=int, default=300)
    args = ap.parse_args(argv)

    g = wan_clusters(2, args.rack_size, cross_cost=args.cross_cost,
                     cross_links=args.cross_links, seed=0)
    print(f"wan_clusters: 2 racks x {args.rack_size} nodes, "
          f"{g.m} links ({sum(1 for c in g.costs if c > 1.0)} cross-rack "
          f"at cost {args.cross_cost:g})")

    rng = np.random.default_rng(0)
    k, d = args.k, 8
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.15 * rng.standard_normal((args.per_cluster, d))
         for i in range(k)]).astype(np.float32)
    idx = partition_indices(pts, g.n, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    key = jax.random.PRNGKey(0)

    results = {}
    for routing in ("bfs", "min_cost"):
        tree = spanning_tree(g, routing=routing)
        cross = sum(1 for v in range(g.n)
                    if tree.parent[v] >= 0 and tree.parent_costs()[v] > 1.0)
        res = graph_distributed_kmeans(key, sp, sm, k, t=args.t, graph=g,
                                       routing=routing, engine="exec")
        results[routing] = res
        print(f"\nrouting={routing}: tree height {tree.height}, "
              f"{cross} cross-rack link(s) in tree, "
              f"total tree edge cost {tree.edge_cost_total():g}")
        print(f"  {'phase':18s} {'scalars':>8s} {'points':>8s} "
              f"{'bytes':>10s} {'link_cost':>10s}")
        d_l = res.ledger.as_dict(by_phase=True)
        for phase, sub in d_l["phases"].items():
            print(f"  {phase:18s} {sub['scalars']:8.0f} {sub['points']:8.0f}"
                  f" {sub['bytes']:10.0f} {sub['link_cost']:10.0f}")
        print(f"  {'total':18s} {d_l['scalars']:8.0f} {d_l['points']:8.0f}"
              f" {d_l['bytes']:10.0f} {d_l['link_cost']:10.0f}")

    bfs_l = results["bfs"].ledger.link_cost
    mc_l = results["min_cost"].ledger.link_cost
    same = np.array_equal(np.asarray(results["bfs"].centers),
                          np.asarray(results["min_cost"].centers))
    print(f"\nmin-cost routing ships {bfs_l / mc_l:.2f}x fewer "
          f"cost-weighted bytes than BFS ({mc_l:.0f} vs {bfs_l:.0f}), "
          f"centers bit-identical: {same}")
    assert same, "routing must not change the clustering result"
    if min(args.cross_links, args.rack_size) >= 2:   # effective link count
        # with a single cross link both trees must use it (BFS can even
        # edge out min-cost on gather paths); min-cost strictly wins once
        # BFS has multiple shallow entry points to pay for
        assert mc_l < bfs_l, "min-cost routing must beat BFS on WAN links"
    elif mc_l >= bfs_l:
        print("(single cross link: both trees must cross it, no routing "
              "freedom to exploit)")


if __name__ == "__main__":
    main()
