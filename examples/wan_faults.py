"""Asynchronous failure-prone WAN deployment, end to end.

A 12-site deployment (3 racks, 16x cross-rack links) runs the paper's
Algorithm 1 while the network misbehaves: one cross link is down, one
node takes a 3-round outage, one node dies and never rejoins, and links
occasionally re-deliver old messages. The demo

1. certifies quiescence for every activation mode (synchronous-under-
   faults, per-edge clocks, randomized gossip),
2. runs ``graph_distributed_kmeans(engine="exec", faults=...)`` and
   checks the centers bit-match the host oracle restricted to the
   surviving sites, and
3. streams contaminated batches into a ``DistributedStream`` and runs
   one asynchronous aggregation round under the same plan.

    PYTHONPATH=src python examples/wan_faults.py [--backend pallas]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import pad_partition, partition_indices
from repro.core.topology import wan_clusters
from repro.data.synthetic import contaminated_stream
from repro.stream.ingest import DistributedStream
from repro.stream.tree import TreeConfig
from repro.wan import FaultPlan, certify_quiescence


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="local-solve backend (e.g. pallas; interpret "
                         "mode on CPU)")
    args = ap.parse_args()
    g = wan_clusters(3, 4, cross_links=2, seed=0)
    plan = FaultPlan(drop=((0, 1),),           # one intra-rack link cut
                     churn=((5, 1, 3),         # node 5: rounds [1, 3) outage
                            (9, 0, -1)),       # node 9: dead from round 0
                     dup_rate=0.15, seed=3)
    surv = plan.surviving_nodes(g.n)
    print(f"topology: {g.n} sites, {g.m} edges; survivors {surv.tolist()}")

    # clustered site data
    rng = np.random.default_rng(2)
    centers = 3.0 * rng.standard_normal((3, 5))
    pts = np.concatenate(
        [c + 0.2 * rng.standard_normal((140, 5)) for c in centers]
    ).astype(np.float32)
    sp, sm = pad_partition(pts, partition_indices(pts, g.n, "weighted",
                                                  seed=1))
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    key = jax.random.PRNGKey(17)

    print("\n-- quiescence certificates ------------------------------------")
    for mode in ("full", "clock", "random"):
        cert = certify_quiescence(g, plan, mode=mode, seed=4,
                                  check_clustering=True, key=key,
                                  site_points=sp, site_mask=sm, k=3, t=48,
                                  backend=args.backend)
        bound = "-" if cert.bound is None else cert.bound
        print(f"  mode={mode:6s} complete@{cert.rounds_to_complete:3d} "
              f"(bound {bound}), quiesce@{cert.rounds_to_quiesce:3d}, "
              f"staleness {cert.staleness_mean:5.2f}, "
              f"dup extra {cert.duplicate_messages_extra:7.0f} msgs "
              f"(tables unchanged: {cert.duplicates_idempotent}), "
              f"centers==oracle: {cert.centers_match}  "
              f"=> {'OK' if cert.ok else 'FAIL'}")

    print("\n-- one asynchronous stream round under the same faults --------")
    cfg = TreeConfig(k=4, t=60, d=6, batch_size=200, levels=12)
    ds = DistributedStream(g, cfg, key=jax.random.PRNGKey(5))
    batches = contaminated_stream(2 * g.n, cfg.batch_size, d=cfg.d, k=4,
                                  outlier_frac=0.05, burst_every=8, seed=5)
    for i, b in enumerate(batches):
        ds.push(i % g.n, b)
    res = ds.aggregate(k=4, t=120, mode="resample", engine="async",
                       faults=plan)
    d = res.ledger.as_dict()
    print(f"  coreset {tuple(res.coreset.points.shape)} from "
          f"{surv.size}/{g.n} surviving sites")
    print(f"  round ledger: {d['messages']:.0f} messages, "
          f"link_cost {d['link_cost']:.0f}, staleness {d['staleness']:.2f}")
    print(f"  centers:\n{np.asarray(res.centers).round(2)}")


if __name__ == "__main__":
    main()
