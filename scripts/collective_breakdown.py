"""Trip-count-aware per-op collective breakdown for one dry-run cell."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import sys
from collections import Counter

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.roofline import hlo as H


def breakdown(arch, shape, mesh_name="single", top=14):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cell = build_cell(arch, shape, mesh)
    comp = cell.lower().compile()
    comps = H.parse_computations(comp.as_text())
    entry = re.search(r"ENTRY\s+%?([\w.\-]+)", comp.as_text()).group(1)
    agg = Counter()

    def visit(name, mult, depth=0):
        c = comps.get(name)
        if c is None or depth > 60:
            return
        for op in c.ops:
            kind = (op.opcode[:-6] if op.opcode.endswith("-start")
                    else op.opcode)
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trips = (H._trip_count(comps[mc.group(1)], comps)
                         if mc and mc.group(1) in comps else 1)
                if mb:
                    visit(mb.group(1), mult * trips, depth + 1)
            elif op.opcode in ("fusion", "call", "conditional"):
                for called in H._CALL_RE.findall(op.rest):
                    visit(called, mult, depth + 1)
            elif kind in H.COLLECTIVES:
                b = H._shape_bytes(op.result_type)
                n, _ = H._group_size_and_span(op, None)
                if kind == "all-reduce":
                    link = 2.0 * (n - 1) / max(n, 1) * b
                elif kind == "all-gather":
                    link = (n - 1) / max(n, 1) * b
                elif kind == "reduce-scatter":
                    link = (n - 1) * b
                else:
                    link = b
                m = re.search(r'op_name="([^"]+)"', op.raw)
                nm = re.sub(r"/[a-z_0-9.()]*$", "",
                            (m.group(1) if m else "?"))[-58:]
                agg[(kind, op.result_type[:40], nm, n)] += link * mult

    visit(entry, 1.0)
    total = sum(agg.values())
    print(f"total link bytes/device: {total/1e9:.1f} GB "
          f"-> {total/50e9:.2f}s at 50GB/s")
    for (kind, shape_s, nm, n), b in agg.most_common(top):
        print(f"{b/1e9:8.2f}GB {kind:16s} N={n:3d} {shape_s:40s} {nm}")


if __name__ == "__main__":
    breakdown(sys.argv[1], sys.argv[2],
              sys.argv[3] if len(sys.argv) > 3 else "single")
