"""Render experiments/dryrun/*.json as the EXPERIMENTS.md roofline table."""
import glob
import json
import os
import sys


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main(dry_dir="experiments/dryrun", mesh_filter=None):
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") != "ok":
            rows.append((path, None))
            continue
        if mesh_filter and d["mesh"] != mesh_filter:
            continue
        rows.append((path, d))
    print("| arch | shape | mesh | peak GB | fits | compute | memory | "
          "collective | bottleneck | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for path, d in rows:
        if d is None:
            print(f"| {os.path.basename(path)} | FAIL | | | | | | | | | |")
            continue
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
              f"{d['peak_memory_bytes']/1e9:.1f} | "
              f"{'Y' if d.get('fits_hbm') else 'N'} | "
              f"{fmt_s(d['compute_s'])} | {fmt_s(d['memory_s'])} | "
              f"{fmt_s(d['collective_s'])} | {d['bottleneck']} | "
              f"{d['useful_flop_ratio']:.2f} | "
              f"{d['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
