from repro.checkpoint.manager import (AsyncCheckpointer, gc, latest_step,
                                      restore, save, steps)

__all__ = ["AsyncCheckpointer", "gc", "latest_step", "restore", "save",
           "steps"]
