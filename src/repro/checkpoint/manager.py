"""Checkpointing: atomic step directories, async writer thread, elastic
restore (onto a different mesh / sharding), and retention GC.

Layout:  <root>/step_<N>/ arrays.npz + tree.json + COMMIT (marker written
last; a directory without COMMIT is incomplete and ignored by restore).

This container is single-process, so leaves are saved as full host arrays;
on a real multi-host pod each process would write its shards via
``jax.experimental.multihost_utils`` / tensorstore-OCDBT -- the manager API
(save/restore/latest_step/gc) is the stable surface either way, and restore
already re-device_puts onto arbitrary target shardings, which is what makes
elastic rescaling work (see tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return ({f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)},
            treedef)


def save(root: str, step: int, tree: PyTree) -> str:
    """Synchronous atomic save."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, treedef = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"treedef": str(treedef), "step": step,
                   "n_leaves": len(arrays)}, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write(str(time.time()))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def steps(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, "COMMIT")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    s = steps(root)
    return s[-1] if s else None


def restore(root: str, step: Optional[int] = None,
            target: Optional[PyTree] = None,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
    """Restore a checkpoint. ``target`` (a pytree of arrays or
    ShapeDtypeStructs with the same structure) rebuilds the tree; with
    ``shardings`` the leaves are device_put onto them -- the mesh may differ
    from the one that saved (elastic restart)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
    path = os.path.join(root, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"checkpoint {path} is incomplete")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    if target is None:
        raise ValueError("restore requires a target tree (structure donor)")
    treedef = jax.tree_util.tree_structure(target)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    else:
        leaves = [jnp.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def gc(root: str, keep_last: int = 3) -> List[int]:
    """Delete all but the newest ``keep_last`` complete checkpoints."""
    all_steps = steps(root)
    removed = []
    for s in all_steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"))
        removed.append(s)
    return removed


class AsyncCheckpointer:
    """Background-thread writer: ``save`` snapshots the tree to host memory
    synchronously (cheap) and enqueues the disk write. ``wait()`` drains the
    queue; errors surface on the next call."""

    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree = item
            try:
                save(self.root, step, host_tree)
                gc(self.root, self.keep_last)
            except BaseException as e:  # surfaced on next save/wait
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree: PyTree):
        if self._err is not None:
            err, self._err = self._err, None
            raise err
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self):
        self._q.put(None)
        self._q.join()
