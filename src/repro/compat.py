"""Small jax version-compatibility shims.

The repo targets current jax but must degrade gracefully on older
releases (the pinned CI/container toolchain): ``shard_map`` moved out of
``jax.experimental`` and its replication-check kwarg was renamed
(``check_rep`` -> ``check_vma``) along the way.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` wherever this jax provides it, with replication
    checking off (callers here produce replicated outputs by construction,
    e.g. the coreset solve repeated on every device, which the checker
    cannot see through)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
