"""Assigned architecture configs (public literature) + paper experiment
configs. ``get(name)`` -> full ModelConfig; ``get_reduced(name)`` -> smoke
variant of the same family."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "dbrx_132b",
    "granite_moe_3b_a800m",
    "gemma3_27b",
    "qwen2_72b",
    "granite_34b",
    "llama3_8b",
    "qwen2_vl_2b",
    "mamba2_370m",
    "musicgen_large",
    "recurrentgemma_2b",
]


def _mod(name: str):
    return importlib.import_module(f"repro.configs.{name.replace('-', '_')}")


def get(name: str) -> ModelConfig:
    return _mod(name).config().validate()


def get_reduced(name: str) -> ModelConfig:
    return _mod(name).reduced().validate()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}
