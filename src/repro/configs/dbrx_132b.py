"""DBRX-132B: 40L fine-grained MoE, 16 experts top-4, GQA kv=8.
[hf:databricks/dbrx-base; unverified]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
    pattern=("attn",),
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="dbrx-132b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=512, n_experts=4,
        top_k=2)
