"""Gemma-3-27B: 62L dense, 5:1 local:global attention (1024-token sliding
window), GQA kv=16, QK-norm, sandwich norms, 262k vocab, 128k context.
[hf:google/gemma-3-1b-pt (family); unverified]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=("local",) * 5 + ("attn",),
    window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    qk_norm=True,
    post_norms=True,
    emb_scale_by_sqrt_dim=True,
    tie_embeddings=True,
    mlp_act="gelu",
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="gemma3-reduced", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, window=16)
