"""Granite-34B-Code: 88L dense llama-arch with MQA (kv=1).
[arXiv:2405.04324; hf]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=10_000.0,
    tie_embeddings=True,
    mlp_act="gelu",
    mlp_gated=False,          # GPT-BigCode-style 2-matrix FFN
    pattern=("attn",),
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="granite-34b-reduced", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512)
