"""Granite-3.0-3B-A800M MoE: 32L, 40 experts top-8, fine-grained d_ff=512,
GQA kv=8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
    pattern=("attn",),
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="granite-moe-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=515, n_experts=8,
        top_k=2)
