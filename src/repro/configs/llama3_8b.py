"""Llama-3-8B: 32L dense, GQA kv=8, 128k vocab. [arXiv:2407.21783;
unverified]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    pattern=("attn",),
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="llama3-8b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512)
