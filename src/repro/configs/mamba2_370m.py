"""Mamba2-370M: 48L attention-free SSD (state-space duality), state N=128,
headdim 64, expand 2 (d_inner 2048 -> 32 heads). [arXiv:2405.21060;
unverified]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssd",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="mamba2-reduced", n_layers=3, d_model=64, vocab_size=512,
        ssm_state=16, ssm_headdim=16, ssm_chunk=16)
