"""MusicGen-large backbone: 48L decoder-only over EnCodec audio tokens
(2048-entry codebook), MHA (kv=32). The EnCodec tokenizer/delay-pattern
frontend is a STUB per the brief: ``input_specs()`` supplies precomputed
frame token ids. Positions use RoPE (TPU-native adaptation of the original
sinusoidal embeddings; noted in DESIGN.md). [arXiv:2306.05284; hf]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_act="gelu",
    mlp_gated=False,          # classic transformer FFN
    rope_theta=10_000.0,
    pattern=("attn",),
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="musicgen-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
