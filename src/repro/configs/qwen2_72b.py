"""Qwen2-72B: 80L dense, GQA kv=8, QKV bias. [arXiv:2407.10671; hf]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pattern=("attn",),
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="qwen2-72b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512)
