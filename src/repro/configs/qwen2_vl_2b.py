"""Qwen2-VL-2B backbone: 28L, GQA kv=2, M-RoPE (t/h/w sections 16/24/24 of
the 64 rotary frequency slots). The vision frontend is a STUB per the brief:
``input_specs()`` supplies token ids plus 3-axis M-RoPE position ids (for
text-only smoke runs all three axes carry identical ids).
[arXiv:2409.12191; hf]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
    pattern=("attn",),
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="qwen2-vl-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        mrope_sections=(2, 3, 3))
