"""RecurrentGemma-2B: 26L Griffin hybrid -- repeating (RG-LRU, RG-LRU,
local-attention) pattern (2:1), 2048-token window, MQA (kv=1), lru_width
2560. [arXiv:2402.19427; hf]"""
import dataclasses

from repro.models.config import ModelConfig

_BASE = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=10_000.0,
    emb_scale_by_sqrt_dim=True,
    tie_embeddings=True,
    mlp_act="gelu",
)


def config() -> ModelConfig:
    return _BASE


def reduced() -> ModelConfig:
    return dataclasses.replace(
        _BASE, name="recurrentgemma-reduced", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
        window=16, lru_width=64)
