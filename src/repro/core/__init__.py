"""The paper's contribution: distributed coreset construction and clustering
on general topologies (Balcan-Ehrlich-Liang 2013)."""

from repro.core import backend, baselines, clustering, comm, coreset
from repro.core import distributed, message_passing, partition, topology
from repro.core.backend import (ClusteringBackend, available_backends,
                                get_backend, query_assignments,
                                register_backend, use_backend)
from repro.core.clustering import (cost, kmeans_pp_init, lloyd, lloyd_stats,
                                   min_dist_argmin, solve)
from repro.core.comm import CommLedger
from repro.core.coreset import (Coreset, DistributedCoreset, build_coreset,
                                distributed_coreset, merge_coresets)
from repro.core.distributed import (ClusteringResult, distributed_kmeans,
                                    distributed_kmeans_tree,
                                    spmd_distributed_kmeans)
from repro.core.topology import (Graph, SpanningTree, bfs_spanning_tree,
                                 diameter, erdos_renyi, grid, preferential)

__all__ = [
    "backend", "baselines", "clustering", "comm", "coreset", "distributed",
    "message_passing", "partition", "topology",
    "ClusteringBackend", "available_backends", "get_backend",
    "query_assignments", "register_backend", "use_backend",
    "cost", "kmeans_pp_init", "lloyd", "lloyd_stats", "min_dist_argmin",
    "solve",
    "CommLedger", "Coreset", "DistributedCoreset", "build_coreset",
    "distributed_coreset", "merge_coresets",
    "ClusteringResult", "distributed_kmeans",
    "distributed_kmeans_tree", "spmd_distributed_kmeans",
    "Graph", "SpanningTree", "bfs_spanning_tree", "diameter", "erdos_renyi",
    "grid", "preferential",
]
