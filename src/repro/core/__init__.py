"""The paper's contribution: distributed coreset construction and clustering
on general topologies (Balcan-Ehrlich-Liang 2013)."""

from repro.core import backend, baselines, clustering, comm, coreset
from repro.core import distributed, message_passing, partition, strategy
from repro.core import topology
from repro.core.backend import (ClusteringBackend, available_backends,
                                get_backend, query_assignments,
                                register_backend, use_backend)
from repro.core.clustering import (cost, kmeans_pp_init, lloyd,
                                   lloyd_converged, lloyd_stats,
                                   min_dist_argmin, solve)
from repro.core.comm import CommLedger
from repro.core.coreset import (Coreset, DistributedCoreset, StagedDetail,
                                build_coreset, distributed_coreset,
                                merge_coresets, staged_distributed_coreset)
from repro.core.distributed import (ClusteringResult, ExecDetail,
                                    distributed_kmeans,
                                    distributed_kmeans_tree,
                                    graph_distributed_kmeans,
                                    spmd_distributed_kmeans)
from repro.core.strategy import (CoresetStrategy, available_strategies,
                                 get_strategy, register_strategy)
from repro.core.message_passing import (ExecResult, GossipSchedule,
                                        TreeSchedule, collective_hops,
                                        flood_exec, neighbor_rounds_gather,
                                        neighbor_rounds_sum, torus_mesh_shape,
                                        torus_rounds_gather, torus_rounds_sum,
                                        tree_broadcast_exec, tree_gather_exec,
                                        tree_scatter_exec, tree_up_sum_exec)
from repro.core.topology import (Graph, SpanningTree, bfs_spanning_tree,
                                 diameter, erdos_renyi, grid, heterogeneous,
                                 mst_spanning_tree, preferential, ring,
                                 spanning_tree, star, torus, wan_clusters)

__all__ = [
    "backend", "baselines", "clustering", "comm", "coreset", "distributed",
    "message_passing", "partition", "strategy", "topology",
    "CoresetStrategy", "available_strategies", "get_strategy",
    "register_strategy",
    "ClusteringBackend", "available_backends", "get_backend",
    "query_assignments", "register_backend", "use_backend",
    "cost", "kmeans_pp_init", "lloyd", "lloyd_converged", "lloyd_stats",
    "min_dist_argmin", "solve",
    "CommLedger", "Coreset", "DistributedCoreset", "StagedDetail",
    "build_coreset", "distributed_coreset", "merge_coresets",
    "staged_distributed_coreset",
    "ClusteringResult", "ExecDetail", "distributed_kmeans",
    "distributed_kmeans_tree", "graph_distributed_kmeans",
    "spmd_distributed_kmeans",
    "ExecResult", "GossipSchedule", "TreeSchedule", "collective_hops",
    "flood_exec", "neighbor_rounds_gather", "neighbor_rounds_sum",
    "torus_mesh_shape", "torus_rounds_gather", "torus_rounds_sum",
    "tree_broadcast_exec", "tree_gather_exec", "tree_scatter_exec",
    "tree_up_sum_exec",
    "Graph", "SpanningTree", "bfs_spanning_tree", "diameter", "erdos_renyi",
    "grid", "heterogeneous", "mst_spanning_tree", "preferential", "ring",
    "spanning_tree", "star", "torus", "wan_clusters",
]
