"""The paper's contribution: distributed coreset construction and clustering
on general topologies (Balcan-Ehrlich-Liang 2013)."""

from repro.core import baselines, clustering, comm, coreset, distributed
from repro.core import message_passing, partition, topology
from repro.core.clustering import (cost, kmeans_pp_init, lloyd,
                                   min_dist_argmin, solve)
from repro.core.comm import CommLedger
from repro.core.coreset import (Coreset, DistributedCoreset, build_coreset,
                                distributed_coreset)
from repro.core.distributed import (ClusteringResult, distributed_kmeans,
                                    distributed_kmeans_tree,
                                    spmd_distributed_kmeans)
from repro.core.topology import (Graph, SpanningTree, bfs_spanning_tree,
                                 diameter, erdos_renyi, grid, preferential)

__all__ = [
    "baselines", "clustering", "comm", "coreset", "distributed",
    "message_passing", "partition", "topology",
    "cost", "kmeans_pp_init", "lloyd", "min_dist_argmin", "solve",
    "CommLedger", "Coreset", "DistributedCoreset", "build_coreset",
    "distributed_coreset", "ClusteringResult", "distributed_kmeans",
    "distributed_kmeans_tree", "spmd_distributed_kmeans",
    "Graph", "SpanningTree", "bfs_spanning_tree", "diameter", "erdos_renyi",
    "grid", "preferential",
]
