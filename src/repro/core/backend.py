"""Unified clustering-backend dispatch layer (DESIGN.md Sec. 8).

Every hot path of the pipeline -- Algorithm 1's local solves, D^2 seeding,
sensitivity computation, and the final coreset solve of Algorithm 2, for
*every* registered objective (:mod:`repro.core.objective`) -- reduces to
the same three primitive ops over a (possibly weighted) point set:

* ``min_dist_argmin(points, centers)``
    ``(n, d), (k, d) -> (min_d2 (n,) f32, argmin (n,) i32)``
* ``lloyd_stats(points, centers, weights)``
    ``(n, d), (k, d), (n,) -> (sums (k, d) f32, counts (k,) f32, cost () f32)``
  where ``sums[c] = sum_{p: argmin(p)=c} w_p p``, ``counts[c] = sum w_p``
  and ``cost = sum_p w_p min_d2(p)`` -- one fused E+M statistics pass
  (the k-means Lloyd step).
* ``weiszfeld_stats(points, centers, weights)``
    ``(n, d), (k, d), (n,) -> (nums (k, d) f32, denoms (k,) f32, cost () f32)``
  where, with ``dist(p) = sqrt(d2(p) + eta^2)`` the smoothed exact-form
  distance to the assigned center,
  ``nums[c] = sum_{p: argmin(p)=c} max(w_p, 0) p / dist(p)``,
  ``denoms[c] = sum max(w_p, 0) / dist(p)`` and
  ``cost = sum_p w_p sqrt(d2(p))`` -- one fused assign+Weiszfeld
  statistics pass (the k-median refinement step; DESIGN.md Sec. 10).

A :class:`ClusteringBackend` supplies all three; the registry maps names to
singleton instances:

* ``"jnp"``         -- dense XLA formulation, materializes the (n, k)
                       distance block (fastest on CPU for small n*k).
* ``"jnp_chunked"`` -- ``lax.map`` over fixed-size point chunks: bounded
                       memory for large n, same numerics as ``"jnp"``.
* ``"pallas"``      -- the fused TPU kernels in :mod:`repro.kernels`
                       (flash-style online argmin + one-pass statistics;
                       interpret mode on CPU via ``ops._auto_interpret``).

Selection precedence: explicit argument (name or instance) > ambient
default set by :func:`use_backend` > auto-detection (``"pallas"`` on TPU,
``"jnp"`` elsewhere).

All accumulation is float32 regardless of input dtype (the kernels' dtype
policy); callers cast results back as needed.

jit interaction: backend choice must be a *static* trace property, so the
public entry points in :mod:`repro.core.clustering` etc. resolve the
ambient default to a concrete registry name *outside* their jitted inner
functions and pass the name through ``static_argnames``. Never call
:func:`get_backend` with ``None`` from inside a jitted function -- the
ambient default would be baked into a stale cache entry.
"""
from __future__ import annotations

import functools
import threading
from typing import Dict, Optional, Protocol, Tuple, Union, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import objective as objective_mod
from repro.kernels.ref import CENTER_SENTINEL as _CENTER_SENTINEL

Array = jax.Array

_EPS = 1e-12


@runtime_checkable
class ClusteringBackend(Protocol):
    """The primitive ops every numerical path dispatches through.

    ``min_dist_argmin_batched`` is the *stacked-tenant* sibling of
    ``min_dist_argmin``: ``(T, m, d), (T, k, d) -> ((T, m) f32, (T, m)
    i32)`` where tenant t's queries reduce over tenant t's centers only --
    the multi-tenant serving tier fuses T tenants' query traffic into one
    such dispatch (DESIGN.md Sec. 13). Ragged center sets arrive sentinel-
    masked (see :func:`query_assignments_batched`)."""

    name: str

    def min_dist_argmin(self, points: Array, centers: Array
                        ) -> Tuple[Array, Array]:
        ...

    def min_dist_argmin_batched(self, points: Array, centers: Array
                                ) -> Tuple[Array, Array]:
        ...

    def lloyd_stats(self, points: Array, centers: Array,
                    weights: Optional[Array] = None
                    ) -> Tuple[Array, Array, Array]:
        ...

    def weiszfeld_stats(self, points: Array, centers: Array,
                        weights: Optional[Array] = None
                        ) -> Tuple[Array, Array, Array]:
        ...


BackendLike = Union[str, ClusteringBackend, None]


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------

def _dense_min_dist_argmin(points: Array, centers: Array
                           ) -> Tuple[Array, Array]:
    p = points.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    p2 = jnp.sum(p * p, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = jnp.maximum(p2 + c2[None, :] - 2.0 * (p @ c.T), 0.0)
    return jnp.min(d2, axis=-1), jnp.argmin(d2, axis=-1).astype(jnp.int32)


def _dense_lloyd_stats(points: Array, centers: Array,
                       weights: Optional[Array] = None
                       ) -> Tuple[Array, Array, Array]:
    p = points.astype(jnp.float32)
    w = (jnp.ones((p.shape[0],), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    min_d2, assign = _dense_min_dist_argmin(points, centers)
    k = centers.shape[0]
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
    sums = oh.T @ p
    counts = jnp.sum(oh, axis=0)
    cost = jnp.sum(w * min_d2)
    return sums, counts, cost


# Batched tenant axis via vmap: on every platform this lowers to one
# batched dot_general, and each tenant slice runs the *same* arithmetic as
# a standalone _dense_min_dist_argmin call, so batched results are
# bit-identical to the per-tenant serial loop (asserted in
# tests/test_serve_cluster.py).
_dense_min_dist_argmin_batched = jax.vmap(_dense_min_dist_argmin)


def _dense_weiszfeld_stats(points: Array, centers: Array,
                           weights: Optional[Array] = None
                           ) -> Tuple[Array, Array, Array]:
    # the normative reduction (exact-form assigned distance + eta-smoothed
    # inverse, DESIGN.md Sec. 10) is shared with the ops.py fallback and
    # the oracle; only the argmin source differs per backend
    from repro.kernels.ref import weiszfeld_reduce

    _, assign = _dense_min_dist_argmin(points, centers)
    return weiszfeld_reduce(points, centers, weights, assign)


class JnpBackend:
    """Dense XLA-fused matmul formulation d^2 = |p|^2 + |c|^2 - 2 p.c."""

    name = "jnp"

    def min_dist_argmin(self, points, centers):
        return _dense_min_dist_argmin(points, centers)

    def min_dist_argmin_batched(self, points, centers):
        return _dense_min_dist_argmin_batched(points, centers)

    def lloyd_stats(self, points, centers, weights=None):
        return _dense_lloyd_stats(points, centers, weights)

    def weiszfeld_stats(self, points, centers, weights=None):
        return _dense_weiszfeld_stats(points, centers, weights)


class JnpChunkedBackend:
    """Bounded-memory variant: ``lax.map`` over ``chunk``-point blocks, so
    the materialized distance block is (chunk, k) instead of (n, k). Padded
    tail points carry weight 0 and never contribute."""

    def __init__(self, chunk: int = 65536, name: str = "jnp_chunked"):
        self.chunk = int(chunk)
        self.name = name

    def _blocks(self, points: Array, weights: Array
                ) -> Tuple[Array, Array]:
        n, d = points.shape
        pad = (-n) % self.chunk
        pts = jnp.pad(points, ((0, pad), (0, 0)))
        w = jnp.pad(weights, (0, pad))
        return (pts.reshape(-1, self.chunk, d),
                w.reshape(-1, self.chunk))

    def min_dist_argmin(self, points, centers):
        n = points.shape[0]
        if n <= self.chunk:
            return _dense_min_dist_argmin(points, centers)
        pts, _ = self._blocks(points, jnp.zeros((n,), jnp.float32))
        md, am = jax.lax.map(
            lambda blk: _dense_min_dist_argmin(blk, centers), pts)
        return md.reshape(-1)[:n], am.reshape(-1)[:n]

    def min_dist_argmin_batched(self, points, centers):
        T, m, d = points.shape
        if T * m <= self.chunk:
            return _dense_min_dist_argmin_batched(points, centers)
        # lax.map over fixed-size tenant blocks: the materialized distance
        # block is (blk, m, k) instead of (T, m, k). Padding tenants carry
        # sentinel centers (never win) and are sliced off.
        blk = max(1, self.chunk // max(m, 1))
        pad = (-T) % blk
        pts = jnp.pad(points, ((0, pad), (0, 0), (0, 0)))
        ctr = jnp.pad(centers, ((0, pad), (0, 0), (0, 0)),
                      constant_values=_CENTER_SENTINEL)
        k = centers.shape[1]
        md, am = jax.lax.map(
            lambda args: _dense_min_dist_argmin_batched(args[0], args[1]),
            (pts.reshape(-1, blk, m, d), ctr.reshape(-1, blk, k, d)))
        return md.reshape(-1, m)[:T], am.reshape(-1, m)[:T]

    def lloyd_stats(self, points, centers, weights=None):
        n = points.shape[0]
        w = (jnp.ones((n,), jnp.float32) if weights is None
             else weights.astype(jnp.float32))
        if n <= self.chunk:
            return _dense_lloyd_stats(points, centers, w)
        pts, ws = self._blocks(points, w)
        sums, counts, cost = jax.lax.map(
            lambda args: _dense_lloyd_stats(args[0], centers, args[1]),
            (pts, ws))
        return sums.sum(axis=0), counts.sum(axis=0), cost.sum()

    def weiszfeld_stats(self, points, centers, weights=None):
        n = points.shape[0]
        w = (jnp.ones((n,), jnp.float32) if weights is None
             else weights.astype(jnp.float32))
        if n <= self.chunk:
            return _dense_weiszfeld_stats(points, centers, w)
        pts, ws = self._blocks(points, w)
        nums, denoms, cost = jax.lax.map(
            lambda args: _dense_weiszfeld_stats(args[0], centers, args[1]),
            (pts, ws))
        return nums.sum(axis=0), denoms.sum(axis=0), cost.sum()


class PallasBackend:
    """Fused Pallas TPU kernels (interpret mode on CPU). Thin delegation to
    the safe padded wrappers in :mod:`repro.kernels.ops`."""

    def __init__(self, block_n: int = 256, block_k: int = 256,
                 interpret: Optional[bool] = None, name: str = "pallas"):
        self.block_n = block_n
        self.block_k = block_k
        self.interpret = interpret
        self.name = name

    def min_dist_argmin(self, points, centers):
        from repro.kernels import ops as kops

        return kops.min_dist_argmin(points, centers, block_n=self.block_n,
                                    block_k=self.block_k,
                                    interpret=self.interpret)

    def min_dist_argmin_batched(self, points, centers):
        from repro.kernels import ops as kops

        return kops.min_dist_argmin_batched(points, centers,
                                            block_n=self.block_n,
                                            block_k=self.block_k,
                                            interpret=self.interpret)

    def lloyd_stats(self, points, centers, weights=None):
        from repro.kernels import ops as kops

        return kops.lloyd_stats(points, centers, weights,
                                block_n=self.block_n,
                                interpret=self.interpret)

    def weiszfeld_stats(self, points, centers, weights=None):
        from repro.kernels import ops as kops

        return kops.weiszfeld_stats(points, centers, weights,
                                    block_n=self.block_n,
                                    interpret=self.interpret)


# ---------------------------------------------------------------------------
# registry + ambient default
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ClusteringBackend] = {}
_local = threading.local()


def register_backend(backend: ClusteringBackend, name: Optional[str] = None
                     ) -> ClusteringBackend:
    """Add a backend instance to the registry (future GPU/Triton or sparse
    backends are one ``register_backend`` call).

    Overriding an existing name is allowed here (explicitly) but note that
    jitted entry points cache compiled traces keyed on the *name*: traces
    already compiled against the old instance are not invalidated."""
    _REGISTRY[name or backend.name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(JnpBackend())
register_backend(JnpChunkedBackend())
register_backend(PallasBackend())


def _auto_name() -> str:
    """Pallas on TPU (the kernels' target); dense jnp elsewhere (interpret
    mode is orders of magnitude slower than XLA on CPU, so it is opt-in)."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def default_backend_name() -> str:
    name = getattr(_local, "default", None)
    return name if name is not None else _auto_name()


def resolve_name(backend: BackendLike) -> str:
    """Resolve a selection to a concrete registry name (for use as a static
    jit argument). Must be called *outside* jit for ``None`` to track the
    ambient default correctly."""
    if backend is None:
        return default_backend_name()
    if isinstance(backend, str):
        if backend not in _REGISTRY:
            raise KeyError(
                f"unknown clustering backend {backend!r}; "
                f"available: {available_backends()}")
        return backend
    name = getattr(backend, "name", None)
    if not name:
        raise TypeError(f"backend must be a name or ClusteringBackend, got "
                        f"{type(backend).__name__}")
    existing = _REGISTRY.get(name)
    if existing is None:
        register_backend(backend, name)
    elif existing is not backend:
        # never silently shadow: jit caches key on the name, so a second
        # instance under the same name would hit the first instance's
        # compiled traces and be silently ignored.
        raise ValueError(
            f"a different backend is already registered as {name!r}; give "
            f"this instance a unique .name or call register_backend() "
            f"explicitly to override")
    return name


def get_backend(backend: BackendLike = None) -> ClusteringBackend:
    """Resolve a selection to a backend instance."""
    if backend is not None and not isinstance(backend, str):
        resolve_name(backend)  # validate + register
        return backend
    return _REGISTRY[resolve_name(backend)]


def query_assignments(points: Array, centers: Array,
                      objective: objective_mod.ObjectiveLike = "kmeans",
                      backend: BackendLike = None) -> Tuple[Array, Array]:
    """Batched cluster-query entry point: nearest center and distance per
    query point, ``(n, d), (k, d) -> (assign (n,) i32, dist (n,) f32)``.

    This is the serving hot path of :mod:`repro.stream.service` -- one
    fused ``min_dist_argmin`` pass through the registry (the Pallas
    ``distance_argmin`` kernel on TPU), with the distance reported in the
    objective's metric (``dist^z``: squared for z=2, euclidean for z=1;
    trimmed objectives report the plain z=2 metric -- trimming is a
    training-time notion, queries always get their true nearest center).
    """
    return _query_assignments(
        points, centers, objective=objective_mod.resolve_name(objective),
        backend=resolve_name(backend))


@functools.partial(jax.jit, static_argnames=("objective", "backend"))
def _query_assignments(points, centers, objective, backend):
    d2, assign = _REGISTRY[backend].min_dist_argmin(points, centers)
    dist = objective_mod.get_objective(objective).clamped_cost(d2)
    return assign, dist


def query_assignments_batched(queries: Array, centers: Array,
                              center_mask: Optional[Array] = None,
                              objective: objective_mod.ObjectiveLike = "kmeans",
                              backend: BackendLike = None
                              ) -> Tuple[Array, Array]:
    """Stacked-tenant cluster-query entry point: ``(T, m, d), (T, k, d)[,
    (T, k) bool] -> (assign (T, m) i32, dist (T, m) f32)`` -- T tenants'
    nearest-center queries fused into ONE device dispatch (one Pallas
    ``distance_argmin_batched`` launch on TPU, one batched dot_general on
    the jnp backends). This is the multi-tenant serving hot path of
    :mod:`repro.serve.cluster` (DESIGN.md Sec. 13).

    **Masking contract**: tenants with ragged center counts are stacked
    into the common ``(T, k, d)`` buffer and described by ``center_mask``
    (True = live row). Masked-out rows are substituted with the
    ``CENTER_SENTINEL`` coordinate *here*, uniformly for every backend, so
    they can never win an argmin and all backends see identical operands
    -- batched results are bit-identical to a per-tenant serial loop over
    the same stacked buffers on the jnp backends (and ~1e-7 on pallas,
    whose padded-k tiling differs). Padded *query* rows are the caller's
    to slice off. ``dist`` is the objective's metric ``dist^z`` (squared
    for z=2 -- including trimmed variants -- euclidean for z=1).
    """
    return _query_assignments_batched(
        queries, centers, center_mask,
        objective=objective_mod.resolve_name(objective),
        backend=resolve_name(backend))


@functools.partial(jax.jit, static_argnames=("objective", "backend"))
def _query_assignments_batched(queries, centers, center_mask, objective,
                               backend):
    if center_mask is not None:
        centers = jnp.where(center_mask[..., None], centers,
                            jnp.asarray(_CENTER_SENTINEL, centers.dtype))
    d2, assign = _REGISTRY[backend].min_dist_argmin_batched(queries, centers)
    dist = objective_mod.get_objective(objective).clamped_cost(d2)
    return assign, dist


_UNSET = object()


class use_backend:
    """Set the ambient default backend.

    Works both as a plain call (``use_backend("pallas")`` -- sticky) and as
    a context manager (restores the previous default on exit)::

        with use_backend("jnp_chunked"):
            lloyd(points, centers)          # runs chunked

    The restorable mutation lives in ``__enter__``, not ``__init__``: each
    entry captures the default *at entry time* and restores exactly that on
    exit, so a stored instance can be (re-)entered later -- even nested
    inside other contexts -- without restoring a stale snapshot. The
    ``__init__`` sticky set (the plain-call contract) records the
    pre-construction default; the first entry immediately following
    construction consumes it, so ``with use_backend(...)`` restores the
    default from *before* the expression ran. ``__exit__`` without a
    matching ``__enter__`` is a no-op.
    """

    def __init__(self, backend: BackendLike):
        self._name = resolve_name(backend)
        # plain-call stickiness: constructing the object sets the ambient
        # default; _pending remembers what it replaced for the first enter.
        self._pending = getattr(_local, "default", None)
        self._stack = []
        _local.default = self._name

    def __enter__(self) -> ClusteringBackend:
        cur = getattr(_local, "default", None)
        if self._pending is not _UNSET and cur == self._name:
            # entering right after construction: the __init__ mutation was
            # this entry's set; restore the pre-construction default.
            prev = self._pending
        else:
            # stored instance entered later (ambient changed since
            # construction): capture the current default, not the stale one.
            prev = cur
        self._pending = _UNSET
        self._stack.append(prev)
        _local.default = self._name
        return get_backend(self._name)

    def __exit__(self, *exc) -> bool:
        if self._stack:
            _local.default = self._stack.pop()
        return False
