"""Baselines the paper compares against (Sec. 1, Sec. 5).

* :func:`combine` -- COMBINE: each site builds a *local* eps-coreset of its
  own data and the union is shipped. Correct, but the global summary is a
  factor n larger than Algorithm 1's for the same accuracy.

* :func:`zhang_tree` -- Zhang et al. [26]: on a rooted (spanning) tree, every
  node builds a coreset of (its own data) union (its children's coresets) and
  forwards it to its parent -- "coreset of coresets". Error compounds over the
  tree height h, so matching a target accuracy needs size ~ (h/eps)^2
  (k-median) / (h/eps)^4 (k-means); at a fixed communication budget the
  quality is correspondingly worse, which is what the experiments measure.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core.backend import BackendLike
from repro.core.comm import CommLedger, flood_cost
from repro.core.objective import ObjectiveLike
from repro.core.coreset import Coreset, build_coreset
from repro.core.topology import Graph, SpanningTree

Array = jax.Array


def combine(
    key: Array,
    site_points: Array,   # (n_sites, M, d)
    site_mask: Array,     # (n_sites, M)
    k: int,
    t_total: int,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 5,
    backend: BackendLike = None,
) -> Coreset:
    """Union of per-site local coresets, each of sample size t_total // n.

    Total summary size: n * (t_total//n + k) -- the O(n)-factor blowup that
    Algorithm 1 removes.
    """
    n_sites = site_points.shape[0]
    s = max(t_total // n_sites, 1)
    backend = backend_mod.resolve_name(backend)
    keys = jax.random.split(key, n_sites)
    w = site_mask.astype(site_points.dtype)

    def one(ki, pts, wi):
        cs = build_coreset(ki, pts, k, s, weights=wi, objective=objective,
                           lloyd_iters=lloyd_iters, backend=backend)
        return cs.points, cs.weights

    pts, ws = jax.vmap(one)(keys, site_points, w)
    d = pts.shape[-1]
    return Coreset(points=pts.reshape(-1, d), weights=ws.reshape(-1))


def combine_ledger(g: Graph, n_sites: int, k: int, t_total: int, d: int
                   ) -> CommLedger:
    s = max(t_total // n_sites, 1)
    return flood_cost(g, n_messages=n_sites, unit_points=float(s + k), dim=d)


def _pad_bucket(n: int, bucket: int = 256) -> int:
    return int(np.ceil(max(n, 1) / bucket) * bucket)


def zhang_tree(
    key: Array,
    site_points: np.ndarray,   # (n_sites, M, d) padded numpy
    site_mask: np.ndarray,     # (n_sites, M)
    tree: SpanningTree,
    k: int,
    s: int,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 5,
    backend: BackendLike = None,
) -> Tuple[Coreset, CommLedger]:
    """Coreset-of-coresets, leaves to root. Host-orchestrated (the per-node
    inputs are ragged); each node's construction is the jitted
    :func:`build_coreset` on a bucket-padded weighted instance.

    Communication: every non-root node sends its (s + k)-point coreset one
    edge up => (n - 1) * (s + k) points total.
    """
    n_sites, M, d = site_points.shape
    backend = backend_mod.resolve_name(backend)
    children = tree.children()
    store: List[Tuple[np.ndarray, np.ndarray]] = [None] * n_sites  # type: ignore
    keys = jax.random.split(key, n_sites)

    for v in tree.bottom_up_order():
        own_pts = site_points[v][site_mask[v]]
        own_w = np.ones(len(own_pts), dtype=site_points.dtype)
        parts_p = [own_pts] + [store[c][0] for c in children[v]]
        parts_w = [own_w] + [store[c][1] for c in children[v]]
        pts = np.concatenate(parts_p, axis=0)
        ws = np.concatenate(parts_w, axis=0)
        # bucket-pad for a bounded number of jit shapes
        pad = _pad_bucket(len(pts)) - len(pts)
        pts = np.pad(pts, ((0, pad), (0, 0)))
        ws = np.pad(ws, (0, pad))
        cs = build_coreset(keys[v], jnp.asarray(pts), k, s,
                           weights=jnp.asarray(ws), objective=objective,
                           lloyd_iters=lloyd_iters, backend=backend)
        store[v] = (np.asarray(cs.points), np.asarray(cs.weights))

    root_pts, root_w = store[tree.root]
    ledger = CommLedger(points=float((n_sites - 1) * (s + k)),
                        messages=float(n_sites - 1), dim=d)
    return Coreset(points=jnp.asarray(root_pts),
                   weights=jnp.asarray(root_w)), ledger
