"""Centralized *weighted* clustering primitives (k-means / k-median).

Pure-JAX implementations used both by the paper's algorithms (local constant
approximation solves on each site, Algorithm 1 Round 1) and by the final
clustering of the global coreset (Algorithm 2 Round 2). Every function supports
per-point weights -- the coreset is a *weighted* instance, possibly with
negative center weights -- and is jit-compatible with static ``k`` and
iteration counts.

The distance hot loop can be routed through the Pallas fused kernel
(``repro.kernels``) with ``backend="pallas"``; the default ``"jnp"`` path is
the XLA-fused matmul formulation ``d^2(p,c) = |p|^2 + |c|^2 - 2 p.c``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_TINY = 1e-30
_EPS = 1e-12


def pairwise_sq_dists(points: Array, centers: Array) -> Array:
    """Squared euclidean distances. points (n,d), centers (k,d) -> (n,k)."""
    p2 = jnp.sum(points * points, axis=-1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=-1)
    d2 = p2 + c2[None, :] - 2.0 * (points @ centers.T)
    return jnp.maximum(d2, 0.0)


def min_dist_argmin(
    points: Array,
    centers: Array,
    chunk: Optional[int] = None,
    backend: str = "jnp",
) -> Tuple[Array, Array]:
    """Min squared distance and argmin center per point.

    ``chunk`` bounds the materialized (chunk, k) distance block for large n.
    ``backend="pallas"`` routes through the fused TPU kernel (see
    ``repro.kernels.ops``).
    """
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.min_dist_argmin(points, centers)
    n = points.shape[0]
    if chunk is None or n <= chunk:
        d2 = pairwise_sq_dists(points, centers)
        return jnp.min(d2, axis=-1), jnp.argmin(d2, axis=-1).astype(jnp.int32)
    pad = (-n) % chunk
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    pts = pts.reshape(-1, chunk, points.shape[1])

    def one(block):
        d2 = pairwise_sq_dists(block, centers)
        return jnp.min(d2, axis=-1), jnp.argmin(d2, axis=-1).astype(jnp.int32)

    md, am = jax.lax.map(one, pts)
    return md.reshape(-1)[:n], am.reshape(-1)[:n]


def cost(
    points: Array,
    centers: Array,
    weights: Optional[Array] = None,
    objective: str = "kmeans",
    chunk: Optional[int] = None,
) -> Array:
    """Weighted clustering cost: sum_p w_p d(p, X)^2 (k-means) or ^1 (k-median)."""
    d2, _ = min_dist_argmin(points, centers, chunk=chunk)
    per_point = d2 if objective == "kmeans" else jnp.sqrt(d2)
    if weights is not None:
        per_point = per_point * weights
    return jnp.sum(per_point)


def point_costs(
    points: Array,
    centers: Array,
    objective: str = "kmeans",
    chunk: Optional[int] = None,
) -> Tuple[Array, Array]:
    """Per-point cost to the nearest center and the assignment (n,), (n,)."""
    d2, assign = min_dist_argmin(points, centers, chunk=chunk)
    c = d2 if objective == "kmeans" else jnp.sqrt(d2)
    return c, assign


@functools.partial(jax.jit, static_argnames=("k", "objective"))
def kmeans_pp_init(
    key: Array,
    points: Array,
    k: int,
    weights: Optional[Array] = None,
    objective: str = "kmeans",
) -> Array:
    """k-means++ (D^2) / k-median++ (D^1) seeding with optional weights.

    Weight-0 points (padding) are never selected: the categorical logits are
    ``log(w * D^power)`` which is -inf for them.
    """
    n, d = points.shape
    w = jnp.ones((n,), points.dtype) if weights is None else weights
    w = jnp.maximum(w, 0.0)
    power = 1.0 if objective == "kmedian" else 2.0

    key, k0 = jax.random.split(key)
    first = jax.random.categorical(k0, jnp.log(w + _TINY))
    centers = jnp.zeros((k, d), points.dtype).at[0].set(points[first])
    d2 = jnp.sum((points - points[first]) ** 2, axis=-1)
    mind = d2 if power == 2.0 else jnp.sqrt(jnp.maximum(d2, 0.0))

    def body(i, carry):
        centers, mind, key = carry
        key, ki = jax.random.split(key)
        logits = jnp.log(w * mind + _TINY)
        idx = jax.random.categorical(ki, logits)
        c = points[idx]
        centers = centers.at[i].set(c)
        d2 = jnp.sum((points - c) ** 2, axis=-1)
        dnew = d2 if power == 2.0 else jnp.sqrt(jnp.maximum(d2, 0.0))
        mind = jnp.minimum(mind, dnew)
        return centers, mind, key

    centers, _, _ = jax.lax.fori_loop(1, k, body, (centers, mind, key))
    return centers


def _kmeans_update(points, weights, centers, k):
    """One weighted Lloyd step for the k-means objective."""
    d2, assign = min_dist_argmin(points, centers)
    oh = jax.nn.one_hot(assign, k, dtype=points.dtype)
    ww = oh * weights[:, None]
    sums = ww.T @ points                       # (k, d)
    counts = jnp.sum(ww, axis=0)               # (k,)
    new = sums / jnp.where(counts > _EPS, counts, 1.0)[:, None]
    new = jnp.where((counts > _EPS)[:, None], new, centers)
    c = jnp.sum(weights * d2)
    return new, c


def _kmedian_update(points, weights, centers, k, weiszfeld_iters=4):
    """One weighted alternating step for k-median: assign + per-cluster
    Weiszfeld geometric-median refinement."""
    d2, assign = min_dist_argmin(points, centers)
    oh = jax.nn.one_hot(assign, k, dtype=points.dtype)
    memb = oh * jnp.maximum(weights, 0.0)[:, None]   # (n, k)

    def wbody(_, y):
        # distance of every point to its cluster's current median estimate
        dist = jnp.sqrt(
            jnp.maximum(pairwise_sq_dists(points, y), _EPS)
        )                                           # (n, k)
        inv = memb / dist                           # (n, k)
        denom = jnp.sum(inv, axis=0)                # (k,)
        num = inv.T @ points                        # (k, d)
        ynew = num / jnp.where(denom > _EPS, denom, 1.0)[:, None]
        return jnp.where((denom > _EPS)[:, None], ynew, y)

    new = jax.lax.fori_loop(0, weiszfeld_iters, wbody, centers)
    c = jnp.sum(weights * jnp.sqrt(jnp.maximum(d2, 0.0)))
    return new, c


@functools.partial(jax.jit, static_argnames=("iters", "objective", "k"))
def lloyd(
    points: Array,
    centers: Array,
    weights: Optional[Array] = None,
    iters: int = 10,
    objective: str = "kmeans",
    k: Optional[int] = None,
) -> Tuple[Array, Array]:
    """Weighted Lloyd iterations. Returns (centers, cost_history (iters,)).

    Handles negative weights (signed coreset measures): clusters whose total
    weight is <= eps keep their previous center.
    """
    k = centers.shape[0] if k is None else k
    w = jnp.ones((points.shape[0],), points.dtype) if weights is None else weights
    upd = _kmeans_update if objective == "kmeans" else _kmedian_update

    def body(centers, _):
        new, c = upd(points, w, centers, k)
        return new, c

    centers, hist = jax.lax.scan(body, centers, None, length=iters)
    return centers, hist


@functools.partial(jax.jit,
                   static_argnames=("k", "lloyd_iters", "objective",
                                    "restarts"))
def solve(
    key: Array,
    points: Array,
    k: int,
    weights: Optional[Array] = None,
    lloyd_iters: int = 10,
    objective: str = "kmeans",
    restarts: int = 1,
) -> Tuple[Array, Array]:
    """Constant-approximation solver: k-means++ seeding + Lloyd refinement,
    best of ``restarts`` independent seedings (k-means++ is only O(log k) in
    expectation; restarts make the constant-approximation assumption of
    Theorem 1 hold in practice).

    This is the ``A_alpha`` subroutine of Algorithm 2 and the local solver
    ``B_i`` of Algorithm 1. Returns (centers (k,d), final cost scalar).
    """

    def one(ki):
        centers = kmeans_pp_init(ki, points, k, weights=weights,
                                 objective=objective)
        centers, _ = lloyd(points, centers, weights=weights,
                           iters=lloyd_iters, objective=objective)
        c = cost(points, centers, weights=weights, objective=objective)
        return centers, c

    if restarts == 1:
        return one(key)
    all_centers, costs = jax.lax.map(one, jax.random.split(key, restarts))
    best = jnp.argmin(costs)
    return all_centers[best], costs[best]
