"""Centralized *weighted* clustering primitives (k-means / k-median).

Used both by the paper's algorithms (local constant approximation solves on
each site, Algorithm 1 Round 1) and by the final clustering of the global
coreset (Algorithm 2 Round 2). Every function supports per-point weights --
the coreset is a *weighted* instance, possibly with negative center weights
-- and is jit-compatible with static ``k`` and iteration counts.

Every distance/statistics hot loop dispatches through the backend registry
(:mod:`repro.core.backend`): ``backend`` accepts a registry name
(``"jnp"``, ``"jnp_chunked"``, ``"pallas"``), a :class:`ClusteringBackend`
instance, or ``None`` for the ambient default (``use_backend`` /
auto-detection). The *objective* dispatches the same way through
:mod:`repro.core.objective`: ``objective`` accepts a registry name
(``"kmeans"``, ``"kmedian"``, parametrized ``"kmeans_trimmed(<t>)"`` /
``"power(<z>)"``) or an :class:`Objective` instance, resolved once at the
public boundary (unknown names raise). Center updates, seeding masses, and
per-point costs all come from the descriptor's hooks: the k-means instance
consumes the fused one-pass ``lloyd_stats`` primitive and the k-median
instance the fused ``weiszfeld_stats`` primitive -- on the Pallas backend
the (n, k) distance matrix never exists in HBM for any objective
(DESIGN.md Sec. 8, 10, 15).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import objective as objective_mod
from repro.core.backend import BackendLike
from repro.core.objective import ObjectiveLike

Array = jax.Array

_TINY = 1e-30
_EPS = 1e-12


def pairwise_sq_dists(points: Array, centers: Array) -> Array:
    """Squared euclidean distances. points (n,d), centers (k,d) -> (n,k)."""
    p2 = jnp.sum(points * points, axis=-1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=-1)
    d2 = p2 + c2[None, :] - 2.0 * (points @ centers.T)
    return jnp.maximum(d2, 0.0)


def min_dist_argmin(
    points: Array,
    centers: Array,
    chunk: Optional[int] = None,
    backend: BackendLike = None,
) -> Tuple[Array, Array]:
    """Min squared distance and argmin center per point, via the dispatch
    layer. ``chunk`` bounds the materialized (chunk, k) distance block of
    the dense jnp path for large n: it upgrades a resolved ``jnp`` backend
    (explicit or ambient) to a chunked one, and is ignored by backends that
    already bound their memory (pallas tiles, jnp_chunked's own chunk)."""
    b = backend_mod.get_backend(backend)
    if chunk is not None and type(b) is backend_mod.JnpBackend:
        b = backend_mod.JnpChunkedBackend(chunk)
    return b.min_dist_argmin(points, centers)


def lloyd_stats(
    points: Array,
    centers: Array,
    weights: Optional[Array] = None,
    backend: BackendLike = None,
) -> Tuple[Array, Array, Array]:
    """Fused weighted Lloyd statistics (sums (k,d), counts (k,), cost ())
    via the dispatch layer."""
    return backend_mod.get_backend(backend).lloyd_stats(
        points, centers, weights)


def weiszfeld_stats(
    points: Array,
    centers: Array,
    weights: Optional[Array] = None,
    backend: BackendLike = None,
) -> Tuple[Array, Array, Array]:
    """Fused weighted Weiszfeld statistics (nums (k,d), denoms (k,),
    cost ()) for one k-median refinement pass, via the dispatch layer
    (DESIGN.md Sec. 10)."""
    return backend_mod.get_backend(backend).weiszfeld_stats(
        points, centers, weights)


def _costing_backend(chunk, backend):
    """Resolve a backend instance for a costing call, applying the ``chunk``
    upgrade of :func:`min_dist_argmin`."""
    b = backend_mod.get_backend(backend)
    if chunk is not None and type(b) is backend_mod.JnpBackend:
        b = backend_mod.JnpChunkedBackend(chunk)
    return b


def cost(
    points: Array,
    centers: Array,
    weights: Optional[Array] = None,
    objective: ObjectiveLike = "kmeans",
    chunk: Optional[int] = None,
    backend: BackendLike = None,
) -> Array:
    """Weighted clustering cost: sum_p w_p d(p, X)^z in the objective's
    metric (z=2 k-means, z=1 k-median, trimmed variants exclude their
    top-t residual points)."""
    obj = objective_mod.get_objective(objective)
    per_point, _ = obj.costs(_costing_backend(chunk, backend),
                             points, centers, weights)
    if weights is not None:
        per_point = per_point * weights
    return jnp.sum(per_point)


def point_costs(
    points: Array,
    centers: Array,
    objective: ObjectiveLike = "kmeans",
    chunk: Optional[int] = None,
    backend: BackendLike = None,
    weights: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Per-point cost to the nearest center and the assignment (n,), (n,).

    ``weights`` only feeds the objective's liveness mask (trimmed
    objectives never count weight-0 padding against the trim budget); the
    returned costs are *unweighted*.
    """
    obj = objective_mod.get_objective(objective)
    return obj.costs(_costing_backend(chunk, backend),
                     points, centers, weights)


def kmeans_pp_init(
    key: Array,
    points: Array,
    k: int,
    weights: Optional[Array] = None,
    objective: ObjectiveLike = "kmeans",
    backend: BackendLike = None,
) -> Array:
    """D^z seeding (k-means++ for z=2, k-median++ for z=1) with optional
    weights. The seeding mass of each step comes from the objective's
    ``seeding_mass`` hook: plain objectives use ``w * D^z`` (weight-0
    padding is never selected -- its logit is -inf), trimmed objectives
    additionally zero the mass of the current top-t residual points so
    seeds avoid far-field outliers.
    """
    return _kmeans_pp_init(key, points, weights, k=k,
                           objective=objective_mod.resolve_name(objective),
                           backend=backend_mod.resolve_name(backend))


def _masked_choice(key, mass):
    """Categorical draw proportional to ``mass``, deterministic row 0 when
    the total mass is zero. All-zero mass (a fully masked site under vmap,
    or every remaining point coinciding with a chosen center) would make
    every logit equal and seed uniformly from padding rows; those rows are
    weight-0 and inert downstream, but the draw must be deterministic, not
    an accident of the key."""
    idx = jax.random.categorical(key, jnp.log(mass + _TINY))
    return jnp.where(jnp.sum(mass) > 0.0, idx, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "objective", "backend"))
def _kmeans_pp_init(key, points, weights, k, objective, backend):
    obj = objective_mod.get_objective(objective)
    b = backend_mod.get_backend(backend)
    n, d = points.shape
    w = jnp.ones((n,), points.dtype) if weights is None else weights
    w = jnp.maximum(w, 0.0)

    def dist_to(c):
        # distance of every point to one candidate center, via the backend
        d2 = b.min_dist_argmin(points, c[None, :])[0]
        return obj.clamped_cost(d2)

    key, k0 = jax.random.split(key)
    first = _masked_choice(k0, w)
    centers = jnp.zeros((k, d), points.dtype).at[0].set(points[first])
    mind = dist_to(points[first])

    def body(i, carry):
        centers, mind, key = carry
        key, ki = jax.random.split(key)
        idx = _masked_choice(ki, obj.seeding(w, mind))
        c = points[idx]
        centers = centers.at[i].set(c)
        mind = jnp.minimum(mind, dist_to(c))
        return centers, mind, key

    centers, _, _ = jax.lax.fori_loop(1, k, body, (centers, mind, key))
    return centers


def lloyd(
    points: Array,
    centers: Array,
    weights: Optional[Array] = None,
    iters: int = 10,
    objective: ObjectiveLike = "kmeans",
    k: Optional[int] = None,
    backend: BackendLike = None,
) -> Tuple[Array, Array]:
    """Weighted center-update iterations in the objective's metric (Lloyd
    steps for k-means, fused Weiszfeld passes for k-median, trimmed /
    IRLS passes for the registered extensions). Returns
    (centers, cost_history (iters,)).

    Handles negative weights (signed coreset measures): clusters whose total
    weight is <= eps keep their previous center.
    """
    k = centers.shape[0] if k is None else k
    return _lloyd(points, centers, weights, iters=iters,
                  objective=objective_mod.resolve_name(objective),
                  k=k, backend=backend_mod.resolve_name(backend))


@functools.partial(jax.jit,
                   static_argnames=("iters", "objective", "k", "backend"))
def _lloyd(points, centers, weights, iters, objective, k, backend):
    obj = objective_mod.get_objective(objective)
    b = backend_mod.get_backend(backend)
    w = jnp.ones((points.shape[0],), points.dtype) if weights is None \
        else weights

    def body(centers, _):
        new, c = obj.update(b, points, w, centers)
        return new, c

    centers, hist = jax.lax.scan(body, centers, None, length=iters)
    return centers, hist


def lloyd_converged(
    points: Array,
    centers: Array,
    weights: Optional[Array] = None,
    iters: int = 10,
    tol: float = 0.0,
    objective: ObjectiveLike = "kmeans",
    k: Optional[int] = None,
    backend: BackendLike = None,
) -> Tuple[Array, Array]:
    """:func:`lloyd` with an early exit: stop refining once the relative
    cost improvement of a pass drops to ``tol`` (or after ``iters`` passes,
    whichever comes first). Returns (centers, iters_run).

    ``tol == 0.0`` is the strict mode: it delegates to the fixed-length
    scan of :func:`lloyd`, so centers are bit-identical to the lockstep
    path (the staged coreset engine's parity contract; DESIGN.md Sec. 17).
    ``tol > 0.0`` trades bit-parity for wall-clock -- sites whose local
    solve converges early skip the remaining passes entirely (while_loop),
    which is where the staged engine's per-site overlap win comes from.
    """
    k = centers.shape[0] if k is None else k
    return _lloyd_converged(points, centers, weights, iters=iters,
                            tol=float(tol),
                            objective=objective_mod.resolve_name(objective),
                            k=k, backend=backend_mod.resolve_name(backend))


@functools.partial(jax.jit,
                   static_argnames=("iters", "tol", "objective", "k",
                                    "backend"))
def _lloyd_converged(points, centers, weights, iters, tol, objective, k,
                     backend):
    if tol == 0.0:
        centers, _ = _lloyd(points, centers, weights, iters=iters,
                            objective=objective, k=k, backend=backend)
        return centers, jnp.asarray(iters, jnp.int32)
    obj = objective_mod.get_objective(objective)
    b = backend_mod.get_backend(backend)
    w = jnp.ones((points.shape[0],), points.dtype) if weights is None \
        else weights

    def cond(carry):
        i, _, _, done = carry
        return (i < iters) & ~done

    def body(carry):
        i, centers, prev, _ = carry
        new, c = obj.update(b, points, w, centers)
        # relative improvement of this pass; prev starts at +inf so the
        # first pass never exits (inf <= tol * c is false for finite c)
        done = (prev - c) <= tol * jnp.maximum(c, _TINY)
        return i + 1, new, c, done

    i, centers, _, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), centers,
                     jnp.asarray(jnp.inf, points.dtype),
                     jnp.asarray(False)))
    return centers, i


def solve(
    key: Array,
    points: Array,
    k: int,
    weights: Optional[Array] = None,
    lloyd_iters: int = 10,
    objective: ObjectiveLike = "kmeans",
    restarts: int = 1,
    backend: BackendLike = None,
) -> Tuple[Array, Array]:
    """Constant-approximation solver: D^z seeding + iterative refinement,
    best of ``restarts`` independent seedings (k-means++ is only O(log k) in
    expectation; restarts make the constant-approximation assumption of
    Theorem 1 hold in practice). Restart selection uses the objective's own
    cost, so trimmed objectives pick the best *trimmed* restart.

    This is the ``A_alpha`` subroutine of Algorithm 2 and the local solver
    ``B_i`` of Algorithm 1. Returns (centers (k,d), final cost scalar).
    """
    return _solve(key, points, weights, k=k, lloyd_iters=lloyd_iters,
                  objective=objective_mod.resolve_name(objective),
                  restarts=restarts,
                  backend=backend_mod.resolve_name(backend))


@functools.partial(jax.jit,
                   static_argnames=("k", "lloyd_iters", "objective",
                                    "restarts", "backend"))
def _solve(key, points, weights, k, lloyd_iters, objective, restarts,
           backend):
    def one(ki):
        centers = kmeans_pp_init(ki, points, k, weights=weights,
                                 objective=objective, backend=backend)
        centers, _ = lloyd(points, centers, weights=weights,
                           iters=lloyd_iters, objective=objective,
                           backend=backend)
        c = cost(points, centers, weights=weights, objective=objective,
                 backend=backend)
        return centers, c

    if restarts == 1:
        return one(key)
    all_centers, costs = jax.lax.map(one, jax.random.split(key, restarts))
    best = jnp.argmin(costs)
    return all_centers[best], costs[best]
