"""Communication-cost ledger.

The paper measures communication in "number of points transmitted"; we keep
that unit (``points``) and also derive bytes (``(d+1) * 4`` bytes per weighted
point, ``4`` per scalar) so the LM-side roofline and the clustering-side
experiments share one currency. Every algorithm in ``repro.core`` returns a
``CommLedger`` alongside its result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.topology import Graph, SpanningTree


@dataclasses.dataclass
class CommLedger:
    """Counts of transmitted units, with an optional per-phase breakdown.

    ``phases`` maps a phase label (e.g. ``"stream_round_3"``) to a
    totals-only sub-ledger; :meth:`tag` files an untagged ledger under a
    label, :meth:`add` merges breakdowns label-wise, and
    ``as_dict(by_phase=True)`` exposes them -- the streaming aggregation
    rounds report points/scalars/bytes per round this way."""

    scalars: float = 0.0          # single float values (local costs)
    points: float = 0.0           # weighted d-dim points
    messages: float = 0.0         # individual edge transmissions
    dim: int = 0                  # point dimensionality (for bytes)
    phases: Dict[str, "CommLedger"] = dataclasses.field(default_factory=dict)

    def add(self, other: "CommLedger") -> "CommLedger":
        phases = {k: dataclasses.replace(v) for k, v in self.phases.items()}
        for name, sub in other.phases.items():
            phases[name] = (phases[name].add(sub) if name in phases
                            else dataclasses.replace(sub))
        return CommLedger(
            scalars=self.scalars + other.scalars,
            points=self.points + other.points,
            messages=self.messages + other.messages,
            dim=max(self.dim, other.dim),
            phases=phases,
        )

    def tag(self, phase: str) -> "CommLedger":
        """Return a copy whose totals are also filed under ``phase``. Any
        existing breakdown is collapsed into the new label (a tagged ledger
        stays one level deep)."""
        totals = CommLedger(scalars=self.scalars, points=self.points,
                            messages=self.messages, dim=self.dim)
        return dataclasses.replace(totals, phases={phase: totals})

    @property
    def bytes(self) -> float:
        return 4.0 * self.scalars + 4.0 * (self.dim + 1) * self.points

    def as_dict(self, by_phase: bool = False) -> Dict[str, float]:
        out = {
            "scalars": self.scalars,
            "points": self.points,
            "messages": self.messages,
            "bytes": self.bytes,
        }
        if by_phase:
            out["phases"] = {name: sub.as_dict()
                             for name, sub in self.phases.items()}
        return out


def flood_cost(g: Graph, n_messages: int, unit_points: float = 0.0,
               unit_scalars: float = 0.0, dim: int = 0) -> CommLedger:
    """Algorithm 3 on a general graph: every node forwards each of the
    ``n_messages`` distinct messages to all its neighbours exactly once
    => sum_v deg(v) = 2m transmissions per message (Theorem 2's O(m) factor).
    """
    per_message = 2.0 * g.m
    return CommLedger(
        scalars=per_message * n_messages * unit_scalars,
        points=per_message * n_messages * unit_points,
        messages=per_message * n_messages,
        dim=dim,
    )


def tree_gather_cost(tree: SpanningTree, unit_points_per_node=0.0,
                     unit_scalars_per_node=0.0, dim: int = 0) -> CommLedger:
    """Per-node payloads routed along parent edges to the root: node v's
    payload travels its ``depth(v)`` edges (Theorem 3's O(h) factor). By
    path symmetry the identical ledger prices the root *scattering*
    per-node payloads back down their subtree paths (the executed Round-1
    allocation delivery; DESIGN.md Sec. 11). Units: scalar or per-node
    sequence; a node transmits (counts a message per hop) iff it has any
    positive unit."""

    def per_node(u):
        return [u] * tree.n if not hasattr(u, "__len__") else u

    up = per_node(unit_points_per_node)
    us = per_node(unit_scalars_per_node)
    pts = sum(tree.depth[v] * up[v] for v in range(tree.n))
    scl = sum(tree.depth[v] * us[v] for v in range(tree.n))
    msgs = sum(tree.depth[v] for v in range(tree.n)
               if up[v] > 0 or us[v] > 0)
    return CommLedger(scalars=float(scl), points=float(pts),
                      messages=float(msgs), dim=dim)


def tree_up_cost(tree: SpanningTree, unit_points_per_node, dim: int = 0
                 ) -> CommLedger:
    """Each node's payload travels its depth edges up to the root
    (Theorem 3's O(h) factor). ``unit_points_per_node``: scalar or seq."""
    return tree_gather_cost(tree, unit_points_per_node=unit_points_per_node,
                            dim=dim)


def tree_broadcast_cost(tree: SpanningTree, unit_points: float = 0.0,
                        unit_scalars: float = 0.0, dim: int = 0) -> CommLedger:
    """Root sends one payload down every tree edge (n-1 transmissions)."""
    edges = tree.n - 1
    return CommLedger(
        scalars=edges * unit_scalars,
        points=edges * unit_points,
        messages=float(edges),
        dim=dim,
    )
