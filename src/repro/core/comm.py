"""Communication-cost ledger.

The paper measures communication in "number of points transmitted"; we keep
that unit (``points``) and also derive bytes (``(d+1) * 4`` bytes per weighted
point, ``4`` per scalar) so the LM-side roofline and the clustering-side
experiments share one currency. Every algorithm in ``repro.core`` returns a
``CommLedger`` alongside its result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.topology import Graph, SpanningTree


@dataclasses.dataclass
class CommLedger:
    """Counts of transmitted units, broken down by phase."""

    scalars: float = 0.0          # single float values (local costs)
    points: float = 0.0           # weighted d-dim points
    messages: float = 0.0         # individual edge transmissions
    dim: int = 0                  # point dimensionality (for bytes)

    def add(self, other: "CommLedger") -> "CommLedger":
        return CommLedger(
            scalars=self.scalars + other.scalars,
            points=self.points + other.points,
            messages=self.messages + other.messages,
            dim=max(self.dim, other.dim),
        )

    @property
    def bytes(self) -> float:
        return 4.0 * self.scalars + 4.0 * (self.dim + 1) * self.points

    def as_dict(self) -> Dict[str, float]:
        return {
            "scalars": self.scalars,
            "points": self.points,
            "messages": self.messages,
            "bytes": self.bytes,
        }


def flood_cost(g: Graph, n_messages: int, unit_points: float = 0.0,
               unit_scalars: float = 0.0, dim: int = 0) -> CommLedger:
    """Algorithm 3 on a general graph: every node forwards each of the
    ``n_messages`` distinct messages to all its neighbours exactly once
    => sum_v deg(v) = 2m transmissions per message (Theorem 2's O(m) factor).
    """
    per_message = 2.0 * g.m
    return CommLedger(
        scalars=per_message * n_messages * unit_scalars,
        points=per_message * n_messages * unit_points,
        messages=per_message * n_messages,
        dim=dim,
    )


def tree_up_cost(tree: SpanningTree, unit_points_per_node, dim: int = 0
                 ) -> CommLedger:
    """Each node's payload travels its depth edges up to the root
    (Theorem 3's O(h) factor). ``unit_points_per_node``: scalar or seq."""
    if not hasattr(unit_points_per_node, "__len__"):
        unit_points_per_node = [unit_points_per_node] * tree.n
    pts = sum(tree.depth[v] * unit_points_per_node[v] for v in range(tree.n))
    msgs = sum(tree.depth[v] for v in range(tree.n)
               if unit_points_per_node[v] > 0)
    return CommLedger(points=float(pts), messages=float(msgs), dim=dim)


def tree_broadcast_cost(tree: SpanningTree, unit_points: float = 0.0,
                        unit_scalars: float = 0.0, dim: int = 0) -> CommLedger:
    """Root sends one payload down every tree edge (n-1 transmissions)."""
    edges = tree.n - 1
    return CommLedger(
        scalars=edges * unit_scalars,
        points=edges * unit_points,
        messages=float(edges),
        dim=dim,
    )
