"""Communication-cost ledger.

The paper measures communication in "number of points transmitted"; we keep
that unit (``points``) and also derive bytes (``(d+1) * 4`` bytes per weighted
point, ``4`` per scalar) so the LM-side roofline and the clustering-side
experiments share one currency. Every algorithm in ``repro.core`` returns a
``CommLedger`` alongside its result.

Heterogeneous links add a fourth axis, ``link_cost``: cost-weighted bytes.
Every transmission is priced by the edge it crosses -- a payload of ``b``
bytes over a link of cost ``c`` contributes ``c * b`` -- so WAN-vs-rack
deployments are no longer metered as if every hop were equal. On uniform
(unit) costs ``link_cost == bytes``, reproducing the pre-cost accounting
bit-exactly; :func:`link_cost_of` is the one canonical float64 summation
both the analytic helpers here and the engine's measured pricing share, so
analytic and measured ledgers agree bit-for-bit whenever costs and units
are integer-valued (DESIGN.md Sec. 12).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.topology import Graph, SpanningTree


@dataclasses.dataclass
class CommLedger:
    """Counts of transmitted units, with an optional per-phase breakdown.

    ``phases`` maps a phase label (e.g. ``"stream_round_3"``) to a
    totals-only sub-ledger; :meth:`tag` files an untagged ledger under a
    label, :meth:`add` merges breakdowns label-wise, and
    ``as_dict(by_phase=True)`` exposes them -- the streaming aggregation
    rounds report points/scalars/bytes per round this way.

    ``link_cost`` is the cost-weighted byte total over heterogeneous links
    (equals ``bytes`` on uniform unit costs); unlike ``bytes`` it is
    accumulated at pricing time, per transmission, because the per-edge
    cost is not recoverable from the unit totals.

    ``staleness`` is the asynchronous-runtime axis (DESIGN.md Sec. 14):
    mean rounds-behind of the nodes relative to the synchronous lossless
    engine on the same graph. A synchronous/analytic ledger is 0.0 by
    definition; the WAN runtime's measured ledgers fill it in. Unlike the
    traffic axes it is a *lag*, not a volume, so :meth:`add` combines it
    by max (the staleness of a multi-phase protocol is its worst phase),
    which keeps every existing volume identity untouched."""

    scalars: float = 0.0          # single float values (local costs)
    points: float = 0.0           # weighted d-dim points
    messages: float = 0.0         # individual edge transmissions
    dim: int = 0                  # point dimensionality (for bytes)
    link_cost: float = 0.0        # cost-weighted bytes (heterogeneous links)
    staleness: float = 0.0        # mean rounds-behind vs the sync engine
    phases: Dict[str, "CommLedger"] = dataclasses.field(default_factory=dict)

    def add(self, other: "CommLedger") -> "CommLedger":
        phases = {k: dataclasses.replace(v) for k, v in self.phases.items()}
        for name, sub in other.phases.items():
            phases[name] = (phases[name].add(sub) if name in phases
                            else dataclasses.replace(sub))
        return CommLedger(
            scalars=self.scalars + other.scalars,
            points=self.points + other.points,
            messages=self.messages + other.messages,
            dim=max(self.dim, other.dim),
            link_cost=self.link_cost + other.link_cost,
            staleness=max(self.staleness, other.staleness),
            phases=phases,
        )

    def tag(self, phase: str) -> "CommLedger":
        """Return a copy whose totals are also filed under ``phase``. Any
        existing breakdown is collapsed into the new label (a tagged ledger
        stays one level deep)."""
        totals = CommLedger(scalars=self.scalars, points=self.points,
                            messages=self.messages, dim=self.dim,
                            link_cost=self.link_cost,
                            staleness=self.staleness)
        return dataclasses.replace(totals, phases={phase: totals})

    @property
    def bytes(self) -> float:
        return 4.0 * self.scalars + 4.0 * (self.dim + 1) * self.points

    def as_dict(self, by_phase: bool = False) -> Dict[str, float]:
        out = {
            "scalars": self.scalars,
            "points": self.points,
            "messages": self.messages,
            "bytes": self.bytes,
            "link_cost": self.link_cost,
            "staleness": self.staleness,
        }
        if by_phase:
            out["phases"] = {name: sub.as_dict()
                             for name, sub in self.phases.items()}
        return out


def link_cost_of(per_origin_cost, unit_scalars=0.0, unit_points=0.0,
                 dim: int = 0) -> float:
    """Canonical cost-weighted-bytes summation.

    ``per_origin_cost[o]`` is the summed cost of every edge origin ``o``'s
    payload crossed; each origin contributes ``cost * (4*scalars +
    4*(dim+1)*points)``. Sequential float64 accumulation in origin order --
    shared by the analytic helpers and the engine's measured pricing so the
    two agree bit-for-bit (exactly so for integer-valued costs and units,
    which every shipped pipeline uses)."""
    per = np.asarray(per_origin_cost, np.float64).reshape(-1)
    us = np.broadcast_to(np.asarray(unit_scalars, np.float64), per.shape)
    up = np.broadcast_to(np.asarray(unit_points, np.float64), per.shape)
    total = 0.0
    for w, s, p in zip(per.tolist(), us.tolist(), up.tolist()):
        total += w * (4.0 * s + 4.0 * (dim + 1) * p)
    return float(total)


def flood_cost(g: Graph, n_messages: int, unit_points: float = 0.0,
               unit_scalars: float = 0.0, dim: int = 0) -> CommLedger:
    """Algorithm 3 on a general graph: every node forwards each of the
    ``n_messages`` distinct messages to all its neighbours exactly once
    => sum_v deg(v) = 2m transmissions per message (Theorem 2's O(m)
    factor; m on a directed graph, where only out-links forward). A flood
    has no routing freedom -- each message crosses *every* link -- so its
    cost-weighted price is the full weighted degree sum per message."""
    per_message = float(g.m if g.directed else 2 * g.m)
    w_per_message = float(g.weighted_degrees().sum())
    return CommLedger(
        scalars=per_message * n_messages * unit_scalars,
        points=per_message * n_messages * unit_points,
        messages=per_message * n_messages,
        dim=dim,
        link_cost=link_cost_of([w_per_message * n_messages],
                               unit_scalars, unit_points, dim),
    )


def flood_portions_cost(g: Graph, t_i, k: int, dim: int) -> CommLedger:
    """Analytic Round-2 flood ledger: n messages of per-site size
    ``t_i + k`` points, each crossing every link. The per-origin
    ``link_cost`` summation mirrors the engine's measured pricing term for
    term, so sim and exec agree bit-for-bit. Shared by the graph path of
    Algorithm 2 and the streaming resample rounds."""
    per_message = float(g.m if g.directed else 2 * g.m)
    w_per_message = float(g.weighted_degrees().sum())
    unit_pts = np.asarray(t_i, np.float64) + k
    return CommLedger(
        points=per_message * float(unit_pts.sum()),
        messages=per_message * g.n,
        dim=dim,
        link_cost=link_cost_of(np.full(g.n, w_per_message),
                               unit_points=unit_pts, dim=dim),
    )


def tree_gather_cost(tree: SpanningTree, unit_points_per_node=0.0,
                     unit_scalars_per_node=0.0, dim: int = 0) -> CommLedger:
    """Per-node payloads routed along parent edges to the root: node v's
    payload travels its ``depth(v)`` edges (Theorem 3's O(h) factor) and
    pays its root-path link costs (``path_costs``). By path symmetry the
    identical ledger prices the root *scattering* per-node payloads back
    down their subtree paths (the executed Round-1 allocation delivery;
    DESIGN.md Sec. 11). Units: scalar or per-node sequence; a node
    transmits (counts a message per hop) iff it has any positive unit."""

    def per_node(u):
        return [u] * tree.n if not hasattr(u, "__len__") else u

    up = per_node(unit_points_per_node)
    us = per_node(unit_scalars_per_node)
    pts = sum(tree.depth[v] * up[v] for v in range(tree.n))
    scl = sum(tree.depth[v] * us[v] for v in range(tree.n))
    msgs = sum(tree.depth[v] for v in range(tree.n)
               if up[v] > 0 or us[v] > 0)
    return CommLedger(scalars=float(scl), points=float(pts),
                      messages=float(msgs), dim=dim,
                      link_cost=link_cost_of(tree.path_costs(),
                                             np.asarray(us, np.float64),
                                             np.asarray(up, np.float64),
                                             dim))


def tree_up_cost(tree: SpanningTree, unit_points_per_node, dim: int = 0
                 ) -> CommLedger:
    """Each node's payload travels its depth edges up to the root
    (Theorem 3's O(h) factor). ``unit_points_per_node``: scalar or seq."""
    return tree_gather_cost(tree, unit_points_per_node=unit_points_per_node,
                            dim=dim)


def tree_broadcast_cost(tree: SpanningTree, unit_points: float = 0.0,
                        unit_scalars: float = 0.0, dim: int = 0) -> CommLedger:
    """Root sends one payload down every tree edge (n-1 transmissions,
    priced at the tree's total edge cost -- the quantity a min-cost
    spanning tree minimizes)."""
    edges = tree.n - 1
    return CommLedger(
        scalars=edges * unit_scalars,
        points=edges * unit_points,
        messages=float(edges),
        dim=dim,
        link_cost=link_cost_of([tree.edge_cost_total()], unit_scalars,
                               unit_points, dim),
    )


def tree_allocation_cost(tree: SpanningTree) -> CommLedger:
    """Analytic Round-1 ledger of the executable tree protocol: raw cost
    scalars up (gather), per-site allocations down (scatter), total down
    (broadcast). The scatter prices like the gather by path symmetry
    (DESIGN.md Sec. 11)."""
    ledger = tree_gather_cost(tree, unit_scalars_per_node=1.0)   # costs up
    ledger = ledger.add(tree_gather_cost(tree, unit_scalars_per_node=1.0))
    ledger = ledger.add(tree_broadcast_cost(tree, unit_scalars=1.0))
    return ledger
