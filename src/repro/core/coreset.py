"""Coreset constructions (paper Sec. 3, Algorithm 1).

Two entry points:

* :func:`build_coreset` -- the centralized sensitivity-sampling construction of
  Feldman-Langberg [10] on a (possibly weighted) point set. Used as the
  subroutine of the COMBINE and Zhang-et-al. baselines and as the reference
  centralized construction.

* :func:`distributed_coreset` -- **Algorithm 1**: every site solves its local
  instance, the only communicated quantities are the ``n`` scalar local costs,
  and each site then samples ``t_i = t * cost_i / sum_j cost_j`` points from
  its own data with probability proportional to the local sensitivity
  surrogate ``m_p = cost(p, B_i)``. (The paper writes ``m_p = 2 cost(p,B_i)``;
  the constant cancels in both the sampling distribution and the weight
  formula ``w_q = sum m / (t * m_q)``, so we drop it.) The union of all local
  portions ``S_i \\cup B_i`` is an eps-coreset of the *global* data set
  (Theorem 1).

Center weights ``w_b = |P_b| - sum_{q in P_b \\cap S} w_q`` may be negative --
the coreset is a signed measure (faithful to the paper); ``clip_negative``
opts into the common non-negative heuristic.

Everything is fixed-shape: sites sample into a ``t_buffer``-slot buffer with a
validity mask (XLA static shapes; see DESIGN.md Sec. 7).

Both constructions dispatch their distance/statistics hot loops through the
backend registry (``backend=`` accepts ``"jnp"``/``"jnp_chunked"``/
``"pallas"`` or ``None`` for the ambient default; DESIGN.md Sec. 8) and are
objective-generic through the objective registry (``objective=`` accepts
any registered :class:`Objective` name -- ``"kmeans"``, ``"kmedian"``,
``"kmeans_trimmed(<t>)"``, ``"power(<z>)"`` -- resolved once at the public
boundary; DESIGN.md Sec. 15). The objective's ``sensitivity_rule`` supplies
both the sampling masses and the *effective weights* Round 2 must use --
trimmed objectives zero their outliers' weights so trimmed mass never
reaches the sampled portions or the center weights.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core import objective as objective_mod
from repro.core.backend import BackendLike
from repro.core.objective import ObjectiveLike

Array = jax.Array
_TINY = 1e-30


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["points", "weights"], meta_fields=[])
@dataclasses.dataclass
class Coreset:
    """Weighted summary: invalid slots carry weight exactly 0."""

    points: Array    # (M, d)
    weights: Array   # (M,)

    @property
    def size(self) -> int:
        return int(self.points.shape[0])

    def effective_size(self) -> Array:
        return jnp.sum(self.weights != 0.0)

    def cost(self, centers: Array,
             objective: ObjectiveLike = "kmeans") -> Array:
        return clustering.cost(self.points, centers, weights=self.weights,
                               objective=objective)

    @staticmethod
    def concat(*coresets: "Coreset") -> "Coreset":
        """Weight-preserving union of summaries (mask discipline of
        DESIGN.md Sec. 7 makes this exact: invalid slots carry weight
        exactly 0 and stay inert in the union). jit/vmap-compatible --
        the merge-and-reduce stream tree and ``distributed_coreset`` both
        stitch their buffers through here."""
        if not coresets:
            raise ValueError("Coreset.concat needs at least one coreset")
        return Coreset(
            points=jnp.concatenate([c.points for c in coresets], axis=-2),
            weights=jnp.concatenate([c.weights for c in coresets], axis=-1))

    def compact(self, size: Optional[int] = None) -> "Coreset":
        """Move weight-carrying slots to the front (stable) and truncate to
        ``size`` slots (default: same size). Mask-aware and jit-able (static
        output shape). Caller contract: ``size`` must be >= the number of
        nonzero-weight slots, otherwise mass is silently dropped -- check
        ``effective_size()`` first when in doubt."""
        size = self.size if size is None else size
        order = jnp.argsort(self.weights == 0.0, stable=True)
        return Coreset(points=self.points[order][:size],
                       weights=self.weights[order][:size])


def sensitivities(points: Array, centers: Array, weights: Array,
                  objective: ObjectiveLike = "kmeans",
                  backend: BackendLike = None
                  ) -> Tuple[Array, Array, Array]:
    """Per-point sampling masses, assignments, and *effective weights*
    ``(m, assign, w_eff)`` via the objective's ``sensitivity_rule``.

    Plain objectives: the paper's m_p = |w_p| * cost(p, B) with
    ``w_eff = weights`` passed through unchanged. The absolute value
    matters only for *signed* instances (re-sampling a coreset whose
    center weights went negative, as the streaming merge-and-reduce tree
    does): masses must be a valid sampling distribution, while the
    sample-weight formula keeps the original sign, so
    ``E[sum_q w_q f(q)] = sum_p w_p f(p)`` still holds and the total
    weight identity stays exact.

    Trimmed objectives additionally zero both the mass *and* ``w_eff`` on
    their top-t residual points -- downstream sampling and center
    weighting must consume ``w_eff``, not the raw weights, so outlier mass
    never folds back into the coreset."""
    obj = objective_mod.get_objective(objective)
    b = backend_mod.get_backend(backend)
    return obj.sensitivities(b, points, centers, weights)


def weighted_choice(key: Array, masses: Array, n_draws: int) -> Array:
    """``n_draws`` i.i.d. draws proportional to ``masses`` via inverse-CDF
    (O(M + t log M); jax.random.categorical would materialize a
    (n_draws, M) gumbel tensor). Zero-mass entries are never drawn."""
    cdf = jnp.cumsum(masses)
    total = cdf[-1]
    u = jax.random.uniform(key, (n_draws,), masses.dtype) * total
    idx = jnp.searchsorted(cdf, u, side="right")
    return jnp.clip(idx, 0, masses.shape[0] - 1).astype(jnp.int32)


def _sample_and_weight(key: Array, points: Array, m: Array, weights: Array,
                       assign: Array, k: int, t_local: Array, t_buffer: int,
                       total_m: Array, t_total: Array):
    """Draw ``t_local`` (<= t_buffer) points ~ m_p; compute sample + center
    weights. Shared by the centralized and distributed constructions."""
    n = points.shape[0]
    idx = weighted_choice(key, m, t_buffer)
    valid = (jnp.arange(t_buffer) < t_local) & (total_m > _TINY)
    # w_q = (sum_z m_z) * w_q_orig / (t * m_q); zero for invalid slots
    m_q = m[idx]
    w_s = jnp.where(
        valid & (m_q > _TINY),
        total_m * weights[idx] / (jnp.maximum(t_total, 1.0) * jnp.maximum(m_q, _TINY)),
        0.0,
    )
    sampled = points[idx]
    # center weights: w_b = W(P_b) - sum_{q in P_b cap S} w_q
    oh = jax.nn.one_hot(assign, k, dtype=points.dtype)          # (n, k)
    w_pb = (weights[:, None] * oh).sum(0)                        # (k,)
    sampled_assign = assign[idx]
    w_sb = jnp.zeros((k,), points.dtype).at[sampled_assign].add(w_s)
    w_b = w_pb - w_sb
    return sampled, w_s, w_b


def build_coreset(
    key: Array,
    points: Array,
    k: int,
    t: int,
    weights: Optional[Array] = None,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 5,
    clip_negative: bool = False,
    backend: BackendLike = None,
) -> Coreset:
    """Centralized [10]-style coreset of ``t`` samples + ``k`` solution
    centers on a weighted instance. Output size t + k."""
    return _build_coreset(key, points, weights, k=k, t=t,
                          objective=objective_mod.resolve_name(objective),
                          lloyd_iters=lloyd_iters,
                          clip_negative=clip_negative,
                          backend=backend_mod.resolve_name(backend))


@functools.partial(
    jax.jit, static_argnames=("k", "t", "objective", "lloyd_iters",
                              "clip_negative", "backend"))
def _build_coreset(key, points, weights, k, t, objective, lloyd_iters,
                   clip_negative, backend):
    n = points.shape[0]
    w = jnp.ones((n,), points.dtype) if weights is None else weights
    # solve the approximation B on the non-negative part of the measure
    # (identity for mask/raw instances); optimizing centers against
    # negative mass admits spurious minima (DESIGN.md Sec. 9). The signed
    # w stays authoritative for sensitivities and the weight identities.
    w_solve = jnp.maximum(w, 0.0)
    key, ks = jax.random.split(key)
    centers = clustering.kmeans_pp_init(key, points, k, weights=w_solve,
                                        objective=objective, backend=backend)
    centers, _ = clustering.lloyd(points, centers, weights=w_solve,
                                  iters=lloyd_iters, objective=objective,
                                  backend=backend)
    m, assign, w_eff = sensitivities(points, centers, w, objective=objective,
                                     backend=backend)
    total_m = jnp.sum(m)
    sampled, w_s, w_b = _sample_and_weight(
        ks, points, m, w_eff, assign, k, jnp.asarray(t), t, total_m,
        jnp.asarray(float(t)))
    if clip_negative:
        w_b = jnp.maximum(w_b, 0.0)
    return Coreset.concat(Coreset(sampled, w_s), Coreset(centers, w_b))


def merge_coresets(
    key: Array,
    a: Coreset,
    b: Coreset,
    k: int,
    t: int,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 5,
    backend: BackendLike = None,
) -> Coreset:
    """Merge-and-reduce step: re-run sensitivity sampling on the union of
    two summaries. This is the reduction of the streaming coreset tree
    (``repro.stream.tree``); composability of eps-coresets (union of
    coresets is a coreset of the union) makes it sound, and the signed
    weights of ``a``/``b`` are handled by the |w| sampling mass in
    :func:`sensitivities`. Output size t + k regardless of input sizes."""
    u = Coreset.concat(a, b)
    return build_coreset(key, u.points, k, t, weights=u.weights,
                         objective=objective, lloyd_iters=lloyd_iters,
                         backend=backend)


def proportional_allocation(costs: Array, t: int) -> Array:
    """Largest-remainder allocation of ``t`` samples proportional to local
    costs: sum_i t_i == t exactly, t_i >= 0, t_i ~= t * cost_i / sum_j cost_j.

    Degenerate all-zero costs (every site already solves its data exactly)
    fall back to the uniform allocation -- the sum-to-``t`` invariant must
    hold for any input, since Round 2 draws exactly ``t_i`` samples.

    The remainder correction is sign-safe: float error in ``t * cost_i /
    total`` can drive ``rem = t - sum(floor(frac))`` *negative* at extreme
    cost scales (every fraction rounding up), and the one-sided bonus would
    then leave ``sum(t_i) > t``. A negative remainder is taken back from
    the sites with the smallest fractional parts, capped per-site at its
    floor so no allocation goes negative (greedy over the sorted capacity
    prefix -- total capacity is ``sum(base) = t - rem >= -rem``, so the
    take-back always completes). The positive branch likewise survives
    ``rem > n_sites`` (uniform ``rem // n`` plus largest-remainder on the
    rest)."""
    n_sites = costs.shape[0]
    total = jnp.sum(costs)
    # ratio-first: costs/total <= 1 never overflows, while t*costs can hit
    # inf around 1e36 in f32 (an inf fraction floors to garbage and drives
    # the remainder arbitrarily negative)
    frac = jnp.where(total > _TINY,
                     t * (costs / jnp.maximum(total, _TINY)),
                     jnp.full_like(costs, t / n_sites))
    base = jnp.floor(frac)
    rem = t - jnp.sum(base).astype(jnp.int32)
    fr = frac - base
    # rem > 0: rank sites by fractional part, award the remainder to the
    # top-`rem` (cycling via // when rem exceeds n_sites)
    rank_hi = jnp.argsort(jnp.argsort(-fr))
    pos = jnp.maximum(rem, 0)
    award = pos // n_sites + (rank_hi < pos % n_sites).astype(jnp.int32)
    # rem < 0: take back from the smallest fractional parts first, at most
    # `base_i` each (keeps t_i >= 0); greedy prefix over sorted capacities
    need = jnp.maximum(-rem, 0)
    order = jnp.argsort(fr)
    cap = base[order].astype(jnp.int32)
    before = jnp.cumsum(cap) - cap
    take_sorted = jnp.clip(need - before, 0, cap)
    take = jnp.zeros_like(cap).at[order].set(take_sorted)
    return base.astype(jnp.int32) + award - take


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["points", "weights", "t_i", "local_costs"],
                   meta_fields=[])
@dataclasses.dataclass
class DistributedCoreset:
    """Per-site local portions (Algorithm 1 output, before any sharing).

    ``points``: (n_sites, t_buffer + k, d); ``weights``: (n_sites, t_buffer+k)
    with exact zeros on invalid slots; ``t_i``: realized per-site sample
    counts; ``local_costs``: cost(P_i, B_i) -- the Round-1 scalars.
    """

    points: Array
    weights: Array
    t_i: Array
    local_costs: Array

    def flatten(self) -> Coreset:
        d = self.points.shape[-1]
        return Coreset(points=self.points.reshape(-1, d),
                       weights=self.weights.reshape(-1))


def distributed_coreset(
    key: Array,
    site_points: Array,          # (n_sites, M, d) padded
    site_mask: Array,            # (n_sites, M) bool
    k: int,
    t: int,
    t_buffer: Optional[int] = None,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 5,
    clip_negative: bool = False,
    backend: BackendLike = None,
    site_weights: Optional[Array] = None,   # (n_sites, M) overrides mask
    strategy: "strategy_mod.StrategyLike" = None,
) -> DistributedCoreset:
    """The distributed coreset rounds over all sites at once (vmapped host
    simulation), driven by a registered
    :class:`~repro.core.strategy.CoresetStrategy` (default
    ``"algorithm1"``, the paper's protocol -- bit-identical to the
    pre-strategy-layer implementation).

    For exchanging strategies the only cross-site quantities used are
    ``local_costs`` (Round 1: n scalars) and their sum -- exactly the
    paper's communication pattern; single-shuffle strategies
    (``"mapreduce"``) use none at all. The SPMD/mesh execution of the same
    math lives in :mod:`repro.core.distributed`.

    ``site_weights`` generalizes each site's instance from masked raw points
    to an arbitrary *weighted* (possibly signed) local summary -- the
    streaming aggregation rounds run Algorithm 1 over per-site coreset-tree
    summaries this way. When given, ``site_mask`` is ignored (a zero weight
    is an invalid slot).
    """
    from repro.core import strategy as strategy_mod
    t_buffer = t if t_buffer is None else t_buffer
    backend = backend_mod.resolve_name(backend)
    objective = objective_mod.resolve_name(objective)
    strat = strategy_mod.get_strategy(strategy)
    n_sites = site_points.shape[0]
    w_site = (site_mask.astype(site_points.dtype) if site_weights is None
              else site_weights.astype(site_points.dtype))
    keys = strat.keys(key, n_sites)

    r1 = strat.summary(keys[:, 0], site_points, w_site, k=k,
                       objective=objective, lloyd_iters=lloyd_iters,
                       backend=backend)
    local_costs = r1.local_costs

    # -- the single communicated aggregate (exchanging strategies only) ------
    # (the topology execution engine in repro.core.distributed runs these
    # same two stages but moves local_costs / the portions through executed
    # message-passing rounds instead of touching them globally here)
    t_i = strat.allocate(local_costs, t)
    if strat.needs_exchange:
        totals = jnp.broadcast_to(jnp.sum(local_costs), (n_sites,))
    else:
        totals = strat.local_totals(local_costs)

    portions = strat.contribute(keys[:, 1], site_points, r1, t_i, totals,
                                k=k, t=t, t_buffer=t_buffer,
                                clip_negative=clip_negative)
    return DistributedCoreset(points=portions.points,
                              weights=portions.weights, t_i=t_i,
                              local_costs=local_costs)


# ---------------------------------------------------------------------------
# staged Round-1/Round-2 engine (per-site dispatch instead of lockstep vmap)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagedDetail:
    """Measurement sidecar of :func:`staged_distributed_coreset`.

    ``site_lengths``: the per-site padded solve lengths actually compiled
    (all equal to the lockstep pad length M unless ``site_buckets``);
    ``iters_run``: per-site realized refinement passes (== ``lloyd_iters``
    everywhere unless ``tol > 0`` let a site exit early); the walls split
    Round 1 (dispatch + solves until every exchange scalar is on host)
    from Round 2 (allocation + finalize)."""

    site_lengths: Tuple[int, ...]
    iters_run: Array
    wall_round1_s: float
    wall_round2_s: float
    wall_total_s: float


@functools.partial(
    jax.jit, static_argnames=("k", "objective", "lloyd_iters", "tol",
                              "backend", "strategy"))
def _staged_solve_site(key, pts, w, k, objective, lloyd_iters, tol, backend,
                       strategy):
    """One site's Round-1 stage, unbatched: same math as the vmapped
    ``local_solve`` of :func:`round1_local_solves` (bit-identical at
    ``tol == 0``), plus the strategy's sampling-mass rule and the realized
    refinement-pass count."""
    from repro.core import strategy as strategy_mod
    strat = strategy_mod.get_strategy(strategy)
    w_solve = jnp.maximum(w, 0.0)
    centers = clustering.kmeans_pp_init(key, pts, k, weights=w_solve,
                                        objective=objective, backend=backend)
    centers, iters_run = clustering.lloyd_converged(
        pts, centers, weights=w_solve, iters=lloyd_iters, tol=tol,
        objective=objective, backend=backend)
    m, assign, w_eff = strat.site_sensitivities(pts, centers, w,
                                                objective=objective,
                                                backend=backend)
    return centers, m, assign, jnp.sum(m), w_eff, iters_run


@functools.partial(jax.jit, static_argnames=("k", "t_buffer"))
def _staged_round2_precompute(key, pts, m, w_eff, assign, k, t_buffer):
    """The allocation-independent prefix of :func:`_sample_and_weight`:
    the ``t_buffer`` draws, their masses/weights/assignments, and the
    per-cluster weight totals depend only on Round-1 locals -- so a site
    can run this *before* its ``t_i`` arrives, overlapping slower sites'
    Round-1 solves. Expressions match ``_sample_and_weight`` term for term
    (bit-parity contract; DESIGN.md Sec. 17)."""
    idx = weighted_choice(key, m, t_buffer)
    m_q = m[idx]
    w_idx = w_eff[idx]
    sampled = pts[idx]
    sampled_assign = assign[idx]
    oh = jax.nn.one_hot(assign, k, dtype=pts.dtype)
    w_pb = (w_eff[:, None] * oh).sum(0)
    return sampled, m_q, w_idx, sampled_assign, w_pb


@functools.partial(jax.jit,
                   static_argnames=("k", "t_buffer", "clip_negative"))
def _staged_round2_finalize(sampled, m_q, w_idx, sampled_assign, w_pb,
                            centers, t_local, total_m, t_total, k, t_buffer,
                            clip_negative):
    """The allocation-dependent suffix of :func:`_sample_and_weight` +
    portion assembly: validity mask, sample weights, residual center
    weights, concat. Cheap (O(t_buffer + k)); runs after the exchange."""
    valid = (jnp.arange(t_buffer) < t_local) & (total_m > _TINY)
    w_s = jnp.where(
        valid & (m_q > _TINY),
        total_m * w_idx / (jnp.maximum(t_total, 1.0)
                           * jnp.maximum(m_q, _TINY)),
        0.0,
    )
    w_sb = jnp.zeros((k,), sampled.dtype).at[sampled_assign].add(w_s)
    w_b = w_pb - w_sb
    if clip_negative:
        w_b = jnp.maximum(w_b, 0.0)
    return (jnp.concatenate([sampled, centers], axis=0),
            jnp.concatenate([w_s, w_b], axis=0))


def _site_valid_lengths(w_site: Array) -> Tuple[int, ...]:
    """Per-site count covering every nonzero-weight slot (1 + its last
    index). ``pad_partition`` packs valid slots first, so this equals the
    true site size there; arbitrary weighted summaries stay covered
    because slicing ``[:count]`` keeps every weight-carrying slot."""
    w = np.asarray(w_site)
    nz = (w != 0.0)[:, ::-1].argmax(axis=1)
    any_nz = (w != 0.0).any(axis=1)
    return tuple(int(w.shape[1] - z) if a else 1
                 for z, a in zip(nz, any_nz))


def staged_distributed_coreset(
    key: Array,
    site_points: Array,          # (n_sites, M, d) padded
    site_mask: Array,            # (n_sites, M) bool
    k: int,
    t: int,
    t_buffer: Optional[int] = None,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 5,
    clip_negative: bool = False,
    backend: BackendLike = None,
    site_weights: Optional[Array] = None,
    strategy: "strategy_mod.StrategyLike" = None,
    tol: float = 0.0,
    site_buckets: bool = False,
    min_bucket: int = 64,
) -> Tuple[DistributedCoreset, StagedDetail]:
    """:func:`distributed_coreset` with Round 1 broken out of the lockstep
    vmap: sites are dispatched one jitted solve at a time, each site's
    Round-1 scalar starts moving to the allocator the moment its own solve
    converges (async device-to-host copy), and its allocation-independent
    Round-2 sampling prefix (:func:`_staged_round2_precompute`) is
    interleaved between the following site's fused ``lloyd_stats`` /
    ``weiszfeld_stats`` passes -- double-buffered dispatch, so fast sites'
    Round-2 work overlaps slow sites' refinement. Only the validity mask /
    weight scaling / portion assembly (:func:`_staged_round2_finalize`)
    waits for the exchange barrier -- and for single-shuffle strategies
    the allocation is locally derivable, so even that runs inside the
    dispatch loop with no barrier at all.

    Two knobs trade strictness for wall-clock (DESIGN.md Sec. 17):

    * ``tol`` -- early-exit threshold for the local refinement
      (:func:`~repro.core.clustering.lloyd_converged`). ``0.0`` keeps the
      lockstep iteration count.
    * ``site_buckets`` -- solve each site at its own power-of-two padded
      length (:func:`repro.kernels.ops.site_bucket_lengths`) instead of
      the lockstep pad M, so small sites stop paying the largest site's
      FLOPs. Changes draw indices (the sampling CDF has fewer slots), so
      results are deterministic but not bit-equal to lockstep.

    With both off (the default, "strict" mode) every output field of the
    returned :class:`DistributedCoreset` is bit-identical to
    :func:`distributed_coreset` for every registered strategy -- the
    frozen ``algorithm1`` key-derivation and digest contracts survive
    because the key table, draw indices, and weight formulas are shared
    term for term.

    Returns ``(coreset, StagedDetail)`` -- the sidecar carries per-phase
    walls and realized per-site lengths/iterations for
    ``bench_collectives``.
    """
    from repro.core import strategy as strategy_mod
    from repro.kernels.ops import site_bucket_lengths
    t_buffer = t if t_buffer is None else t_buffer
    backend = backend_mod.resolve_name(backend)
    objective = objective_mod.resolve_name(objective)
    strategy = strategy_mod.resolve_name(strategy)
    strat = strategy_mod.get_strategy(strategy)
    n_sites, M = site_points.shape[0], site_points.shape[1]
    w_site = (site_mask.astype(site_points.dtype) if site_weights is None
              else site_weights.astype(site_points.dtype))
    lengths = (site_bucket_lengths(_site_valid_lengths(w_site), M,
                                   min_bucket=min_bucket)
               if site_buckets else (M,) * n_sites)
    keys = strat.keys(key, n_sites)
    tol = float(tol)

    if not strat.needs_exchange:
        # locally derivable split: no barrier anywhere in the loop below
        t_i = strat.allocate(jnp.ones((n_sites,), site_points.dtype), t)
        t_totals = strat.sample_t_total(t, t_i)

    t0 = time.perf_counter()
    solves: list = []
    pre: list = []
    final: list = []

    def dispatch_round2(i):
        c_i, m_i, a_i, cost_i, w_eff_i, _ = solves[i]
        pre.append(_staged_round2_precompute(
            keys[i, 1], site_points[i, :lengths[i]], m_i, w_eff_i, a_i,
            k=k, t_buffer=t_buffer))
        if not strat.needs_exchange:
            final.append(_staged_round2_finalize(
                *pre[i], c_i, t_i[i], cost_i, t_totals[i], k=k,
                t_buffer=t_buffer, clip_negative=clip_negative))

    for i in range(n_sites):
        solves.append(_staged_solve_site(
            keys[i, 0], site_points[i, :lengths[i]], w_site[i, :lengths[i]],
            k=k, objective=objective, lloyd_iters=lloyd_iters, tol=tol,
            backend=backend, strategy=strategy))
        # the site's Round-1 scalar starts its exchange immediately ...
        solves[-1][3].copy_to_host_async()
        # ... and the previous site's Round-2 prefix overlaps this solve
        if i:
            dispatch_round2(i - 1)
    dispatch_round2(n_sites - 1)

    local_costs = jnp.stack([s[3] for s in solves])
    jax.block_until_ready(local_costs)
    wall_r1 = time.perf_counter() - t0

    t1 = time.perf_counter()
    if strat.needs_exchange:
        t_i = strat.allocate(local_costs, t)
        totals = jnp.broadcast_to(jnp.sum(local_costs), (n_sites,))
        t_totals = strat.sample_t_total(t, t_i)
        for i in range(n_sites):
            final.append(_staged_round2_finalize(
                *pre[i], solves[i][0], t_i[i], totals[i], t_totals[i],
                k=k, t_buffer=t_buffer, clip_negative=clip_negative))
    points = jnp.stack([f[0] for f in final])
    weights = jnp.stack([f[1] for f in final])
    jax.block_until_ready(weights)
    wall_r2 = time.perf_counter() - t1

    detail = StagedDetail(
        site_lengths=lengths,
        iters_run=jnp.stack([s[5] for s in solves]),
        wall_round1_s=wall_r1, wall_round2_s=wall_r2,
        wall_total_s=wall_r1 + wall_r2)
    return (DistributedCoreset(points=points, weights=weights, t_i=t_i,
                               local_costs=local_costs), detail)


@functools.partial(
    jax.jit, static_argnames=("k", "objective", "lloyd_iters", "backend"))
def round1_local_solves(keys, site_points, w_site, k, objective, lloyd_iters,
                        backend):
    """Algorithm 1 Round 1, the purely-local stage: every site solves its
    own weighted instance. Returns (centers (n,k,d), sensitivities m (n,M),
    assignments (n,M), local_costs (n,), w_eff (n,M)) -- ``local_costs``
    are the only values any communication round needs to move, and
    ``w_eff`` are the objective's effective weights Round 2 must sample
    and center-weight with (identical to ``w_site`` for plain objectives;
    zeroed on trimmed-out points for trimmed ones). Shared verbatim by the
    host-simulation path, the topology execution engine, and the streaming
    aggregation rounds, so their numerics are identical by construction."""

    def local_solve(ki, pts, w):
        # as in _build_coreset: solve B_i on max(w, 0) (identity for masked
        # sites), signed w for the sensitivities
        w_solve = jnp.maximum(w, 0.0)
        centers = clustering.kmeans_pp_init(ki, pts, k, weights=w_solve,
                                            objective=objective,
                                            backend=backend)
        centers, _ = clustering.lloyd(pts, centers, weights=w_solve,
                                      iters=lloyd_iters, objective=objective,
                                      backend=backend)
        m, assign, w_eff = sensitivities(pts, centers, w,
                                         objective=objective,
                                         backend=backend)
        return centers, m, assign, w_eff

    centers, m, assign, w_eff = jax.vmap(local_solve)(
        keys, site_points, w_site)
    # costs == trimmed/plain cost(P_i, B_i) in the objective's own metric
    return centers, m, assign, m.sum(axis=1), w_eff


@functools.partial(
    jax.jit, static_argnames=("k", "t", "t_buffer", "clip_negative"))
def round2_local_samples(keys, site_points, m, w_eff, assign, centers, t_i,
                         total_m, k, t, t_buffer, clip_negative):
    """Algorithm 1 Round 2, the purely-local stage: every site draws its
    ``t_i`` samples and assembles its portion S_i u B_i. ``w_eff`` are the
    Round-1 effective weights (raw site weights for plain objectives).
    ``total_m`` is per-site (n,) -- each site uses the global sensitivity
    total *it received* (all entries are bit-identical copies on every
    path, but the execution engine genuinely delivers one per node)."""

    def local_sample(ki, pts, m_i, w_i, a_i, ti, tm):
        return _sample_and_weight(ki, pts, m_i, w_i, a_i, k, ti, t_buffer,
                                  tm, jnp.asarray(float(t)))

    sampled, w_s, w_b = jax.vmap(local_sample)(
        keys, site_points, m, w_eff, assign, t_i, total_m)
    if clip_negative:
        w_b = jnp.maximum(w_b, 0.0)
    # per-site portion S_i u B_i, stitched via the shared mask-aware union
    return jax.vmap(Coreset.concat)(Coreset(sampled, w_s),
                                    Coreset(centers, w_b))


@functools.partial(
    jax.jit, static_argnames=("k", "t_buffer", "clip_negative"))
def round2_local_samples_localized(keys, site_points, m, w_eff, assign,
                                   centers, t_i, total_m, k, t_buffer,
                                   clip_negative):
    """Round 2 with *per-site* normalization: each site's weight formula
    uses its own sensitivity total (``total_m`` carries each site's own
    scalar) and its own realized draw count ``t_i`` -- the site's portion
    is a standalone coreset of its local data, no cross-site quantity
    anywhere. This is the mapreduce strategy's local stage
    (:mod:`repro.core.strategy`); composability of eps-coresets makes the
    union of the portions a coreset of the union."""

    def local_sample(ki, pts, m_i, w_i, a_i, ti, tm):
        return _sample_and_weight(ki, pts, m_i, w_i, a_i, k, ti, t_buffer,
                                  tm, ti.astype(jnp.float32))

    sampled, w_s, w_b = jax.vmap(local_sample)(
        keys, site_points, m, w_eff, assign, t_i, total_m)
    if clip_negative:
        w_b = jnp.maximum(w_b, 0.0)
    return jax.vmap(Coreset.concat)(Coreset(sampled, w_s),
                                    Coreset(centers, w_b))
