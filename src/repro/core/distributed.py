"""Algorithm 2 -- distributed clustering, end to end.

Three execution paths over the same math:

* :func:`graph_distributed_kmeans` -- Algorithm 2 over an arbitrary
  ``Graph``. ``engine="sim"`` is the host-level oracle with an *analytic*
  :class:`CommLedger` (Theorem 2 accounting); ``engine="exec"`` routes the
  identical math through the topology execution engine
  (:mod:`repro.core.message_passing`): the Round-1 scalars and Round-2
  portions physically move through jitted flood rounds, every node ends
  holding the bit-identical global coreset, and the returned ledger is
  *measured* from the executed schedule (it equals the analytic one
  exactly -- tests assert this).
* :func:`distributed_kmeans_tree` -- same over a rooted spanning tree
  (Theorem 3 accounting: everything moves O(h) edges, no flooding), with
  the same ``engine="sim"|"exec"`` choice (gather/scatter/broadcast tree
  schedules).
* :func:`spmd_distributed_kmeans` -- the production SPMD path: sites are
  devices along a mesh axis; ``collectives="all_gather"`` shares Round 1's
  scalars and Round 2's portions via ``lax.all_gather``, while
  ``collectives="neighbor_rounds"`` swaps both gathers for the explicit
  ring ``ppermute`` primitives of Algorithm 3
  (:func:`~repro.core.message_passing.neighbor_rounds_gather`) --
  bit-identical results, neighbour-only traffic. Runs under ``shard_map``
  on real meshes (and under the 512-device dry run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core import objective as objective_mod
from repro.core import strategy as strategy_mod
from repro.core.backend import BackendLike
from repro.core.objective import ObjectiveLike
from repro.core.strategy import StrategyLike
from repro.core.comm import (CommLedger, flood_cost, flood_portions_cost,
                             tree_allocation_cost, tree_broadcast_cost,
                             tree_up_cost)
from repro.core.coreset import (Coreset, DistributedCoreset,
                                distributed_coreset, proportional_allocation,
                                round1_local_solves, round2_local_samples,
                                sensitivities, _sample_and_weight)
from repro.core.message_passing import (ExecResult, GossipSchedule,
                                        TreeSchedule, flood_exec,
                                        gossip_schedule,
                                        neighbor_rounds_gather, pack_payload,
                                        torus_mesh_shape, torus_rounds_gather,
                                        tree_broadcast_exec, tree_gather_exec,
                                        tree_scatter_exec, unpack_payload)
from repro.core.topology import Graph, SpanningTree, spanning_tree

from repro.compat import shard_map as _shard_map

Array = jax.Array


@dataclasses.dataclass
class ExecDetail:
    """Per-node state after the executed communication rounds -- the
    verification surface for engine-vs-simulation parity tests.

    Graph engine: ``node_points``/``node_weights`` are every node's
    assembled global coreset (n, n*S, d) / (n, n*S) and ``node_alloc`` the
    (n, n) allocation vector each node computed from its received scalars
    (all rows bit-identical). Tree engine: ``node_centers`` (n, k, d) holds
    the solution every node received from the root's broadcast and
    ``node_alloc`` the (n,) per-node allocations delivered by the scatter.
    ``node_totals`` is the global cost total as known at each node."""

    node_points: Optional[Array] = None
    node_weights: Optional[Array] = None
    node_centers: Optional[Array] = None
    node_alloc: Optional[Array] = None
    node_totals: Optional[Array] = None
    rounds: Dict[str, ExecResult] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ClusteringResult:
    centers: Array
    coreset: Coreset
    ledger: CommLedger
    local_costs: Array
    exec_detail: Optional[ExecDetail] = None


def _solve_on_coreset(key: Array, cs: Coreset, k: int, objective: str,
                      lloyd_iters: int, backend: BackendLike = None) -> Array:
    centers = clustering.kmeans_pp_init(key, cs.points, k,
                                        weights=jnp.maximum(cs.weights, 0.0),
                                        objective=objective, backend=backend)
    centers, _ = clustering.lloyd(cs.points, centers, weights=cs.weights,
                                  iters=lloyd_iters, objective=objective,
                                  backend=backend)
    return centers


def graph_distributed_kmeans(
    key: Array,
    site_points: Array,
    site_mask: Array,
    k: int,
    t: int,
    graph: Graph,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 8,
    backend: BackendLike = None,
    engine: str = "sim",
    routing: str = "flood",
    root: int = 0,
    faults=None,
    wan_mode: Optional[str] = None,
    wan_seed: int = 0,
    wan_p: float = 0.5,
    strategy: StrategyLike = None,
) -> ClusteringResult:
    """Algorithm 2 on a general graph. With the default ``routing="flood"``
    Round 1 floods n scalars (2mn messages) and Round 2 floods the n local
    portions (2m * sum_i |D_i| points); every node then solves the
    identical weighted instance. ``routing="bfs"`` / ``"min_cost"``
    restrict communication to a spanning tree of the graph (hop-minimal
    BFS vs Prim over ``edge_costs``) rooted at ``root`` and run the
    Theorem-3 tree protocol instead -- same math, same centers, but the
    ledger prices only tree edges; on heterogeneous links min-cost routing
    is what makes the cost-weighted ledger (``link_cost``) small.

    ``engine="sim"`` computes the rounds globally and prices them with the
    analytic Theorem-2 ledger (the oracle). ``engine="exec"`` executes them
    on a compiled :class:`GossipSchedule` -- same local stages, same keys,
    so the result is bit-identical, but the scalars and portions physically
    move edge by edge and the ledger is measured from the schedule.

    ``engine="async"`` routes both rounds through the WAN runtime
    (:mod:`repro.wan.runtime`): asynchronous activation (``wan_mode``:
    ``"clock"`` default, or ``"random"``/``"full"``; ``wan_seed`` /
    ``wan_p`` parameterize it) and an optional ``faults=``
    :class:`~repro.wan.faults.FaultPlan`. Passing ``faults`` with
    ``engine="exec"`` runs the synchronous schedule under the fault plan
    (WAN mode ``"full"``). Either way the allocation and coreset are
    restricted to surviving sites and the returned centers are
    bit-identical to the sim oracle restricted to the survivors
    (:func:`repro.wan.runtime.restricted_sim_coreset`); the measured
    ledger carries the ``staleness`` axis. Flood routing only."""
    objective = objective_mod.resolve_name(objective)
    strategy = strategy_mod.resolve_name(strategy)
    strat = strategy_mod.get_strategy(strategy)
    if faults is not None or engine == "async":
        if routing != "flood":
            raise ValueError(f"faulty/async runs support routing='flood' "
                             f"only, got {routing!r}")
        if engine not in ("exec", "async"):
            raise ValueError(f"faults require engine='exec'|'async', got "
                             f"{engine!r} (the fault-free sim oracle is "
                             f"repro.wan.runtime.restricted_sim_coreset)")
        mode = wan_mode if wan_mode is not None else (
            "full" if engine == "exec" else "clock")
        return _graph_async(key, site_points, site_mask, k, t, graph,
                            objective, lloyd_iters, backend, mode=mode,
                            faults=faults, seed=wan_seed, p=wan_p,
                            strategy=strategy)
    if not strat.needs_exchange and routing == "flood":
        # single-shuffle strategies never flood: with no scalar round to
        # disseminate, the portions move map->shuffle->reduce along a
        # hop-minimal spanning tree (Theorem-3 pricing on tree edges only)
        routing = "bfs"
    if routing in ("bfs", "min_cost"):
        tree = spanning_tree(graph, root=root, routing=routing)
        return distributed_kmeans_tree(key, site_points, site_mask, k, t,
                                       tree, objective=objective,
                                       lloyd_iters=lloyd_iters,
                                       backend=backend, engine=engine,
                                       strategy=strategy)
    if routing != "flood":
        raise ValueError(f"unknown routing {routing!r}: expected "
                         f"'flood'|'bfs'|'min_cost'")
    if engine == "exec":
        return _graph_exec(key, site_points, site_mask, k, t, graph,
                           objective, lloyd_iters, backend, strategy)
    if engine != "sim":
        raise ValueError(f"unknown engine {engine!r}: expected 'sim'|'exec'")
    n_sites, _, d = site_points.shape
    backend = backend_mod.resolve_name(backend)
    k1, k2 = jax.random.split(key)
    dc = distributed_coreset(k1, site_points, site_mask, k, t,
                             objective=objective, lloyd_iters=lloyd_iters,
                             backend=backend, strategy=strategy)
    cs = dc.flatten()
    centers = _solve_on_coreset(k2, cs, k, objective, lloyd_iters, backend)

    spec = strat.exchange_spec()
    ledger = flood_cost(graph, n_messages=graph.n,
                        unit_scalars=spec.unit_scalars).tag("round1")
    ledger = ledger.add(flood_portions_cost(graph, np.asarray(dc.t_i), k,
                                            d).tag("round2"))
    return ClusteringResult(centers, cs, ledger, dc.local_costs)


# the original name stays as an alias (the sim path was the only mode once)
distributed_kmeans = graph_distributed_kmeans


def exec_algorithm1_rounds(
    sched: GossipSchedule,
    key: Array,
    site_points: Array,
    w_site: Array,
    k: int,
    t: int,
    t_buffer: int,
    objective: str,
    lloyd_iters: int,
    clip_negative: bool,
    backend: str,
    strategy: StrategyLike = None,
) -> Tuple[ExecDetail, Array]:
    """A strategy's two rounds with the communication *executed* on a
    gossip schedule. Same descriptor hooks and key derivation as
    ``distributed_coreset``, so every node's assembled coreset is
    bit-identical to the host path's; the ``ExecDetail`` ledgers are
    measured per transmission. Shared by :func:`graph_distributed_kmeans`
    and the streaming aggregation rounds. Exchange strategies only: a
    single-shuffle strategy has no scalar round to flood, so it routes to
    the tree protocol instead (:func:`graph_distributed_kmeans` reroutes).
    Returns (detail, local_costs)."""
    strat = strategy_mod.get_strategy(strategy)
    if not strat.needs_exchange:
        raise ValueError(
            f"strategy {strat.name!r} has no exchange round; the gossip "
            f"flood engine only runs exchange strategies (single-shuffle "
            f"strategies run the tree protocol)")
    n_sites, _, d = site_points.shape
    keys = strat.keys(key, n_sites)

    r1 = strat.summary(keys[:, 0], site_points, w_site, k=k,
                       objective=objective, lloyd_iters=lloyd_iters,
                       backend=backend)
    local_costs = r1.local_costs

    # -- Round 1 executed: flood the n exchange scalars ----------------------
    spec = strat.exchange_spec()
    cost_tables, r1x = flood_exec(sched, local_costs[:, None],
                                  unit_scalars=spec.unit_scalars)
    costs_at = cost_tables[:, :, 0]                        # (node, origin)
    node_alloc = jax.vmap(lambda c: strat.allocate(c, t))(costs_at)
    t_i = jnp.diagonal(node_alloc)            # node v uses its own share
    node_totals = jax.vmap(jnp.sum)(costs_at)

    portions = strat.contribute(
        keys[:, 1], site_points, r1, t_i, node_totals, k=k, t=t,
        t_buffer=t_buffer, clip_negative=clip_negative)

    # -- Round 2 executed: flood the fixed-size local portions ---------------
    payload = pack_payload(portions.points, portions.weights)
    unit_pts = (np.asarray(t_i) + k).astype(np.float64)
    port_tables, r2 = flood_exec(sched, payload, unit_points=unit_pts,
                                 dim=d)
    slots = payload.shape[1]
    node_pts, node_w = unpack_payload(port_tables)
    detail = ExecDetail(
        node_points=node_pts.reshape(n_sites, n_sites * slots, d),
        node_weights=node_w.reshape(n_sites, n_sites * slots),
        node_alloc=node_alloc, node_totals=node_totals,
        rounds={"round1": r1x, "round2": r2})
    return detail, local_costs


def _graph_exec(key, site_points, site_mask, k, t, graph, objective,
                lloyd_iters, backend,
                strategy: StrategyLike = None) -> ClusteringResult:
    """Execute Algorithm 2's communication on a compiled gossip schedule.

    Identical math to the sim path stage for stage (same key derivation,
    same jitted stage functions), but the n Round-1 scalars and the n
    Round-2 portions move through executed flood rounds: every node ends
    holding bit-identical copies of all n cost scalars (from which it
    replays the exact largest-remainder allocation locally) and of the
    global coreset. The returned ledger is measured per transmission."""
    n_sites, _, d = site_points.shape
    if graph.n != n_sites:
        raise ValueError(f"graph has {graph.n} nodes for {n_sites} sites")
    backend = backend_mod.resolve_name(backend)
    sched = gossip_schedule(graph)
    k1, k2 = jax.random.split(key)
    detail, local_costs = exec_algorithm1_rounds(
        sched, k1, site_points, site_mask.astype(site_points.dtype), k, t,
        t_buffer=t, objective=objective, lloyd_iters=lloyd_iters,
        clip_negative=False, backend=backend, strategy=strategy)

    # every node holds the identical instance; solve it once (node 0's copy)
    cs = Coreset(detail.node_points[0], detail.node_weights[0])
    centers = _solve_on_coreset(k2, cs, k, objective, lloyd_iters, backend)
    ledger = detail.rounds["round1"].ledger.tag("round1").add(
        detail.rounds["round2"].ledger.tag("round2"))
    return ClusteringResult(centers, cs, ledger, local_costs,
                            exec_detail=detail)


def _graph_async(key, site_points, site_mask, k, t, graph, objective,
                 lloyd_iters, backend, mode, faults, seed, p,
                 strategy: StrategyLike = None) -> ClusteringResult:
    """Execute Algorithm 2's communication on the asynchronous WAN runtime
    (imported lazily -- :mod:`repro.wan` layers on this module).

    Every *surviving* node assembles the bit-identical survivor-restricted
    coreset; the solve uses the first survivor's copy with the same final
    key split as every other engine, so on a trivial fault plan the
    centers equal the synchronous paths' bit-for-bit, and under faults
    they equal the restricted sim oracle's. ``exec_detail`` holds the
    :class:`repro.wan.runtime.AsyncDetail` (survivor-indexed)."""
    from repro.wan.runtime import async_algorithm1_rounds

    n_sites, _, d = site_points.shape
    if graph.n != n_sites:
        raise ValueError(f"graph has {graph.n} nodes for {n_sites} sites")
    backend = backend_mod.resolve_name(backend)
    k1, k2 = jax.random.split(key)
    detail, local_costs = async_algorithm1_rounds(
        graph, k1, site_points, site_mask.astype(site_points.dtype), k, t,
        t_buffer=t, objective=objective, lloyd_iters=lloyd_iters,
        clip_negative=False, backend=backend, mode=mode, faults=faults,
        seed=seed, p=p, strategy=strategy)

    cs = Coreset(detail.node_points[0], detail.node_weights[0])
    centers = _solve_on_coreset(k2, cs, k, objective, lloyd_iters, backend)
    ledger = detail.rounds["round2"].ledger.tag("round2")
    if "round1" in detail.rounds:   # single-shuffle strategies skip it
        ledger = detail.rounds["round1"].ledger.tag("round1").add(ledger)
    return ClusteringResult(centers, cs, ledger, local_costs,
                            exec_detail=detail)


def distributed_kmeans_tree(
    key: Array,
    site_points: Array,
    site_mask: Array,
    k: int,
    t: int,
    tree: SpanningTree,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 8,
    backend: BackendLike = None,
    engine: str = "sim",
    strategy: StrategyLike = None,
) -> ClusteringResult:
    """Algorithm 2 restricted to a rooted tree (Theorem 3): the raw cost
    scalars are gathered to the root along parent edges (sum_v depth(v)
    scalars), the root replays the exact largest-remainder allocation and
    scatters each site's share back down its subtree path (sum_v depth(v)
    scalars) plus broadcasts the cost total (n-1 scalars); portions travel
    depth(v) edges to the root, and the solution (k points) is broadcast
    back.

    (The 2(n-1)-scalar up-sum-only accounting previously used here priced a
    protocol that cannot compute the exact allocation: largest-remainder
    needs all n scalars at one place, and a tree-structured partial-sum
    reduction neither delivers them nor reproduces the host's float-exact
    total. The ledger now prices the executable gather/scatter protocol --
    the ``engine="exec"`` path runs it and measures the same numbers.)"""
    objective = objective_mod.resolve_name(objective)
    strategy = strategy_mod.resolve_name(strategy)
    strat = strategy_mod.get_strategy(strategy)
    if engine == "exec":
        return _tree_exec(key, site_points, site_mask, k, t, tree,
                          objective, lloyd_iters, backend, strategy)
    if engine != "sim":
        raise ValueError(f"unknown engine {engine!r}: expected 'sim'|'exec'")
    n_sites, _, d = site_points.shape
    backend = backend_mod.resolve_name(backend)
    k1, k2 = jax.random.split(key)
    dc = distributed_coreset(k1, site_points, site_mask, k, t,
                             objective=objective, lloyd_iters=lloyd_iters,
                             backend=backend, strategy=strategy)
    cs = dc.flatten()
    centers = _solve_on_coreset(k2, cs, k, objective, lloyd_iters, backend)

    t_i = [float(x) for x in dc.t_i]
    per_node = [t_i[v] + k for v in range(tree.n)]
    up = tree_up_cost(tree, per_node, dim=d).tag("round2_gather")
    if strat.needs_exchange:
        ledger = tree_allocation_cost(tree).tag("round1").add(up)
    else:
        # single shuffle: no scalar round, no allocation traffic -- the
        # uniform split is derived locally at every site
        ledger = up
    ledger = ledger.add(tree_broadcast_cost(tree, unit_points=float(k),
                                            dim=d).tag("round2_broadcast"))
    return ClusteringResult(centers, cs, ledger, dc.local_costs)


def exec_algorithm1_tree_rounds(
    sched: TreeSchedule,
    key: Array,
    site_points: Array,
    w_site: Array,
    k: int,
    t: int,
    t_buffer: int,
    objective: str,
    lloyd_iters: int,
    clip_negative: bool,
    backend: str,
    strategy: StrategyLike = None,
):
    """A strategy's two rounds with the communication *executed* on a tree
    schedule. For exchange strategies: gather the raw Round-1 scalars to
    the root, replay the strategy's exact allocation there, scatter each
    site's share down its subtree path, broadcast the total; gather the
    fixed-size Round-2 portions to the root. Single-shuffle strategies
    skip the Round-1 gather/scatter/broadcast entirely -- every site
    derives the identical uniform split locally and normalizes by its own
    scalar -- so the only traffic is the portions gather (map -> shuffle
    -> reduce). Same descriptor hooks and key derivation as
    ``distributed_coreset``, so the root's assembled table is
    bit-identical to the host path's coreset. Shared by
    :func:`distributed_kmeans_tree` and the streaming tree-transport
    aggregation rounds. Returns ``(root_points, root_weights, t_i,
    node_totals, rounds, local_costs)`` where ``rounds`` maps phase label
    to the measured :class:`ExecResult`."""
    strat = strategy_mod.get_strategy(strategy)
    n_sites, _, d = site_points.shape
    keys = strat.keys(key, n_sites)

    r1 = strat.summary(keys[:, 0], site_points, w_site, k=k,
                       objective=objective, lloyd_iters=lloyd_iters,
                       backend=backend)
    local_costs = r1.local_costs

    if strat.needs_exchange:
        # -- Round 1 executed: scalars up, allocations + total down ----------
        spec = strat.exchange_spec()
        root_costs, r1a = tree_gather_exec(sched, local_costs[:, None],
                                           unit_scalars=spec.unit_scalars)
        t_root = strat.allocate(root_costs[:, 0], t)
        total = jnp.sum(root_costs[:, 0])
        own_t, r1b = tree_scatter_exec(sched, t_root[:, None],
                                       unit_scalars=1.0)
        node_totals, r1c = tree_broadcast_exec(sched, total[None],
                                               unit_scalars=1.0)
        t_i = own_t[:, 0]
        totals = node_totals[:, 0]
        rounds = {"round1_gather": r1a, "round1_scatter": r1b,
                  "round1_broadcast": r1c}
    else:
        # no Round-1 traffic at all: the split is locally derivable and
        # each site's weight formula uses its own scalar
        t_i = strat.allocate(local_costs, t)
        totals = strat.local_totals(local_costs)
        rounds = {}

    portions = strat.contribute(
        keys[:, 1], site_points, r1, t_i, totals, k=k, t=t,
        t_buffer=t_buffer, clip_negative=clip_negative)

    # -- Round 2 executed: portions up ---------------------------------------
    payload = pack_payload(portions.points, portions.weights)
    unit_pts = (np.asarray(t_i) + k).astype(np.float64)
    root_table, r2a = tree_gather_exec(sched, payload, unit_points=unit_pts,
                                       dim=d)
    root_pts, root_w = unpack_payload(root_table)
    rounds["round2_gather"] = r2a
    return (root_pts, root_w, t_i, totals, rounds, local_costs)


def _tree_exec(key, site_points, site_mask, k, t, tree, objective,
               lloyd_iters, backend,
               strategy: StrategyLike = None) -> ClusteringResult:
    """Execute Algorithm 2's communication on a compiled tree schedule:
    the Round-1/Round-2 tree protocol of
    :func:`exec_algorithm1_tree_rounds`, then solve at the root and
    broadcast the k centers. Bit-identical to the sim path; measured
    ledger."""
    n_sites, _, d = site_points.shape
    if tree.n != n_sites:
        raise ValueError(f"tree has {tree.n} nodes for {n_sites} sites")
    backend = backend_mod.resolve_name(backend)
    sched = TreeSchedule.from_tree(tree)
    k1, k2 = jax.random.split(key)
    w_site = site_mask.astype(site_points.dtype)

    root_pts, root_w, t_i, node_totals, rounds, local_costs = \
        exec_algorithm1_tree_rounds(
            sched, k1, site_points, w_site, k, t, t_buffer=t,
            objective=objective, lloyd_iters=lloyd_iters,
            clip_negative=False, backend=backend, strategy=strategy)

    cs = Coreset(root_pts.reshape(-1, d), root_w.reshape(-1))
    centers = _solve_on_coreset(k2, cs, k, objective, lloyd_iters, backend)
    node_centers, r2b = tree_broadcast_exec(sched, centers,
                                            unit_points=float(k), dim=d)
    rounds = dict(rounds, round2_broadcast=r2b)

    if "round1_gather" in rounds:
        ledger = (rounds["round1_gather"].ledger
                  .add(rounds["round1_scatter"].ledger)
                  .add(rounds["round1_broadcast"].ledger).tag("round1")
                  .add(rounds["round2_gather"].ledger.tag("round2_gather")))
    else:   # single-shuffle strategies have no Round-1 phases
        ledger = rounds["round2_gather"].ledger.tag("round2_gather")
    ledger = ledger.add(r2b.ledger.tag("round2_broadcast"))
    detail = ExecDetail(node_centers=node_centers, node_alloc=t_i,
                        node_totals=node_totals, rounds=rounds)
    return ClusteringResult(centers, cs, ledger, local_costs,
                            exec_detail=detail)


# ---------------------------------------------------------------------------
# SPMD / mesh path (production)
# ---------------------------------------------------------------------------

def spmd_distributed_kmeans_fn(
    axis_name: str,
    axis_size: int,
    k: int,
    t: int,
    t_buffer: int,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 8,
    final_lloyd_iters: int = 10,
    backend: BackendLike = None,
    collectives: str = "all_gather",
    strategy: StrategyLike = None,
    mesh_shape: Optional[Tuple[int, int]] = None,
):
    """Build the per-device function for Algorithm 1+2 under ``shard_map``.

    Each device holds one site's (M, d) shard + mask (the mesh wrapper
    reshape-merges multiple site blocks per device, so ``axis_size`` devices
    participate as ``axis_size`` sites). Cross-device traffic is exactly:
    one gather of the ``axis_size`` Round-1 cost scalars + one gather of the
    fixed-size local portion (Round 2) -- the paper's communication pattern
    mapped onto mesh collectives. ``collectives`` picks the lowering:
    ``"all_gather"`` uses ``lax.all_gather`` (XLA lowers it to neighbour
    rounds on the ICI torus itself); ``"neighbor_rounds"`` uses the explicit
    ring ``ppermute`` schedule of Algorithm 3
    (:func:`~repro.core.message_passing.neighbor_rounds_gather`) -- the
    gathered buffers are pure relays, so results are bit-identical;
    ``"torus_2d"`` folds the flat axis onto an (R, C) torus
    (:func:`~repro.core.message_passing.torus_rounds_gather`, row phase
    then column phase, (R-1)+(C-1) hops instead of R*C-1) -- also a pure
    relay in flat row-major order, so still bit-identical. ``mesh_shape``
    picks (R, C); the default is the most-square factorization of
    ``axis_size`` (:func:`~repro.core.message_passing.torus_mesh_shape`).
    (The cost *total* is always reduced from the gathered vector, never
    via ``neighbor_rounds_sum``/``torus_rounds_sum``: a ring-order
    accumulation starts at a different shard on every device, which breaks
    both cross-device and gather-path bit-equality of the float total.)

    The two communication points are wrapped in ``jax.named_scope("round1")``
    / ``("round2")`` so compiled-HLO collectives carry phase-attributable
    ``op_name`` metadata (consumed by ``roofline/hlo.py``'s per-phase
    collective ledger).

    Gathering the scalars (rather than psum-ing them) lets every device run
    the *exact* largest-remainder ``proportional_allocation`` the host path
    uses, so ``sum_i t_i == t`` holds on this path too (a rounded per-site
    share can collectively over/under-draw; DESIGN.md Sec. 7's allocation
    invariant). The ``backend`` hot-loop selection composes with
    ``shard_map``: the Pallas kernels run per-device on that device's shard.
    """
    backend = backend_mod.resolve_name(backend)
    objective = objective_mod.resolve_name(objective)
    strat = strategy_mod.get_strategy(strategy_mod.resolve_name(strategy))
    if collectives not in ("all_gather", "neighbor_rounds", "torus_2d"):
        raise ValueError(f"unknown collectives {collectives!r}: expected "
                         f"'all_gather'|'neighbor_rounds'|'torus_2d'")
    if collectives == "torus_2d":
        mesh_shape = (torus_mesh_shape(axis_size) if mesh_shape is None
                      else tuple(mesh_shape))
        if mesh_shape[0] * mesh_shape[1] != axis_size:
            raise ValueError(f"mesh_shape {mesh_shape} does not tile "
                             f"axis_size {axis_size}")
    elif mesh_shape is not None:
        raise ValueError("mesh_shape is only meaningful with "
                         "collectives='torus_2d'")

    def gather(x: Array) -> Array:
        if collectives == "all_gather":
            out = jax.lax.all_gather(x, axis_name)
        elif collectives == "torus_2d":
            out = torus_rounds_gather(x, axis_name, mesh_shape)
        else:
            out = neighbor_rounds_gather(x, axis_name, axis_size)
        # every mode relays bit-identical values, but without a barrier XLA
        # may fuse the *consumer* differently per producer graph (observed:
        # the torus reshape shifted weiszfeld fusion by ~1e-6 at 16
        # devices) -- the barrier pins the consumer graph so cross-mode
        # bit-parity is structural, not luck
        return jax.lax.optimization_barrier(out)

    def per_device(key: Array, pts: Array, mask: Array):
        w = mask.astype(pts.dtype)
        site = jax.lax.axis_index(axis_name)
        ki = jax.random.fold_in(key, site)
        k_solve, k_sample = jax.random.split(ki)

        # Round 1: local solve + single-scalar communication
        centers = clustering.kmeans_pp_init(k_solve, pts, k, weights=w,
                                            objective=objective,
                                            backend=backend)
        centers, _ = clustering.lloyd(pts, centers, weights=w,
                                      iters=lloyd_iters, objective=objective,
                                      backend=backend)
        m, assign, w_eff = strat.site_sensitivities(
            pts, centers, w, objective=objective, backend=backend)
        local_cost = jnp.sum(m)
        if strat.needs_exchange:
            with jax.named_scope("round1"):
                all_costs = gather(local_cost)                 # <- Round 1
            total_cost = jnp.sum(all_costs)

            # exact largest-remainder allocation over the gathered scalars
            # -- identical math to the host path, replicated per device.
            # t_local is NOT clamped to t_buffer here, also matching the
            # host: _sample_and_weight truncates the realized draws at its
            # t_buffer slots, and the weight formula keeps using the full
            # allocation.
            t_all = strat.allocate(all_costs, t)
            t_local = t_all[site]
            t_total = jnp.sum(t_all).astype(pts.dtype)   # == t exactly
        else:
            # single shuffle: the uniform split is derivable on-device and
            # the standalone weight formula uses the local scalar + share
            t_all = strat.allocate(jnp.ones((axis_size,), pts.dtype), t)
            t_local = t_all[site]
            total_cost = local_cost
            t_total = t_local.astype(pts.dtype)

        sampled, w_s, w_b = _sample_and_weight(
            k_sample, pts, m, w_eff, assign, k, t_local, t_buffer,
            total_cost, t_total)
        portion_pts = jnp.concatenate([sampled, centers], axis=0)
        portion_w = jnp.concatenate([w_s, w_b], axis=0)

        # Round 2: share the fixed-size portions
        with jax.named_scope("round2"):
            all_pts = gather(portion_pts)                       # <- Round 2
            all_w = gather(portion_w)
        cs_pts = all_pts.reshape(-1, pts.shape[-1])
        cs_w = all_w.reshape(-1)

        # every device solves the identical weighted instance (replicated)
        k_final = jax.random.fold_in(key, 0)
        fc = clustering.kmeans_pp_init(k_final, cs_pts, k,
                                       weights=jnp.maximum(cs_w, 0.0),
                                       objective=objective, backend=backend)
        fc, _ = clustering.lloyd(cs_pts, fc, weights=cs_w,
                                 iters=final_lloyd_iters, objective=objective,
                                 backend=backend)
        return fc, local_cost[None], t_local[None]

    return per_device


def spmd_distributed_kmeans(
    mesh: Mesh,
    axis_name: str,
    key: Array,
    site_points: Array,   # (n_sites, M, d) -- sharded over axis_name
    site_mask: Array,
    k: int,
    t: int,
    t_buffer: Optional[int] = None,
    objective: ObjectiveLike = "kmeans",
    lloyd_iters: int = 8,
    backend: BackendLike = None,
    collectives: str = "all_gather",
    strategy: StrategyLike = None,
    mesh_shape: Optional[Tuple[int, int]] = None,
) -> Tuple[Array, Array, Array]:
    """Run the SPMD path on a mesh. Returns (centers (k,d), local_costs,
    t_i) -- ``t_i`` are the per-site sample allocations, which satisfy
    ``sum(t_i) == t`` exactly (largest-remainder allocation, identical to
    the host path's, including its behavior when an allocation exceeds
    ``t_buffer``: realized draws are truncated at the buffer while the
    weight formula keeps the full allocation).

    The default ``t_buffer`` is sized off ``axis_size``, not ``n_sites``:
    ``device_fn`` reshape-merges each device's site blocks into one site,
    so only ``axis_size`` sites participate in the allocation and each
    draws ``t_i ~ t / axis_size``. (Sizing off ``n_sites`` silently
    truncated draws whenever ``n_sites > axis_size``.)"""
    n_sites = site_points.shape[0]
    axis_size = mesh.shape[axis_name]
    if n_sites % axis_size:
        raise ValueError(f"n_sites={n_sites} must divide over {axis_name}="
                         f"{axis_size}")
    t_buffer = t_buffer if t_buffer is not None else max(
        4 * t // max(axis_size, 1), 64)
    fn = spmd_distributed_kmeans_fn(axis_name, axis_size, k, t, t_buffer,
                                    objective, lloyd_iters, backend=backend,
                                    collectives=collectives,
                                    strategy=strategy, mesh_shape=mesh_shape)

    def device_fn(key, pts, mask):
        # collapse the per-device leading site-block dim (sites/device >= 1)
        pts = pts.reshape(-1, pts.shape[-1])
        mask = mask.reshape(-1)
        return fn(key, pts, mask)

    shard = _shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(), P(axis_name), P(axis_name)),
        out_specs=(P(), P(axis_name), P(axis_name)),
    )
    centers, local_costs, t_i = jax.jit(shard)(key, site_points, site_mask)
    return centers, local_costs, t_i
