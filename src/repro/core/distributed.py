"""Algorithm 2 -- distributed clustering, end to end.

Three execution paths over the same math:

* :func:`distributed_kmeans` -- host-level simulation over an arbitrary
  ``Graph`` with an exact :class:`CommLedger` (reproduces the paper's
  experiments: general graphs, Theorem 2 accounting).
* :func:`distributed_kmeans_tree` -- same over a rooted spanning tree
  (Theorem 3 accounting: everything moves O(h) edges, no flooding).
* :func:`spmd_distributed_kmeans` -- the production SPMD path: sites are
  devices along a mesh axis, Round 1's scalar share is a ``lax.all_gather``
  (every device replays the exact largest-remainder allocation), Round 2's
  portion share is a ``lax.all_gather``; runs under ``shard_map`` on real
  meshes (and under the 512-device dry run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core.backend import BackendLike
from repro.core.comm import (CommLedger, flood_cost, tree_broadcast_cost,
                             tree_up_cost)
from repro.core.coreset import (Coreset, DistributedCoreset,
                                distributed_coreset, proportional_allocation,
                                sensitivities, _sample_and_weight)
from repro.core.topology import Graph, SpanningTree

from repro.compat import shard_map as _shard_map

Array = jax.Array


@dataclasses.dataclass
class ClusteringResult:
    centers: Array
    coreset: Coreset
    ledger: CommLedger
    local_costs: Array


def _solve_on_coreset(key: Array, cs: Coreset, k: int, objective: str,
                      lloyd_iters: int, backend: BackendLike = None) -> Array:
    centers = clustering.kmeans_pp_init(key, cs.points, k,
                                        weights=jnp.maximum(cs.weights, 0.0),
                                        objective=objective, backend=backend)
    centers, _ = clustering.lloyd(cs.points, centers, weights=cs.weights,
                                  iters=lloyd_iters, objective=objective,
                                  backend=backend)
    return centers


def distributed_kmeans(
    key: Array,
    site_points: Array,
    site_mask: Array,
    k: int,
    t: int,
    graph: Graph,
    objective: str = "kmeans",
    lloyd_iters: int = 8,
    backend: BackendLike = None,
) -> ClusteringResult:
    """Algorithm 2 on a general graph. Round 1 floods n scalars (2mn
    messages); Round 2 floods the n local portions (2m * sum_i |D_i|
    points); every node then solves the identical weighted instance."""
    n_sites, _, d = site_points.shape
    backend = backend_mod.resolve_name(backend)
    k1, k2 = jax.random.split(key)
    dc = distributed_coreset(k1, site_points, site_mask, k, t,
                             objective=objective, lloyd_iters=lloyd_iters,
                             backend=backend)
    cs = dc.flatten()
    centers = _solve_on_coreset(k2, cs, k, objective, lloyd_iters, backend)

    portion_pts = float(jnp.sum(dc.t_i)) + graph.n * k
    ledger = flood_cost(graph, n_messages=graph.n, unit_scalars=1.0)
    ledger = ledger.add(CommLedger(points=2.0 * graph.m * portion_pts,
                                   messages=2.0 * graph.m * graph.n, dim=d))
    return ClusteringResult(centers, cs, ledger, dc.local_costs)


def distributed_kmeans_tree(
    key: Array,
    site_points: Array,
    site_mask: Array,
    k: int,
    t: int,
    tree: SpanningTree,
    objective: str = "kmeans",
    lloyd_iters: int = 8,
    backend: BackendLike = None,
) -> ClusteringResult:
    """Algorithm 2 restricted to a rooted tree (Theorem 3): costs are summed
    up the tree (n-1 scalars), the total is broadcast down (n-1 scalars),
    portions travel depth(v) edges to the root, the solution (k points) is
    broadcast back."""
    n_sites, _, d = site_points.shape
    backend = backend_mod.resolve_name(backend)
    k1, k2 = jax.random.split(key)
    dc = distributed_coreset(k1, site_points, site_mask, k, t,
                             objective=objective, lloyd_iters=lloyd_iters,
                             backend=backend)
    cs = dc.flatten()
    centers = _solve_on_coreset(k2, cs, k, objective, lloyd_iters, backend)

    t_i = [float(x) for x in dc.t_i]
    per_node = [t_i[v] + k for v in range(tree.n)]
    ledger = CommLedger(scalars=2.0 * (tree.n - 1),
                        messages=2.0 * (tree.n - 1))
    ledger = ledger.add(tree_up_cost(tree, per_node, dim=d))
    ledger = ledger.add(tree_broadcast_cost(tree, unit_points=float(k), dim=d))
    return ClusteringResult(centers, cs, ledger, dc.local_costs)


# ---------------------------------------------------------------------------
# SPMD / mesh path (production)
# ---------------------------------------------------------------------------

def spmd_distributed_kmeans_fn(
    axis_name: str,
    n_sites: int,
    k: int,
    t: int,
    t_buffer: int,
    objective: str = "kmeans",
    lloyd_iters: int = 8,
    final_lloyd_iters: int = 10,
    backend: BackendLike = None,
):
    """Build the per-device function for Algorithm 1+2 under ``shard_map``.

    Each device holds one site's (M, d) shard + mask. Cross-device traffic is
    exactly: one all_gather of the n Round-1 cost scalars + one all_gather of
    the fixed-size local portion (Round 2) -- the paper's communication
    pattern mapped onto the ICI collectives that implement neighbour message
    passing natively. Gathering the scalars (rather than psum-ing them) lets
    every device run the *exact* largest-remainder ``proportional_allocation``
    the host path uses, so ``sum_i t_i == t`` holds on this path too (a
    rounded per-site share can collectively over/under-draw; DESIGN.md
    Sec. 7's allocation invariant). The ``backend`` hot-loop selection
    composes with ``shard_map``: the Pallas kernels run per-device on that
    device's shard.
    """
    backend = backend_mod.resolve_name(backend)

    def per_device(key: Array, pts: Array, mask: Array):
        w = mask.astype(pts.dtype)
        site = jax.lax.axis_index(axis_name)
        ki = jax.random.fold_in(key, site)
        k_solve, k_sample = jax.random.split(ki)

        # Round 1: local solve + single-scalar communication
        centers = clustering.kmeans_pp_init(k_solve, pts, k, weights=w,
                                            objective=objective,
                                            backend=backend)
        centers, _ = clustering.lloyd(pts, centers, weights=w,
                                      iters=lloyd_iters, objective=objective,
                                      backend=backend)
        m, assign = sensitivities(pts, centers, w, objective=objective,
                                  backend=backend)
        local_cost = jnp.sum(m)
        all_costs = jax.lax.all_gather(local_cost, axis_name)  # <- Round 1
        total_cost = jnp.sum(all_costs)

        # exact largest-remainder allocation over the gathered scalars --
        # identical math to the host path, replicated on every device.
        # t_local is NOT clamped to t_buffer here, also matching the host:
        # _sample_and_weight truncates the realized draws at its t_buffer
        # slots, and the weight formula keeps using the full allocation.
        t_all = proportional_allocation(all_costs, t)
        t_local = t_all[site]
        t_total = jnp.sum(t_all).astype(pts.dtype)   # == t exactly

        sampled, w_s, w_b = _sample_and_weight(
            k_sample, pts, m, w, assign, k, t_local, t_buffer, total_cost,
            t_total)
        portion_pts = jnp.concatenate([sampled, centers], axis=0)
        portion_w = jnp.concatenate([w_s, w_b], axis=0)

        # Round 2: share the fixed-size portions
        all_pts = jax.lax.all_gather(portion_pts, axis_name)    # <- Round 2
        all_w = jax.lax.all_gather(portion_w, axis_name)
        cs_pts = all_pts.reshape(-1, pts.shape[-1])
        cs_w = all_w.reshape(-1)

        # every device solves the identical weighted instance (replicated)
        k_final = jax.random.fold_in(key, 0)
        fc = clustering.kmeans_pp_init(k_final, cs_pts, k,
                                       weights=jnp.maximum(cs_w, 0.0),
                                       objective=objective, backend=backend)
        fc, _ = clustering.lloyd(cs_pts, fc, weights=cs_w,
                                 iters=final_lloyd_iters, objective=objective,
                                 backend=backend)
        return fc, local_cost[None], t_local[None]

    return per_device


def spmd_distributed_kmeans(
    mesh: Mesh,
    axis_name: str,
    key: Array,
    site_points: Array,   # (n_sites, M, d) -- sharded over axis_name
    site_mask: Array,
    k: int,
    t: int,
    t_buffer: Optional[int] = None,
    objective: str = "kmeans",
    lloyd_iters: int = 8,
    backend: BackendLike = None,
) -> Tuple[Array, Array, Array]:
    """Run the SPMD path on a mesh. Returns (centers (k,d), local_costs,
    t_i) -- ``t_i`` are the per-site sample allocations, which satisfy
    ``sum(t_i) == t`` exactly (largest-remainder allocation, identical to
    the host path's, including its behavior when an allocation exceeds
    ``t_buffer``: realized draws are truncated at the buffer while the
    weight formula keeps the full allocation)."""
    n_sites = site_points.shape[0]
    axis_size = mesh.shape[axis_name]
    if n_sites % axis_size:
        raise ValueError(f"n_sites={n_sites} must divide over {axis_name}="
                         f"{axis_size}")
    t_buffer = t_buffer if t_buffer is not None else max(
        4 * t // max(n_sites, 1), 64)
    fn = spmd_distributed_kmeans_fn(axis_name, n_sites, k, t, t_buffer,
                                    objective, lloyd_iters, backend=backend)

    def device_fn(key, pts, mask):
        # collapse the per-device leading site-block dim (sites/device >= 1)
        pts = pts.reshape(-1, pts.shape[-1])
        mask = mask.reshape(-1)
        return fn(key, pts, mask)

    shard = _shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(), P(axis_name), P(axis_name)),
        out_specs=(P(), P(axis_name), P(axis_name)),
    )
    centers, local_costs, t_i = jax.jit(shard)(key, site_points, site_mask)
    return centers, local_costs, t_i
