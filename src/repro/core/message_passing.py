"""Algorithm 3 -- Message-Passing on a general communication graph.

Two implementations:

1. :func:`flood` -- a faithful host-level simulation over an arbitrary
   connected ``Graph``: each node initially knows one message and forwards
   every newly seen message to all neighbours exactly once. Used to *verify*
   the O(mn) bound and to drive the paper's experiments with exact per-edge
   message ledgers.

2. :func:`neighbor_rounds_sum` -- the TPU-native counterpart: on a physical
   torus/mesh, the same information pattern is a sequence of
   ``jax.lax.ppermute`` neighbour exchanges; after ``diameter`` rounds every
   device holds the global reduction. Production code uses ``lax.psum``
   directly (XLA lowers it to exactly such neighbour rounds on the ICI
   torus); this explicit version exists to demonstrate the mapping and to
   let tests count per-round traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.topology import Graph


@dataclasses.dataclass
class FloodResult:
    received: List[set]          # per node: set of message ids known
    rounds: int                  # synchronous rounds until quiescence
    transmissions: int           # total edge-messages sent
    per_round_transmissions: List[int]


def flood(g: Graph, payload_ids: Sequence[int] | None = None) -> FloodResult:
    """Synchronous simulation of Algorithm 3.

    Every node starts with its own message id; in each round, each node sends
    every message it learned in the previous round to all neighbours. A node
    never forwards the same message twice. Terminates when no new message is
    delivered anywhere (<= diameter rounds).
    """
    ids = list(payload_ids) if payload_ids is not None else list(range(g.n))
    adj = g.adjacency()
    known: List[set] = [{ids[v]} for v in range(g.n)]
    fresh: List[set] = [{ids[v]} for v in range(g.n)]
    transmissions = 0
    per_round: List[int] = []
    rounds = 0
    while any(fresh):
        sent_this_round = 0
        incoming: List[set] = [set() for _ in range(g.n)]
        for v in range(g.n):
            for msg in fresh[v]:
                for u in adj[v]:
                    incoming[u].add(msg)
                    sent_this_round += 1
        fresh = [incoming[v] - known[v] for v in range(g.n)]
        for v in range(g.n):
            known[v] |= fresh[v]
        transmissions += sent_this_round
        per_round.append(sent_this_round)
        rounds += 1
    return FloodResult(known, rounds, transmissions, per_round)


def flood_scalars(g: Graph, values: Sequence[float]) -> Tuple[List[Dict[int, float]], FloodResult]:
    """Flood real scalar payloads (the per-site costs of Algorithm 1 Round 1).

    Returns per-node {origin: value} tables plus the flood statistics.
    """
    res = flood(g)
    tables = [{origin: float(values[origin]) for origin in res.received[v]}
              for v in range(g.n)]
    return tables, res


def neighbor_rounds_sum(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Global sum via ring neighbour exchanges only (collective_permute),
    demonstrating Algorithm 3 on a physical ring: after ``axis_size - 1``
    rounds each device has accumulated every shard's value.

    Must be called inside ``shard_map`` over ``axis_name``.
    """
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    def body(i, carry):
        acc, buf = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        return acc + buf, buf

    acc, _ = jax.lax.fori_loop(0, axis_size - 1, body, (x, x))
    return acc


def neighbor_rounds_gather(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """All-gather via ring neighbour exchanges (Algorithm 3 Round 2 on a
    physical ring): returns (axis_size, *x.shape) on every device."""
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((axis_size,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, x, idx, 0)
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    def body(i, carry):
        out, buf, src = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        src = (src - 1) % axis_size
        out = jax.lax.dynamic_update_index_in_dim(out, buf, src, 0)
        return out, buf, src

    out, _, _ = jax.lax.fori_loop(0, axis_size - 1, body, (out, x, idx))
    return out
