"""Algorithm 3 -- Message-Passing on a general communication graph.

Three implementations:

1. :func:`flood` -- a faithful host-level simulation over an arbitrary
   connected ``Graph``: each node initially knows one message and forwards
   every newly seen message to all neighbours exactly once. Used to *verify*
   the O(mn) bound and to drive the paper's experiments with exact per-edge
   message ledgers.

2. **The topology execution engine** (DESIGN.md Sec. 11):
   :class:`GossipSchedule` / :class:`TreeSchedule` compile a ``Graph`` /
   ``SpanningTree`` into static per-round schedules (padded neighbor-index
   arrays, per-level segment maps), and :func:`flood_exec`,
   :func:`tree_gather_exec`, :func:`tree_scatter_exec`,
   :func:`tree_up_sum_exec`, :func:`tree_broadcast_exec` *execute* the
   message-passing rounds as jitted vmapped gather + segment-scatter steps
   over per-node state. Payloads physically move edge by edge (every copy a
   node ends up holding is a bit-identical relay of the origin's payload),
   and each primitive returns a *measured* :class:`~repro.core.comm
   .CommLedger` counted from the schedule execution -- by construction it
   must equal the corresponding analytic ``flood_cost`` /
   ``tree_up_cost``-style ledger, and tests assert exactly that. The
   schedules carry the graph's per-link costs, so every measured ledger
   also prices each transmission by the edge it crossed
   (``CommLedger.link_cost``; DESIGN.md Sec. 12).

3. :func:`neighbor_rounds_sum` / :func:`neighbor_rounds_gather` -- the
   TPU-native counterpart: on a physical torus/mesh, the same information
   pattern is a sequence of ``jax.lax.ppermute`` neighbour exchanges; after
   ``diameter`` rounds every device holds the global reduction. These back
   the ``collectives="neighbor_rounds"`` mode of
   ``spmd_distributed_kmeans`` (and demonstrate the mapping XLA applies
   when lowering ``psum``/``all_gather`` to the ICI torus).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLedger, link_cost_of
from repro.core.topology import Graph, SpanningTree, diameter, spanning_tree


@dataclasses.dataclass
class FloodResult:
    received: List[set]          # per node: set of message ids known
    rounds: int                  # synchronous rounds until quiescence
    transmissions: int           # total edge-messages sent
    per_round_transmissions: List[int]


def flood(g: Graph, payload_ids: Sequence[int] | None = None) -> FloodResult:
    """Synchronous simulation of Algorithm 3.

    Every node starts with its own message id; in each round, each node sends
    every message it learned in the previous round to all neighbours. A node
    never forwards the same message twice. Terminates when no new message is
    delivered anywhere (<= diameter rounds).
    """
    ids = list(payload_ids) if payload_ids is not None else list(range(g.n))
    adj = g.adjacency()
    known: List[set] = [{ids[v]} for v in range(g.n)]
    fresh: List[set] = [{ids[v]} for v in range(g.n)]
    transmissions = 0
    per_round: List[int] = []
    rounds = 0
    while any(fresh):
        sent_this_round = 0
        incoming: List[set] = [set() for _ in range(g.n)]
        for v in range(g.n):
            for msg in fresh[v]:
                for u in adj[v]:
                    incoming[u].add(msg)
                    sent_this_round += 1
        fresh = [incoming[v] - known[v] for v in range(g.n)]
        for v in range(g.n):
            known[v] |= fresh[v]
        transmissions += sent_this_round
        per_round.append(sent_this_round)
        rounds += 1
    return FloodResult(known, rounds, transmissions, per_round)


def flood_scalars(g: Graph, values: Sequence[float]) -> Tuple[List[Dict[int, float]], FloodResult]:
    """Flood real scalar payloads (the per-site costs of Algorithm 1 Round 1).

    Returns per-node {origin: value} tables plus the flood statistics.
    """
    if len(values) != g.n:
        raise ValueError(f"flood_scalars needs one value per node: got "
                         f"{len(values)} values for a {g.n}-node graph")
    res = flood(g)
    tables = [{origin: float(values[origin]) for origin in res.received[v]}
              for v in range(g.n)]
    return tables, res


# ---------------------------------------------------------------------------
# Topology execution engine: compiled schedules + jitted message rounds
# ---------------------------------------------------------------------------

Units = Union[float, Sequence[float], np.ndarray, jax.Array]


@dataclasses.dataclass
class ExecResult:
    """Outcome of one executed communication primitive.

    ``rounds`` is the static schedule length that ran; for floods,
    ``rounds_to_complete`` is the first round after which every node knew
    every payload (<= diameter on a connected graph -- the schedule runs one
    extra round so the final fresh messages are forwarded, which is what
    makes the measured transmission count equal the analytic 2mn).
    ``ledger`` is *measured*: every scalar/point/message was counted from an
    actual executed transmission, never from a formula.

    ``wall_s`` is the host wall-clock time the primitive spent (schedule
    execution + ledger pricing, excluding schedule compilation, which is
    cached per graph). It feeds the per-phase timing columns of
    ``bench_topologies`` -- an observability column, deliberately excluded
    from every ledger-parity identity."""

    rounds: int
    rounds_to_complete: int
    ledger: CommLedger
    per_round_transmissions: List[int]
    wall_s: float = 0.0


def pack_payload(points: jax.Array, weights: jax.Array) -> jax.Array:
    """Pack weighted points into an engine payload: ``(..., S, d)`` points +
    ``(..., S)`` weights -> ``(..., S, d+1)`` with the weight as the
    trailing column. Every exec path that ships coreset portions uses this
    layout; :func:`unpack_payload` is its inverse, so the two stay in sync
    by construction."""
    return jnp.concatenate([points, weights[..., None]], axis=-1)


def unpack_payload(table: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Inverse of :func:`pack_payload`: ``(..., S, d+1)`` ->
    ``((..., S, d), (..., S))``."""
    return table[..., :-1], table[..., -1]


def _units_ledger(per_origin_msgs: np.ndarray, unit_scalars: Units,
                  unit_points: Units, dim: int,
                  count_all_messages: bool,
                  per_origin_link: np.ndarray | None = None) -> CommLedger:
    """Price measured per-origin transmission counts. ``count_all_messages``
    distinguishes flooding (a message id is forwarded whether or not it
    carries metered payload; analytic ``flood_cost`` counts all 2mn) from
    tree routing (only payload-carrying origins move; analytic
    ``tree_up_cost`` counts only unit>0 nodes). ``per_origin_link`` is the
    measured per-origin *edge-cost* total (the sum of link costs each
    origin's payload crossed); defaults to the hop counts, i.e. uniform
    unit links."""
    per = np.asarray(per_origin_msgs, np.float64)
    us = np.broadcast_to(np.asarray(unit_scalars, np.float64), per.shape)
    up = np.broadcast_to(np.asarray(unit_points, np.float64), per.shape)
    if count_all_messages or not (us + np.abs(up)).any():
        msgs = float(per.sum())
    else:
        msgs = float(per[(us + np.abs(up)) > 0].sum())
    link = per if per_origin_link is None else per_origin_link
    return CommLedger(scalars=float((per * us).sum()),
                      points=float((per * up).sum()),
                      messages=msgs, dim=dim,
                      link_cost=link_cost_of(link, us, up, dim))


@dataclasses.dataclass(frozen=True, eq=False)
class GossipSchedule:
    """Static flood schedule for a connected :class:`Graph`: padded
    neighbor-index arrays (from ``adjacency()``) plus the round count to
    quiescence. Compile once per graph, execute many times. Carries the
    graph's per-link costs (``neighbor_costs`` aligned with ``neighbors``,
    plus the per-node ``weighted_degrees``) so executed floods can be
    priced per edge crossed."""

    n: int
    m: int
    n_rounds: int               # diameter + 1: last fresh set still forwards
    neighbors: np.ndarray       # (n, max_deg) int32 out-neighbors, 0-padded
    neighbor_mask: np.ndarray   # (n, max_deg) bool
    degrees: np.ndarray         # (n,) int32 out-degrees (send pricing)
    neighbor_costs: np.ndarray  # (n, max_deg) float64, padded with 0
    weighted_degrees: np.ndarray  # (n,) float64 (== Graph.weighted_degrees)
    in_neighbors: np.ndarray    # (n, max_in) int32: the receive gather side
    in_neighbor_mask: np.ndarray  # (n, max_in) bool (== out side undirected)

    @classmethod
    def from_graph(cls, g: Graph) -> "GossipSchedule":
        adj, adjc = g.adjacency(), g.adjacency_costs()
        max_deg = max((len(a) for a in adj), default=0)
        if g.n > 1 and min(len(a) for a in adj) == 0:
            raise ValueError("graph is not connected (isolated node)")
        max_deg = max(max_deg, 1)
        nb = np.zeros((g.n, max_deg), np.int32)
        mask = np.zeros((g.n, max_deg), bool)
        nc = np.zeros((g.n, max_deg), np.float64)
        for v, (a, cs) in enumerate(zip(adj, adjc)):
            nb[v, :len(a)] = a
            mask[v, :len(a)] = True
            nc[v, :len(a)] = cs
        if g.directed:
            # a node *receives* along its in-links; sends meter out-links
            in_adj: list = [[] for _ in range(g.n)]
            for i, j in g.edges:
                in_adj[j].append(i)
            max_in = max(1, max(len(a) for a in in_adj))
            in_nb = np.zeros((g.n, max_in), np.int32)
            in_mask = np.zeros((g.n, max_in), bool)
            for v, a in enumerate(in_adj):
                in_nb[v, :len(a)] = a
                in_mask[v, :len(a)] = True
        else:
            in_nb, in_mask = nb, mask
        return cls(n=g.n, m=g.m, n_rounds=diameter(g) + 1, neighbors=nb,
                   neighbor_mask=mask,
                   degrees=mask.sum(axis=1).astype(np.int32),
                   neighbor_costs=nc,
                   weighted_degrees=np.asarray(g.weighted_degrees()),
                   in_neighbors=in_nb, in_neighbor_mask=in_mask)


@functools.lru_cache(maxsize=128)
def gossip_schedule(g: Graph) -> GossipSchedule:
    """Cached :meth:`GossipSchedule.from_graph`: ``Graph`` is a frozen
    (hashable) dataclass, so identical graphs -- including directed and
    cost-annotated WAN ones -- compile their padded-neighbor tables once
    per process. Streaming aggregation and the WAN runtime call this every
    round; the returned schedule is shared, treat it as read-only."""
    return GossipSchedule.from_graph(g)


@functools.partial(jax.jit, static_argnames=("n_rounds",))
def _flood_exec_rounds(in_neighbors, in_neighbor_mask, out_degrees, payload,
                       n_rounds):
    """Execute ``n_rounds`` synchronous flood rounds over per-node state.

    State: ``known``/``fresh`` (n, n) bool tables (node x origin) and
    ``table`` (n, n, F) payload copies. Each round every node relays the
    payloads it learned last round to all its (out-)neighbours -- the
    receive side is a vmapped gather over *in*-neighbors (identical to the
    out side on undirected graphs; the distinction is what keeps a directed
    flood moving along link directions rather than the transpose graph);
    the payload copy is selected from the first fresh-holding in-neighbour,
    so every copy is a bit-exact relay. ``fwd[v, o]`` counts how often node
    v forwarded origin o's message (exactly once each on a connected graph)
    -- the (node, origin) resolution the cost-weighted ledger prices from,
    with ``out_degrees`` as the per-forward transmission count."""
    n, f = payload.shape
    eye = jnp.eye(n, dtype=bool)
    table = jnp.where(eye[:, :, None], payload[None, :, :],
                      jnp.zeros((), payload.dtype))

    def body(carry, _):
        known, fresh, table, fwd = carry
        # transmissions this round: each fresh holder sends on every out-link
        sends = jnp.sum(fresh.sum(axis=1) * out_degrees)
        fwd = fwd + fresh.astype(jnp.int32)
        f_nb = fresh[in_neighbors] & in_neighbor_mask[:, :, None]
        incoming = jnp.any(f_nb, axis=1)                      # (n, n)
        src = jnp.argmax(f_nb, axis=1)                        # (n, n)
        recv = jnp.take_along_axis(table[in_neighbors],
                                   src[:, None, :, None], axis=1)[:, 0]
        new = incoming & ~known
        table = jnp.where(new[:, :, None], recv, table)
        known = known | new
        return (known, new, table, fwd), (sends, jnp.all(known))

    fwd0 = jnp.zeros((n, n), jnp.int32)
    (known, _, table, fwd), (sends, complete) = jax.lax.scan(
        body, (eye, eye, table, fwd0), None, length=n_rounds)
    return table, known, sends, fwd, complete


def flood_exec(schedule: Union[GossipSchedule, Graph], payload: jax.Array,
               unit_scalars: Units = 0.0, unit_points: Units = 0.0,
               dim: int = 0) -> Tuple[jax.Array, ExecResult]:
    """Execute Algorithm 3 on a compiled gossip schedule.

    ``payload``: (n, ...) origin-indexed array -- node v starts knowing only
    ``payload[v]``. Returns ``(tables, result)`` where ``tables[v, o]`` is
    node v's relayed copy of origin o's payload (on a connected graph every
    node ends holding all n payloads, bit-identical to the originals).

    ``unit_scalars`` / ``unit_points`` price each *transmission* of origin
    o's message (scalar, or (n,) per-origin -- Round 2 portions have
    per-site sizes ``t_i + k``); the returned ledger is measured from the
    executed schedule and equals the analytic
    ``flood_cost(g, n_messages=n, ...)`` exactly.
    """
    if isinstance(schedule, Graph):
        schedule = gossip_schedule(schedule)
    payload = jnp.asarray(payload)
    if payload.shape[0] != schedule.n:
        raise ValueError(f"payload must be origin-indexed: got leading dim "
                         f"{payload.shape[0]} for a {schedule.n}-node graph")
    t0 = time.perf_counter()
    trailing = payload.shape[1:]
    flat = payload.reshape(schedule.n, -1)
    table, known, sends, fwd, complete = _flood_exec_rounds(
        jnp.asarray(schedule.in_neighbors),
        jnp.asarray(schedule.in_neighbor_mask),
        jnp.asarray(schedule.degrees), flat, n_rounds=schedule.n_rounds)
    if not bool(jnp.all(known)):
        raise RuntimeError("flood did not complete: graph disconnected?")
    flags = np.asarray(complete)
    done = int(np.argmax(flags)) + 1 if flags.any() else schedule.n_rounds
    if schedule.n == 1:
        done = 0
    # price the measured (node, origin) forward counts: hop counts with the
    # node's degree, link costs with its weighted degree (each forward is
    # one transmission per incident link)
    fwd_np = np.asarray(fwd, np.int64)
    deg = np.asarray(schedule.degrees, np.int64)
    per_origin = (fwd_np * deg[:, None]).sum(axis=0)
    wdeg = np.asarray(schedule.weighted_degrees, np.float64)
    per_origin_link = np.asarray(
        [float((fwd_np[:, o].astype(np.float64) * wdeg).sum())
         for o in range(schedule.n)], np.float64)
    ledger = _units_ledger(per_origin, unit_scalars, unit_points,
                           dim, count_all_messages=True,
                           per_origin_link=per_origin_link)
    res = ExecResult(rounds=schedule.n_rounds, rounds_to_complete=done,
                     ledger=ledger,
                     per_round_transmissions=[int(s) for s in
                                              np.asarray(sends)],
                     wall_s=time.perf_counter() - t0)
    return table.reshape((schedule.n, schedule.n) + trailing), res


@dataclasses.dataclass(frozen=True, eq=False)
class TreeSchedule:
    """Static per-level schedule for a rooted :class:`SpanningTree`:
    ``levels[l]`` are the nodes at depth ``l+1`` (a segment map derived from
    ``bottom_up_order()``), ``subtree`` the per-node descendant masks that
    route scatter payloads. The up passes iterate levels deepest-first (a
    node transmits only after all its children have), the down passes
    shallowest-first."""

    n: int
    root: int
    height: int
    parent: np.ndarray      # (n,) int32; parent[root] == root (self-loop)
    depth: np.ndarray       # (n,) int32
    levels: np.ndarray      # (height, width) int32, padded with root
    level_mask: np.ndarray  # (height, width) bool
    subtree: np.ndarray     # (n, n) bool; subtree[v, o]: o in subtree of v
    parent_cost: np.ndarray  # (n,) float64; cost of v's parent link (0 @root)

    @classmethod
    def from_tree(cls, tree: SpanningTree) -> "TreeSchedule":
        depth = np.asarray(tree.depth, np.int32)
        parent = np.asarray(tree.parent, np.int32).copy()
        parent[tree.root] = tree.root
        height = tree.height
        by_level = [[] for _ in range(height)]
        for v in range(tree.n):
            if depth[v] > 0:
                by_level[depth[v] - 1].append(v)
        width = max((len(l) for l in by_level), default=1)
        width = max(width, 1)
        levels = np.full((height, width), tree.root, np.int32)
        mask = np.zeros((height, width), bool)
        for l, nodes in enumerate(by_level):
            levels[l, :len(nodes)] = nodes
            mask[l, :len(nodes)] = True
        sub = np.eye(tree.n, dtype=bool)
        for v in tree.bottom_up_order():
            if tree.parent[v] >= 0:
                sub[tree.parent[v]] |= sub[v]
        return cls(n=tree.n, root=tree.root, height=height, parent=parent,
                   depth=depth, levels=levels, level_mask=mask, subtree=sub,
                   parent_cost=np.asarray(tree.parent_costs()))

    @classmethod
    def from_graph(cls, g: Graph, root: int = 0,
                   routing: str = "bfs") -> "TreeSchedule":
        """Compile a tree schedule straight from a graph under a routing
        policy (``"bfs"`` hop-minimal | ``"min_cost"`` Prim)."""
        return cls.from_tree(spanning_tree(g, root=root, routing=routing))


@functools.lru_cache(maxsize=128)
def tree_schedule(g: Graph, root: int = 0,
                  routing: str = "bfs") -> TreeSchedule:
    """Cached :meth:`TreeSchedule.from_graph` (same contract as
    :func:`gossip_schedule`: one compile per (graph, root, routing))."""
    return TreeSchedule.from_graph(g, root=root, routing=routing)


def _path_link_costs(schedule: TreeSchedule,
                     hop_counts: np.ndarray) -> np.ndarray:
    """Measured per-origin link-cost totals for a gather/scatter: origin o
    moved ``hop_counts[o]`` edges along its root path; price them with the
    schedule's parent costs, deepest edge first (the same float64 order
    ``SpanningTree.path_costs`` accumulates in, so measured == analytic
    bit-for-bit for fully-routed origins)."""
    pc = np.asarray(schedule.parent_cost, np.float64)
    parent = np.asarray(schedule.parent, np.int64)
    out = np.zeros(schedule.n, np.float64)
    for o in range(schedule.n):
        acc, v = 0.0, o
        for _ in range(int(hop_counts[o])):
            acc += float(pc[v])
            v = int(parent[v])
        out[o] = acc
    return out


def _level_edge_cost_total(schedule: TreeSchedule) -> float:
    """Total scheduled-edge cost, accumulated level-major / ascending node
    id -- the same float64 order ``SpanningTree.edge_cost_total`` uses, so
    executed broadcast / up-sum pricing equals the analytic
    ``tree_broadcast_cost`` bit-for-bit."""
    total = 0.0
    pc = np.asarray(schedule.parent_cost, np.float64)
    for l in range(schedule.height):
        for w in range(schedule.levels.shape[1]):
            if schedule.level_mask[l, w]:
                total += float(pc[schedule.levels[l, w]])
    return total


def _level_scan(schedule: TreeSchedule, body, carry, bottom_up: bool):
    levels = jnp.asarray(schedule.levels)
    mask = jnp.asarray(schedule.level_mask)
    if bottom_up:
        levels, mask = jnp.flip(levels, 0), jnp.flip(mask, 0)
    return jax.lax.scan(body, carry, (levels, mask))


def tree_gather_exec(schedule: TreeSchedule, payload: jax.Array,
                     unit_scalars: Units = 0.0, unit_points: Units = 0.0,
                     dim: int = 0) -> Tuple[jax.Array, ExecResult]:
    """Route every node's payload up to the root (up-concat): origin o's
    copy travels ``depth(o)`` edges. Returns the root's origin-ordered
    table ``(n, ...)`` (bit-identical to ``payload``) and the measured
    ledger (equals ``tree_up_cost(tree, units)``)."""
    payload = jnp.asarray(payload)
    if payload.shape[0] != schedule.n:
        raise ValueError(f"payload must be origin-indexed: got leading dim "
                         f"{payload.shape[0]} for a {schedule.n}-node tree")
    t0 = time.perf_counter()
    trailing = payload.shape[1:]
    flat = payload.reshape(schedule.n, -1)

    def body(carry, lvl):
        known, table = carry
        nodes, lmask = lvl
        par = jnp.asarray(schedule.parent)[nodes]
        contrib = (known[nodes] > 0) & lmask[:, None]
        hops = contrib.astype(jnp.int32).sum(axis=0)
        tvals = jnp.where(contrib[:, :, None], table[nodes],
                          jnp.zeros((), flat.dtype))
        table = table.at[par].add(tvals)
        known = known.at[par].add(contrib.astype(jnp.int32))
        return (known, table), hops

    eye = jnp.eye(schedule.n, dtype=jnp.int32)
    table0 = jnp.where((eye > 0)[:, :, None], flat[None, :, :],
                       jnp.zeros((), flat.dtype))
    (known, table), hops = _level_scan(schedule, body, (eye, table0),
                                       bottom_up=True)
    per_origin = np.asarray(hops.sum(axis=0) if schedule.height else
                            np.zeros(schedule.n, np.int64))
    ledger = _units_ledger(per_origin, unit_scalars, unit_points, dim,
                           count_all_messages=False,
                           per_origin_link=_path_link_costs(schedule,
                                                            per_origin))
    res = ExecResult(rounds=schedule.height,
                     rounds_to_complete=schedule.height, ledger=ledger,
                     per_round_transmissions=[int(x) for x in
                                              np.asarray(hops.sum(axis=1))]
                     if schedule.height else [],
                     wall_s=time.perf_counter() - t0)
    return table[schedule.root].reshape((schedule.n,) + trailing), res


def tree_scatter_exec(schedule: TreeSchedule, root_values: jax.Array,
                      unit_scalars: Units = 0.0, unit_points: Units = 0.0,
                      dim: int = 0) -> Tuple[jax.Array, ExecResult]:
    """Route per-origin values from the root back down: entry o travels the
    root->o path (``depth(o)`` edges; at each hop a parent forwards to each
    child exactly the entries for that child's subtree). Returns each node's
    own entry ``(n, ...)`` and the measured ledger (symmetric to
    :func:`tree_gather_exec`)."""
    root_values = jnp.asarray(root_values)
    if root_values.shape[0] != schedule.n:
        raise ValueError(f"root_values must be origin-indexed: got leading "
                         f"dim {root_values.shape[0]} for a {schedule.n}-"
                         f"node tree")
    t0 = time.perf_counter()
    trailing = root_values.shape[1:]
    flat = root_values.reshape(schedule.n, -1)
    n = schedule.n
    vals0 = jnp.zeros((n, n, flat.shape[1]), flat.dtype).at[
        schedule.root].set(flat)
    sub = jnp.asarray(schedule.subtree)

    def body(carry, lvl):
        vals = carry
        nodes, lmask = lvl
        par = jnp.asarray(schedule.parent)[nodes]
        want = sub[nodes] & lmask[:, None]                     # (W, n)
        hops = want.astype(jnp.int32).sum(axis=0)
        vals = vals.at[nodes].set(
            jnp.where(want[:, :, None], vals[par], vals[nodes]))
        return vals, hops

    vals, hops = _level_scan(schedule, body, vals0, bottom_up=False)
    per_origin = np.asarray(hops.sum(axis=0) if schedule.height else
                            np.zeros(n, np.int64))
    own = vals[jnp.arange(n), jnp.arange(n)]
    ledger = _units_ledger(per_origin, unit_scalars, unit_points, dim,
                           count_all_messages=False,
                           per_origin_link=_path_link_costs(schedule,
                                                            per_origin))
    res = ExecResult(rounds=schedule.height,
                     rounds_to_complete=schedule.height, ledger=ledger,
                     per_round_transmissions=[int(x) for x in
                                              np.asarray(hops.sum(axis=1))]
                     if schedule.height else [],
                     wall_s=time.perf_counter() - t0)
    return own.reshape((n,) + trailing), res


def tree_up_sum_exec(schedule: TreeSchedule, values: jax.Array,
                     broadcast: bool = True, unit_scalars: Units = 0.0,
                     unit_points: Units = 0.0, dim: int = 0
                     ) -> Tuple[jax.Array, ExecResult]:
    """Up-*sum*: each node sends one aggregated payload to its parent after
    hearing from all children (n-1 fixed-size transmissions); with
    ``broadcast`` the root's total is then sent down every edge (n-1 more),
    so every node ends holding the global sum. ``unit_*`` price one
    transmission (the aggregate has the same size everywhere).

    Note the tree-structured accumulation order differs from a flat
    ``jnp.sum`` in float, so exact-replay protocols (the distributed
    Round-1 allocation) route the raw scalars via gather/scatter instead
    and use this primitive only where a sum is the final answer."""
    values = jnp.asarray(values)
    if values.shape[0] != schedule.n:
        raise ValueError(f"values must be node-indexed: got leading dim "
                         f"{values.shape[0]} for a {schedule.n}-node tree")
    t0 = time.perf_counter()
    trailing = values.shape[1:]
    flat = values.reshape(schedule.n, -1)

    def up(acc, lvl):
        nodes, lmask = lvl
        par = jnp.asarray(schedule.parent)[nodes]
        contrib = jnp.where(lmask[:, None], acc[nodes],
                            jnp.zeros((), flat.dtype))
        acc = acc.at[par].add(contrib)
        return acc, lmask.sum()

    acc, up_sends = _level_scan(schedule, up, flat, bottom_up=True)
    total = acc[schedule.root]
    sends = int(np.asarray(up_sends).sum()) if schedule.height else 0
    w_sends = _level_edge_cost_total(schedule) if sends else 0.0
    per_round = ([int(x) for x in np.asarray(up_sends)]
                 if schedule.height else [])
    if broadcast:
        out, bres = tree_broadcast_exec(schedule, total,
                                        unit_scalars=unit_scalars,
                                        unit_points=unit_points, dim=dim)
        sends_total = sends + int(bres.ledger.messages)
        w_sends = w_sends + (_level_edge_cost_total(schedule)
                             if bres.ledger.messages else 0.0)
        per_round = per_round + bres.per_round_transmissions
    else:
        out = jnp.broadcast_to(total, (schedule.n,) + total.shape)
        sends_total = sends
    ledger = _units_ledger(np.asarray([sends_total], np.float64),
                           unit_scalars, unit_points, dim,
                           count_all_messages=False,
                           per_origin_link=np.asarray([w_sends], np.float64))
    res = ExecResult(rounds=schedule.height * (2 if broadcast else 1),
                     rounds_to_complete=schedule.height, ledger=ledger,
                     per_round_transmissions=per_round,
                     wall_s=time.perf_counter() - t0)
    return out.reshape((schedule.n,) + trailing), res


def tree_broadcast_exec(schedule: TreeSchedule, value: jax.Array,
                        unit_scalars: Units = 0.0, unit_points: Units = 0.0,
                        dim: int = 0) -> Tuple[jax.Array, ExecResult]:
    """Root sends one payload down every tree edge, level by level (n-1
    transmissions). Returns every node's (bit-identical) copy ``(n, ...)``
    and the measured ledger (equals ``tree_broadcast_cost``)."""
    value = jnp.asarray(value)
    t0 = time.perf_counter()
    flat = value.reshape(-1)
    vals0 = jnp.zeros((schedule.n, flat.shape[0]), flat.dtype).at[
        schedule.root].set(flat)

    def body(vals, lvl):
        nodes, lmask = lvl
        par = jnp.asarray(schedule.parent)[nodes]
        vals = vals.at[nodes].set(
            jnp.where(lmask[:, None], vals[par], vals[nodes]))
        return vals, lmask.sum()

    vals, sends = _level_scan(schedule, body, vals0, bottom_up=False)
    n_sends = int(np.asarray(sends).sum()) if schedule.height else 0
    w_sends = _level_edge_cost_total(schedule) if n_sends else 0.0
    ledger = _units_ledger(np.asarray([n_sends], np.float64), unit_scalars,
                           unit_points, dim, count_all_messages=False,
                           per_origin_link=np.asarray([w_sends], np.float64))
    res = ExecResult(rounds=schedule.height,
                     rounds_to_complete=schedule.height, ledger=ledger,
                     per_round_transmissions=[int(x) for x in
                                              np.asarray(sends)]
                     if schedule.height else [],
                     wall_s=time.perf_counter() - t0)
    return vals.reshape((schedule.n,) + value.shape), res


# ---------------------------------------------------------------------------
# SPMD ring + 2-D torus collectives (shard_map primitives)
# ---------------------------------------------------------------------------

def _check_axis_size(axis_name: str, axis_size: int, fn: str) -> None:
    """Fail loudly when the caller's ``axis_size`` disagrees with the mesh.

    The ring/torus permutations are built from the *claimed* ``axis_size``;
    a mismatch used to produce a silently wrong answer (the fori_loop runs
    the wrong number of hops and the permutation indexes phantom devices).
    ``psum(1, axis)`` is static under ``shard_map`` in this jax version, so
    the check costs nothing at runtime; if a future tracer makes it dynamic
    we skip rather than mis-raise.
    """
    if axis_size < 1:
        raise ValueError(f"{fn}: axis_size must be >= 1, got {axis_size}")
    actual = jax.lax.psum(1, axis_name)
    if isinstance(actual, (int, np.integer)) and int(actual) != axis_size:
        raise ValueError(
            f"{fn}: axis_size={axis_size} disagrees with the actual size "
            f"{int(actual)} of mesh axis {axis_name!r}; the ppermute "
            "schedule would be silently wrong")


def neighbor_rounds_sum(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Global sum via ring neighbour exchanges only (collective_permute),
    demonstrating Algorithm 3 on a physical ring: after ``axis_size - 1``
    rounds each device has accumulated every shard's value.

    Must be called inside ``shard_map`` over ``axis_name``. The hop-by-hop
    accumulation order is fixed by the ring schedule, so repeated runs are
    bit-identical to each other (deterministic reduction order), but the
    float total may differ from ``psum`` in the last ulps.
    """
    _check_axis_size(axis_name, axis_size, "neighbor_rounds_sum")
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    def body(i, carry):
        acc, buf = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        return acc + buf, buf

    acc, _ = jax.lax.fori_loop(0, axis_size - 1, body, (x, x))
    return acc


def neighbor_rounds_gather(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """All-gather via ring neighbour exchanges (Algorithm 3 Round 2 on a
    physical ring): returns (axis_size, *x.shape) on every device.

    Every slot of the output is a pure ppermute relay of the origin shard,
    so the result is bit-identical to ``jax.lax.all_gather``.
    """
    _check_axis_size(axis_name, axis_size, "neighbor_rounds_gather")
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((axis_size,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, x, idx, 0)
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    def body(i, carry):
        out, buf, src = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        src = (src - 1) % axis_size
        out = jax.lax.dynamic_update_index_in_dim(out, buf, src, 0)
        return out, buf, src

    out, _, _ = jax.lax.fori_loop(0, axis_size - 1, body, (out, x, idx))
    return out


def torus_mesh_shape(axis_size: int) -> Tuple[int, int]:
    """Most-square (R, C) factorization of ``axis_size`` (R <= C).

    Default ``mesh_shape`` for ``collectives="torus_2d"``: the squarest
    factorization minimizes (R - 1) + (C - 1) hops over all 2-D foldings
    of a flat axis. Prime sizes degenerate to (1, axis_size) == the ring.
    """
    if axis_size < 1:
        raise ValueError(f"axis_size must be >= 1, got {axis_size}")
    r = int(np.sqrt(axis_size))
    while axis_size % r:
        r -= 1
    return r, axis_size // r


def _torus_perms(axis_name: str, mesh_shape: Tuple[int, int], fn: str):
    """Validate (R, C) against the flat mesh axis and build the two
    single-hop permutations: row phase (r, c) -> (r, (c+1) % C) and column
    phase (r, c) -> ((r+1) % R, c), in row-major flat indexing
    i = r * C + c (the order ``jax.make_mesh`` assigns devices)."""
    R, C = mesh_shape
    if R < 1 or C < 1:
        raise ValueError(f"{fn}: mesh_shape must be positive, got {mesh_shape}")
    _check_axis_size(axis_name, R * C, fn)
    row_perm = [(r * C + c, r * C + (c + 1) % C)
                for r in range(R) for c in range(C)]
    col_perm = [(r * C + c, ((r + 1) % R) * C + c)
                for r in range(R) for c in range(C)]
    return row_perm, col_perm


def torus_rounds_gather(x: jax.Array, axis_name: str,
                        mesh_shape: Tuple[int, int]) -> jax.Array:
    """All-gather on a 2-D torus folding of the flat mesh axis.

    Two phases: (C - 1) row-ring hops gather each device's row of C shards,
    then (R - 1) column-ring hops gather the per-row buffers -- a total of
    (R - 1) + (C - 1) sequential hops instead of the 1-D ring's R*C - 1.
    Returns (R * C, *x.shape) in flat row-major order, bit-identical to
    ``jax.lax.all_gather`` (every output slot is a pure ppermute relay).

    Must be called inside ``shard_map`` over ``axis_name`` with
    ``R * C == axis_size``.
    """
    R, C = mesh_shape
    row_perm, col_perm = _torus_perms(axis_name, mesh_shape,
                                      "torus_rounds_gather")
    idx = jax.lax.axis_index(axis_name)
    r, c = idx // C, idx % C

    # row phase: gather the C shards of this device's row
    row = jnp.zeros((C,) + x.shape, x.dtype)
    row = jax.lax.dynamic_update_index_in_dim(row, x, c, 0)

    def rbody(j, carry):
        row, buf, src = carry
        buf = jax.lax.ppermute(buf, axis_name, row_perm)
        src = (src - 1) % C
        row = jax.lax.dynamic_update_index_in_dim(row, buf, src, 0)
        return row, buf, src

    row, _, _ = jax.lax.fori_loop(0, C - 1, rbody, (row, x, c))

    # column phase: gather the R row-buffers of this device's column
    out = jnp.zeros((R,) + row.shape, x.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, row, r, 0)

    def cbody(j, carry):
        out, buf, src = carry
        buf = jax.lax.ppermute(buf, axis_name, col_perm)
        src = (src - 1) % R
        out = jax.lax.dynamic_update_index_in_dim(out, buf, src, 0)
        return out, buf, src

    out, _, _ = jax.lax.fori_loop(0, R - 1, cbody, (out, row, r))
    # (R, C, ...) row-major == flat device order i = r * C + c
    return out.reshape((R * C,) + x.shape)


def torus_rounds_sum(x: jax.Array, axis_name: str,
                     mesh_shape: Tuple[int, int]) -> jax.Array:
    """Global sum on a 2-D torus folding: row-ring partial sums in C - 1
    hops, then column-ring reduction of the row totals in R - 1 hops.

    Deterministic reduction order (bit-identical across repeated runs) but,
    like ``neighbor_rounds_sum``, the grouping differs from ``psum`` so the
    float total may differ in the last ulps; it may also differ from the
    1-D ring's total (different association order).
    """
    row_perm, col_perm = _torus_perms(axis_name, mesh_shape,
                                      "torus_rounds_sum")
    R, C = mesh_shape

    def ring_sum(v, perm, hops):
        def body(i, carry):
            acc, buf = carry
            buf = jax.lax.ppermute(buf, axis_name, perm)
            return acc + buf, buf
        acc, _ = jax.lax.fori_loop(0, hops, body, (v, v))
        return acc

    return ring_sum(ring_sum(x, row_perm, C - 1), col_perm, R - 1)


def collective_hops(collectives: str, axis_size: int,
                    mesh_shape: Optional[Tuple[int, int]] = None) -> int:
    """Sequential ppermute-hop depth of one gather under each schedule.

    ``all_gather`` is counted at the ring depth axis_size - 1 (XLA's ICI
    lowering of a flat-axis all-gather is the same ring); ``torus_2d`` is
    (R - 1) + (C - 1). Used by bench_collectives and the roofline ledgers.
    """
    if collectives in ("all_gather", "neighbor_rounds"):
        return axis_size - 1
    if collectives == "torus_2d":
        R, C = torus_mesh_shape(axis_size) if mesh_shape is None else mesh_shape
        if R * C != axis_size:
            raise ValueError(
                f"mesh_shape {mesh_shape} does not tile axis_size {axis_size}")
        return (R - 1) + (C - 1)
    raise ValueError(f"unknown collectives mode: {collectives!r}")
