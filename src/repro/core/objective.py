"""First-class clustering objectives (DESIGN.md Sec. 15).

The paper's coreset recipe is objective-generic: sensitivities, the Round-1
constant-factor solves, and Round-2 sampling only need a per-point cost and
a center-update rule. An :class:`Objective` is that contract as a frozen,
hashable descriptor -- the registry maps canonical names to instances,
mirroring :mod:`repro.core.backend`'s backend registry, and every layer
that used to branch on ``objective == "kmeans"`` strings now consumes the
descriptor's hooks instead.

**Descriptor fields** (every hook takes the descriptor itself first, so
parametrized instances -- trimmed count, power ``z`` -- stay plain
module-level functions and instance equality/hashability hold):

* ``power_z`` -- the ``z`` of the (k, z) objective: per-point cost is
  ``dist^z`` (z=2 k-means, z=1 k-median).
* ``point_cost(obj, d2)`` -- map squared distances to the objective's
  metric (``d2`` for z=2, ``sqrt(d2)`` for z=1, ``d2^(z/2)`` otherwise;
  the z in {1, 2} special cases are exact, not ``pow`` lowerings, so the
  legacy formulas are reproduced bit for bit).
* ``point_costs(obj, b, points, centers, weights)`` -- fused per-point
  costs + assignments through a backend instance ``b``; the trimmed
  variant zeroes the ``t`` largest-residual live points.
* ``update_stats(obj, b, points, weights, centers)`` -- one center-update
  pass returning ``(new_centers, cost)``: the k-means instance consumes
  the fused ``lloyd_stats`` backend primitive, the k-median instance the
  fused ``weiszfeld_stats`` primitive, generic powers an IRLS pass, and
  the trimmed instance a two-pass trim-then-``lloyd_stats`` (DESIGN.md
  Sec. 15).
* ``sensitivity_rule(obj, b, points, centers, weights)`` -- the paper's
  per-point sampling mass ``m_p`` plus the *effective weights* downstream
  stages must use (``w`` unchanged for plain objectives; zeroed on
  trimmed-out points so outliers are never sampled and never pollute the
  coreset's center weights).
* ``seeding_mass(obj, w, mind)`` -- the D^z seeding distribution of one
  k-means++ step (trimmed: the current top-``t`` residuals carry zero
  seeding mass, so seeds avoid far-field outliers).
* ``validate(obj)`` -- parameter validation, run at construction.

**Registry resolution rules**: public APIs keep accepting strings.
:func:`resolve_name` maps a selection (name, :class:`Objective` instance,
or ``None`` for ``"kmeans"``) to a canonical registry name -- suitable as
a static jit argument, exactly like ``backend.resolve_name`` -- and
**raises ValueError on unknown names** listing the registered ones (the
legacy string branches silently mis-dispatched typos like ``"kmeans "``).
Parametrized names round-trip: ``kmeans_trimmed(16)`` registers itself
under ``"kmeans_trimmed(16)"`` and resolving that string re-derives the
instance through the factory, so tree configs and serve bucket keys can
carry the plain name.

**Bit-compat discipline**: the ``"kmeans"`` / ``"kmedian"`` instances are
the exact legacy code paths (same primitives, same formula shapes, same
clamp placement), so every existing caller gets bit-identical centers,
coresets, and ledgers through the descriptor indirection.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.ref import WEISZFELD_ETA2

Array = jax.Array

_EPS = 1e-12

# Weiszfeld refinement passes per k-median update step (the fused
# assign+refine composition of DESIGN.md Sec. 10).
WEISZFELD_ITERS = 4


# ---------------------------------------------------------------------------
# trimming (shared by the trimmed hooks)
# ---------------------------------------------------------------------------

def resolve_trim_count(obj: "Objective", live_count: Array) -> Array:
    """The number of points this trimmed instance excludes, as a traced
    int32: an integer ``t_outliers`` is an absolute count, a float in
    (0, 1) a fraction of the *live* (weight-carrying) slots -- the natural
    parametrization when the same descriptor drives per-site solves and
    the final coreset solve, whose live counts differ by orders of
    magnitude. Clamped to ``[0, live_count]``."""
    t = obj.t_outliers
    if isinstance(t, float) and 0.0 < t < 1.0:
        te = jnp.floor(t * live_count.astype(jnp.float32) + 0.5)
        te = te.astype(jnp.int32)
    else:
        te = jnp.asarray(int(t), jnp.int32)
    return jnp.clip(te, 0, live_count.astype(jnp.int32))


def trim_mask(obj: "Objective", resid: Array, weights: Optional[Array]
              ) -> Array:
    """Keep-mask (n,) bool: False exactly on the ``t`` largest-residual
    *live* slots (``weights != 0``; padding and vacated slots are never
    counted against the budget). Rank-based -- a double argsort over the
    (n,) residual vector, never an (n, k) materialization -- so exactly
    ``t`` points are trimmed even under ties (deterministic index
    tie-break), and the count stays correct when ``t`` is traced
    (fractional trimming)."""
    if weights is None:
        live = jnp.ones(resid.shape, bool)
    else:
        live = weights != 0.0
    t_eff = resolve_trim_count(obj, jnp.sum(live))
    # descending residual order with dead slots last; rank[i] = position
    order = jnp.argsort(jnp.where(live, -resid, jnp.inf))
    rank = jnp.argsort(order)
    return rank >= t_eff


# ---------------------------------------------------------------------------
# default hook implementations (module-level: instances built from the same
# parameters compare/hash equal, which jit static arguments rely on)
# ---------------------------------------------------------------------------

def _pow_point_cost(obj: "Objective", d2: Array) -> Array:
    """d2 -> per-point cost in the (k, z) metric. z in {1, 2} reproduce
    the legacy formulas exactly (identity / ``jnp.sqrt``, never a ``pow``
    lowering)."""
    z = obj.power_z
    if z == 2.0:
        return d2
    if z == 1.0:
        return jnp.sqrt(d2)
    return jnp.power(jnp.maximum(d2, 0.0), 0.5 * z)


def _plain_point_costs(obj, b, points, centers, weights
                       ) -> Tuple[Array, Array]:
    d2, assign = b.min_dist_argmin(points, centers)
    return obj.point_cost(obj, d2), assign


def _trimmed_point_costs(obj, b, points, centers, weights
                         ) -> Tuple[Array, Array]:
    """Per-point costs with the top-``t`` residual live points zeroed --
    one fused assignment pass plus an (n,)-shaped rank, no (n, k)
    materialization."""
    d2, assign = b.min_dist_argmin(points, centers)
    keep = trim_mask(obj, d2, weights)
    return jnp.where(keep, obj.point_cost(obj, d2), 0.0), assign


def _kmeans_update_stats(obj, b, points, weights, centers
                         ) -> Tuple[Array, Array]:
    """One weighted Lloyd step: a single fused statistics pass
    (assignment + per-cluster sums/counts + cost) through the backend's
    ``lloyd_stats`` primitive."""
    sums, counts, c = b.lloyd_stats(points, centers, weights)
    new = sums / jnp.where(counts > _EPS, counts, 1.0)[:, None]
    new = jnp.where((counts > _EPS)[:, None], new,
                    centers.astype(jnp.float32))
    return new.astype(centers.dtype), c


def _weiszfeld_update_stats(obj, b, points, weights, centers
                            ) -> Tuple[Array, Array]:
    """One weighted alternating k-median step: ``WEISZFELD_ITERS`` fused
    refinement passes through the backend's ``weiszfeld_stats`` primitive.

    Each pass assigns every point to its nearest current center and applies
    one Weiszfeld geometric-median update to each cluster -- both the
    reassignment and the Weiszfeld step (an MM step for the Fermat-Weber
    objective) are non-increasing in k-median cost, so the composition is
    monotone. Membership mass is max(w, 0) (signed coreset measures must
    not pull medians toward negative mass); the returned cost is the signed
    assignment cost at the *incoming* centers, matching the k-means update's
    history semantics."""

    def wstep(y):
        nums, denoms, c = b.weiszfeld_stats(points, y, weights)
        ynew = nums / jnp.where(denoms > _EPS, denoms, 1.0)[:, None]
        ynew = jnp.where((denoms > _EPS)[:, None], ynew,
                         y.astype(jnp.float32))
        return ynew.astype(centers.dtype), c

    new, c = wstep(centers)
    new = jax.lax.fori_loop(1, WEISZFELD_ITERS,
                            lambda _, y: wstep(y)[0], new)
    return new, c


def _power_update_stats(obj, b, points, weights, centers
                        ) -> Tuple[Array, Array]:
    """Generic (k, z) IRLS update: one fused assignment pass, then the
    gradient-stationary weighted mean with per-point IRLS mass
    ``max(w, 0) * (d2 + eta^2)^((z-2)/2)`` -- z=2 reduces to the plain
    mean, z=1 to the eta-smoothed Weiszfeld step (those two route to the
    fused primitives instead; this path serves arbitrary z). The one-hot
    reduction materializes (n, k) in XLA, so arbitrary z is a
    dense-formulation feature; the cost is the signed, unsmoothed
    ``sum w * d2^(z/2)`` at the incoming centers. An MM-monotone step for
    z in (0, 2]; for z > 2 it is the natural fixed-point heuristic."""
    d2, assign = b.min_dist_argmin(points, centers)
    p = points.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    cost = jnp.sum(w * obj.point_cost(obj, d2))
    iw = jnp.maximum(w, 0.0) * jnp.power(d2 + WEISZFELD_ETA2,
                                         0.5 * (obj.power_z - 2.0))
    k = centers.shape[0]
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32) * iw[:, None]
    nums = oh.T @ p
    denoms = jnp.sum(oh, axis=0)
    new = nums / jnp.where(denoms > _EPS, denoms, 1.0)[:, None]
    new = jnp.where((denoms > _EPS)[:, None], new,
                    centers.astype(jnp.float32))
    return new.astype(centers.dtype), cost


def _trimmed_update_stats(obj, b, points, weights, centers
                          ) -> Tuple[Array, Array]:
    """One trimmed Lloyd step, two fused passes on every backend: pass 1
    (``min_dist_argmin``) finds the per-point residuals that rank the
    top-``t`` outliers, pass 2 (``lloyd_stats``) re-runs the fused
    statistics with those points' weights zeroed -- excluded from the
    sums, the counts, and the reported cost alike. No (n, k) matrix ever
    materializes; on the Pallas backend this is the documented two-pass
    form (DESIGN.md Sec. 15)."""
    d2, _ = b.min_dist_argmin(points, centers)
    keep = trim_mask(obj, d2, weights)
    w_t = jnp.where(keep, weights, 0.0)
    sums, counts, c = b.lloyd_stats(points, centers, w_t)
    new = sums / jnp.where(counts > _EPS, counts, 1.0)[:, None]
    new = jnp.where((counts > _EPS)[:, None], new,
                    centers.astype(jnp.float32))
    return new.astype(centers.dtype), c


def _plain_sensitivities(obj, b, points, centers, weights
                         ) -> Tuple[Array, Array, Array]:
    """The paper's m_p = |w_p| * cost(p, B) (absolute value: signed
    streaming summaries need a valid sampling distribution; DESIGN.md
    Sec. 9) with the weights passed through unchanged."""
    c, assign = obj.point_costs(obj, b, points, centers, weights)
    return jnp.abs(weights) * c, assign, weights


def _trimmed_sensitivities(obj, b, points, centers, weights
                           ) -> Tuple[Array, Array, Array]:
    """Trimmed sampling masses: the top-``t`` residual points carry zero
    mass (never sampled into the coreset) AND zero effective weight, so
    their mass does not land on their assigned center's ``w_b`` either --
    the trimmed coreset genuinely drops the outliers instead of folding
    them back in through the center-weight identity."""
    d2, assign = b.min_dist_argmin(points, centers)
    keep = trim_mask(obj, d2, weights)
    w_eff = jnp.where(keep, weights, 0.0)
    return jnp.abs(w_eff) * obj.point_cost(obj, d2), assign, w_eff


def _plain_seeding_mass(obj, w, mind) -> Array:
    return w * mind


def _trimmed_seeding_mass(obj, w, mind) -> Array:
    """D^2 seeding mass with the current top-``t`` residuals zeroed: far-
    field outliers otherwise dominate the D^2 distribution (a 5% fraction
    at 10x radius carries ~80% of the mass) and seeds land on exactly the
    points the update pass will trim."""
    keep = trim_mask(obj, mind, w)
    return w * jnp.where(keep, mind, 0.0)


def _plain_validate(obj) -> None:
    if not obj.power_z > 0.0:
        raise ValueError(f"objective power_z must be > 0, got "
                         f"{obj.power_z}")
    if obj.t_outliers:
        raise ValueError(f"objective {obj.name!r} does not support "
                         f"t_outliers (use kmeans_trimmed)")


def _trimmed_validate(obj) -> None:
    t = obj.t_outliers
    bad = (t < 0 or (isinstance(t, float)
                     and not (0.0 < t < 1.0) and t != 0.0))
    if bad:
        raise ValueError(
            f"t_outliers must be a non-negative integer count or a "
            f"fraction in (0, 1), got {t!r}")


# ---------------------------------------------------------------------------
# the descriptor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Objective:
    """A registered (k, z) clustering objective. Frozen and hashable --
    instances are valid static jit arguments, though the plumbing passes
    canonical *names* (resolved once at the public boundary) exactly like
    the backend registry does."""

    name: str
    power_z: float = 2.0
    t_outliers: Union[int, float] = 0
    point_cost: Callable = _pow_point_cost
    update_stats: Optional[Callable] = None
    point_costs: Callable = _plain_point_costs
    sensitivity_rule: Callable = _plain_sensitivities
    seeding_mass: Callable = _plain_seeding_mass
    validate: Callable = _plain_validate

    def __post_init__(self):
        if self.update_stats is None:
            upd = (_kmeans_update_stats if self.power_z == 2.0 else
                   _weiszfeld_update_stats if self.power_z == 1.0 else
                   _power_update_stats)
            object.__setattr__(self, "update_stats", upd)
        self.validate(self)

    # -- convenience wrappers (hooks take the descriptor first) --------------

    def per_point_cost(self, d2: Array) -> Array:
        """Raw metric map d2 -> cost (no clamp: callers that feed backend
        outputs rely on the backend's own nonnegativity contract)."""
        return self.point_cost(self, d2)

    def clamped_cost(self, d2: Array) -> Array:
        """Metric map with a defensive clamp for the z != 2 branches --
        the exact formula the legacy seeding and query paths used
        (``d2`` unchanged for z=2, ``point_cost(max(d2, 0))`` otherwise),
        preserved bit for bit."""
        if self.power_z == 2.0:
            return d2
        return self.point_cost(self, jnp.maximum(d2, 0.0))

    def costs(self, b, points: Array, centers: Array,
              weights: Optional[Array] = None) -> Tuple[Array, Array]:
        """Fused per-point costs + assignments via backend ``b``."""
        return self.point_costs(self, b, points, centers, weights)

    def update(self, b, points: Array, weights: Array, centers: Array
               ) -> Tuple[Array, Array]:
        """One center-update pass: (new_centers, cost-at-incoming)."""
        return self.update_stats(self, b, points, weights, centers)

    def sensitivities(self, b, points: Array, centers: Array,
                      weights: Array) -> Tuple[Array, Array, Array]:
        """(m, assign, w_eff): sampling masses, assignments, and the
        effective weights Round-2 sampling / center-weighting must use."""
        return self.sensitivity_rule(self, b, points, centers, weights)

    def seeding(self, w: Array, mind: Array) -> Array:
        """Seeding mass of one k-means++ step."""
        return self.seeding_mass(self, w, mind)


# ---------------------------------------------------------------------------
# registry + factories
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Objective] = {}

ObjectiveLike = Union[str, Objective, None]


def register_objective(obj: Objective) -> Objective:
    """Add an objective to the registry (a new robust or power objective is
    one ``register_objective`` call). Re-registering the *same* instance
    (or an equal one) is a no-op; shadowing a name with a different
    objective raises -- jitted entry points cache compiled traces keyed on
    the name, so a silent swap would serve stale numerics."""
    existing = _REGISTRY.get(obj.name)
    if existing is not None and existing != obj:
        raise ValueError(
            f"a different objective is already registered as {obj.name!r}; "
            f"give this instance a unique name")
    _REGISTRY[obj.name] = obj
    return obj


def available_objectives() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


KMEANS = register_objective(Objective(name="kmeans", power_z=2.0))
KMEDIAN = register_objective(Objective(name="kmedian", power_z=1.0))


def _canonical_count(t: Union[int, float]) -> Union[int, float]:
    """16.0 and 16 are the same trim budget; fold to int so the factory
    cache and the registered name agree."""
    if isinstance(t, float) and t.is_integer() and not 0.0 < t < 1.0:
        return int(t)
    return t


@functools.lru_cache(maxsize=None)
def _kmeans_trimmed(t: Union[int, float]) -> Objective:
    return register_objective(Objective(
        name=f"kmeans_trimmed({t:g})", power_z=2.0, t_outliers=t,
        update_stats=_trimmed_update_stats,
        point_costs=_trimmed_point_costs,
        sensitivity_rule=_trimmed_sensitivities,
        seeding_mass=_trimmed_seeding_mass,
        validate=_trimmed_validate))


def kmeans_trimmed(t_outliers: Union[int, float]) -> Objective:
    """Trimmed outlier-robust k-means: cost, update statistics, seeding
    mass, and sampling sensitivities all exclude the ``t_outliers``
    largest-residual live points (an integer count, or a float in (0, 1)
    for a fraction of the live slots). Registered under
    ``kmeans_trimmed(<t>)`` so the name round-trips through tree configs,
    jit static arguments, and serve bucket keys."""
    return _kmeans_trimmed(_canonical_count(t_outliers))


@functools.lru_cache(maxsize=None)
def _power(z: float) -> Objective:
    return register_objective(Objective(name=f"power({z:g})", power_z=z))


def power_objective(z: float) -> Objective:
    """Generalized (k, z) power-cost objective: per-point cost
    ``dist^z``. z=1 and z=2 share the exact fused k-median / k-means code
    paths (bit-identical costs and updates); other z run the IRLS update
    of :func:`_power_update_stats` (dense-formulation reduction)."""
    return _power(float(z))


_PARAM_NAME = re.compile(
    r"^(?P<factory>[a-z][a-z0-9_]*)\((?P<arg>[-+]?[0-9.eE+-]+)\)$")

_FACTORIES: Dict[str, Callable] = {
    "kmeans_trimmed": kmeans_trimmed,
    "power": power_objective,
}


def _parse_number(s: str) -> Union[int, float]:
    try:
        return int(s)
    except ValueError:
        return float(s)


def _resolve_parametrized(name: str) -> Optional[Objective]:
    m = _PARAM_NAME.match(name)
    if m is None:
        return None
    factory = _FACTORIES.get(m.group("factory"))
    if factory is None:
        return None
    try:
        obj = factory(_parse_number(m.group("arg")))
    except ValueError:
        return None
    # only accept round-trips: "kmeans_trimmed(2.0)" must not silently
    # alias the canonical "kmeans_trimmed(2)" under a second jit cache key
    return obj if obj.name == name else None


def resolve_name(objective: ObjectiveLike) -> str:
    """Resolve a selection (canonical name, :class:`Objective` instance,
    or ``None`` for the k-means default) to a registry name, raising
    ``ValueError`` on unknown strings. This is the single boundary where
    the legacy string API meets the descriptor layer: every public entry
    point resolves here once, then threads the canonical name through its
    static jit arguments."""
    if objective is None:
        return KMEANS.name
    if isinstance(objective, Objective):
        return register_objective(objective).name
    if not isinstance(objective, str):
        raise TypeError(f"objective must be a name or Objective, got "
                        f"{type(objective).__name__}")
    if objective in _REGISTRY:
        return objective
    obj = _resolve_parametrized(objective)
    if obj is not None:
        return obj.name
    raise ValueError(
        f"unknown objective {objective!r}; known objectives: "
        f"{', '.join(available_objectives())} (plus parametrized "
        f"'kmeans_trimmed(<t>)' / 'power(<z>)')")


def get_objective(objective: ObjectiveLike = None) -> Objective:
    """Resolve a selection to the descriptor instance. Pure registry
    lookup for already-canonical names -- safe at trace time inside jitted
    functions, exactly like ``backend.get_backend``."""
    if isinstance(objective, Objective):
        register_objective(objective)
        return objective
    return _REGISTRY[resolve_name(objective)]
