"""Data partitioning across sites (paper Sec. 5 experimental methodology).

Given a global dataset, distribute points to ``n`` sites by one of:

* ``uniform``    -- each point i.i.d. uniform over sites;
* ``similarity`` -- each site gets a random anchor point; points are assigned
  with probability proportional to a Gaussian kernel similarity to the
  anchors;
* ``weighted``   -- site weights ~ |N(0,1)|, points assigned proportionally;
* ``degree``     -- probability proportional to the site's degree in the
  communication graph (preferential-attachment experiments).

Sites receive variable-size shards; :func:`pad_partition` converts them to the
fixed-shape (n, max_size, d) + mask representation that the vmapped/SPMD JAX
paths require (XLA static shapes -- documented deviation in DESIGN.md Sec. 7).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


def partition_indices(
    data: np.ndarray,
    n_sites: int,
    method: str = "uniform",
    seed: int = 0,
    degrees: Optional[np.ndarray] = None,
) -> List[np.ndarray]:
    """Return per-site index arrays into ``data``."""
    rng = np.random.default_rng(seed)
    n_pts = data.shape[0]
    if method == "uniform":
        probs = np.full((n_pts, n_sites), 1.0 / n_sites)
    elif method == "similarity":
        anchors = data[rng.choice(n_pts, size=n_sites, replace=False)]
        # Gaussian kernel similarity; bandwidth = mean anchor-anchor distance
        d2 = ((data[:, None, :] - anchors[None, :, :]) ** 2).sum(-1) \
            if n_pts * n_sites * data.shape[1] < 5e8 else _chunked_d2(data, anchors)
        a2 = ((anchors[:, None, :] - anchors[None, :, :]) ** 2).sum(-1)
        bw = np.sqrt(a2[a2 > 0].mean()) if (a2 > 0).any() else 1.0
        sim = np.exp(-d2 / (2.0 * bw * bw))
        probs = sim / np.maximum(sim.sum(1, keepdims=True), 1e-30)
    elif method == "weighted":
        w = np.abs(rng.standard_normal(n_sites))
        w = np.maximum(w, 1e-3)
        probs = np.tile(w / w.sum(), (n_pts, 1))
    elif method == "degree":
        if degrees is None:
            raise ValueError("degree partition requires the graph degrees")
        w = degrees.astype(np.float64)
        probs = np.tile(w / w.sum(), (n_pts, 1))
    else:
        raise ValueError(f"unknown partition method: {method}")
    # vectorized categorical draw per point
    cum = probs.cumsum(axis=1)
    u = rng.random((n_pts, 1))
    site = (u > cum).sum(axis=1).clip(0, n_sites - 1)
    out = [np.nonzero(site == s)[0] for s in range(n_sites)]
    # every site must own at least one point (the paper's sites are non-empty)
    for s in range(n_sites):
        if len(out[s]) == 0:
            donor = int(np.argmax([len(o) for o in out]))
            out[s] = out[donor][-1:]
            out[donor] = out[donor][:-1]
    return out


def _chunked_d2(data: np.ndarray, anchors: np.ndarray, chunk: int = 65536
                ) -> np.ndarray:
    out = np.empty((data.shape[0], anchors.shape[0]), dtype=np.float64)
    a2 = (anchors ** 2).sum(-1)
    for i in range(0, data.shape[0], chunk):
        blk = data[i:i + chunk]
        out[i:i + chunk] = (blk ** 2).sum(-1, keepdims=True) + a2[None, :] \
            - 2.0 * blk @ anchors.T
    return out


def pad_partition(
    data: np.ndarray,
    indices: List[np.ndarray],
    pad_multiple: int = 8,
) -> Tuple[np.ndarray, np.ndarray]:
    """Stack variable-size shards into (n_sites, max_size, d) + bool mask."""
    n_sites = len(indices)
    max_size = max(len(ix) for ix in indices)
    max_size = int(np.ceil(max_size / pad_multiple) * pad_multiple)
    d = data.shape[1]
    out = np.zeros((n_sites, max_size, d), dtype=data.dtype)
    mask = np.zeros((n_sites, max_size), dtype=bool)
    for s, ix in enumerate(indices):
        out[s, : len(ix)] = data[ix]
        mask[s, : len(ix)] = True
    return out, mask
