"""First-class coreset round protocols (DESIGN.md Sec. 16).

Algorithm 1's two-round choreography -- local solve, scalar exchange,
proportional allocation, local sample -- used to be re-implemented inline
by every engine (host sim, gossip exec, tree exec, SPMD collectives, WAN
async, streaming aggregation). A :class:`CoresetStrategy` is that
choreography as a frozen, hashable descriptor: the registry maps canonical
names to instances, mirroring :mod:`repro.core.backend` and
:mod:`repro.core.objective`, and every engine now consumes the
descriptor's hooks instead of hard-coding the paper's round structure.
Engines own the *transport* (how payloads physically move); strategies own
the *protocol* (what is computed locally, what is exchanged, how the
sample budget is split, and how the sampled portions are weighted).

**Descriptor hooks** (every hook takes the descriptor itself first, so
parametrized instances stay plain module-level functions and instance
equality/hashability hold):

* ``derive_keys(strat, key, n_sites)`` -- the all-site PRNG discipline:
  one ``(n_sites, 2, ...)`` key table covering Round 1 (column 0) and
  Round 2 (column 1) for *every* site, dead or alive. Consolidated here
  because the sim, exec, tree, and async engines each used to re-derive
  it independently (a silent-skew hazard: any drift broke the
  engine-bit-parity contract); now they all consume this one hook and a
  regression test asserts the keys agree per ``(seed, strategy)``.
* ``local_summary(strat, keys, site_points, w_site, *, k, objective,
  lloyd_iters, backend)`` -- Round 1's purely-local stage, vmapped over
  sites: returns ``(centers, m, assign, local_costs, w_eff)`` where ``m``
  is the strategy's per-point sampling mass and ``local_costs`` the
  per-site scalar the exchange round moves (if any).
* ``exchange_spec(strat)`` -- the declared communication shape of
  Round 1: an :class:`ExchangeSpec` (each site contributes
  ``unit_scalars`` scalars that must reach the allocator), or ``None``
  for single-shuffle strategies whose allocation is locally derivable --
  engines skip the scalar round entirely and price zero Round-1 traffic.
* ``allocate(strat, costs, t)`` -- split the global budget ``t`` into
  per-site draws ``t_i`` from the (received or locally-known) scalars;
  must satisfy ``sum(t_i) == t`` exactly.
* ``local_contribution(strat, keys, site_points, r1, t_i, totals, *, k,
  t, t_buffer, clip_negative)`` -- Round 2's purely-local stage: each
  site draws its ``t_i`` samples and assembles its fixed-shape portion
  (``t_buffer + k`` slots: samples plus the local solution centers
  carrying the exact residual weights, so total mass is preserved bit
  for bit by every registered strategy). ``totals`` is the per-site
  normalizer each site uses in the weight formula: the *global* scalar
  total it received for exchanging strategies, its *own* local total for
  single-shuffle ones.
* ``assemble(strat, points, weights)`` -- stitch moved portions into one
  :class:`~repro.core.coreset.Coreset`.
* ``site_sensitivities(strat, pts, centers, w, *, objective, backend)``
  -- the unbatched sampling-mass rule, consumed by the SPMD per-device
  path (which runs one site per device and cannot use the vmapped
  ``local_summary``) and by the *staged* coreset engine's per-site
  solves (``repro.core.coreset.staged_distributed_coreset``).
* ``sample_t_total(strat, t, t_i)`` -- the per-site ``t_total``
  normalizer of the sample-weight formula (the global ``t`` for
  exchanging strategies, each site's own ``t_i`` for single-shuffle
  ones); the staged engine's split sample/finalize stages consume this
  instead of re-entering the batched ``contribute`` hook.

**Registered strategies**:

* ``"algorithm1"`` -- the paper's protocol, bit-identical to the
  pre-strategy-layer engines: sampling mass ``m_p = |w_p| cost(p, B_i)``
  (through the objective's ``sensitivity_rule``), one scalar exchanged
  per site, largest-remainder cost-proportional allocation, and the
  global-total weight formula ``w_q = (sum_j cost_j) w_q / (t m_q)``.
* ``"cohen_addad"`` -- the (1+eps)-coreset construction in the style of
  Cohen-Addad et al. (arXiv 2603.08615): the sampling mass is the
  *refined two-term sensitivity* ``s_p = m_p / cost(P_i, B_i) +
  |w_p| / W(cluster(p))`` (cost share plus inverse cluster mass -- the
  bound that upgrades constant-factor to (1+eps) guarantees), computed
  from the same fused backend primitives (one ``min_dist_argmin``
  assignment pass plus an O(n) scatter-add; no (n, k) materialization).
  Same two-round shape and byte cost as ``"algorithm1"``; the exchanged
  scalar and the allocation are the per-site refined-sensitivity totals.
* ``"mapreduce"`` -- the one-shuffle MapReduce-shaped rounds of Mazzetto
  et al. (arXiv 1904.12728): **no scalar exchange** (``exchange_spec``
  is ``None``) -- the budget splits uniformly by largest remainder,
  which every site derives locally -- and each site builds a standalone
  local coreset of its own data (weight formula normalized by its *own*
  sensitivity total and its *own* ``t_i``); composability of
  eps-coresets makes the union a coreset of the union. One gather of
  the per-site portions (map -> shuffle -> reduce) replaces Algorithm
  1's two diameter floods, so its byte cost strictly undercuts
  ``"algorithm1"`` on every topology.

**Registry resolution rules**: public APIs accept strategy names (or
instances, or ``None`` for ``"algorithm1"``); :func:`resolve_name` maps a
selection to a canonical registry name -- the jit-static currency, exactly
like the backend and objective registries -- and raises ``ValueError``
listing the registered names on anything unknown.

**Bit-compat discipline** (DESIGN.md Sec. 16): ``"algorithm1"``'s hooks
delegate to the exact pre-refactor stage functions
(:func:`~repro.core.coreset.round1_local_solves` /
:func:`~repro.core.coreset.round2_local_samples`) with the same key
derivation, so every engine's centers, coresets, and ledgers are
bit-identical through the descriptor indirection.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array

_TINY = 1e-30


class Round1State(NamedTuple):
    """Per-site output of a strategy's Round-1 local stage (all arrays
    site-major). ``m`` is the strategy's sampling mass (the paper's
    ``m_p`` for ``"algorithm1"``, the refined sensitivity for
    ``"cohen_addad"``); ``local_costs`` the per-site exchange scalar
    (``m.sum(axis=1)``); ``w_eff`` the objective's effective weights
    Round 2 must sample and center-weight with."""

    centers: Array      # (n_sites, k, d)
    m: Array            # (n_sites, M)
    assign: Array       # (n_sites, M)
    local_costs: Array  # (n_sites,)
    w_eff: Array        # (n_sites, M)


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """Declared shape of the Round-1 exchange: every site contributes
    ``unit_scalars`` scalars that must reach every allocator (flooded on
    graphs, gathered+scattered on trees, all-gathered on meshes)."""

    unit_scalars: float = 1.0


# ---------------------------------------------------------------------------
# hook implementations (module-level so instances compare/hash equal)
# ---------------------------------------------------------------------------

def _split_keys(strat: "CoresetStrategy", key: Array, n_sites: int) -> Array:
    """The all-site key table: ``split(key, 2 n)`` reshaped to
    ``(n, 2, ...)`` -- column 0 drives Round 1, column 1 Round 2. Spanning
    *all* sites (dead or not) is what keeps survivor-site values
    bit-identical however many peers fault out (DESIGN.md Sec. 14)."""
    return jax.random.split(key, n_sites * 2).reshape(n_sites, 2, -1)


def _alg1_local_summary(strat, keys, site_points, w_site, *, k, objective,
                        lloyd_iters, backend) -> Round1State:
    from repro.core.coreset import round1_local_solves
    return Round1State(*round1_local_solves(
        keys, site_points, w_site, k=k, objective=objective,
        lloyd_iters=lloyd_iters, backend=backend))


def _refined_sensitivities(m: Array, assign: Array, w_eff: Array,
                           k: int) -> Array:
    """The two-term (1+eps) sensitivity bound from the plain masses: per
    point, its share of the local cost plus its share of its cluster's
    mass. O(n) on top of the fused assignment pass (a scatter-add over k
    cluster slots); zero-mass (padding / trimmed-out) slots keep exactly
    zero sampling mass."""
    aw = jnp.abs(w_eff)
    cluster_mass = jnp.zeros((k,), aw.dtype).at[assign].add(aw)
    total = jnp.sum(m)
    s = (m / jnp.maximum(total, _TINY)
         + aw / jnp.maximum(cluster_mass[assign], _TINY))
    return jnp.where(aw > 0.0, s, 0.0)


def _cohen_addad_local_summary(strat, keys, site_points, w_site, *, k,
                               objective, lloyd_iters, backend
                               ) -> Round1State:
    from repro.core.coreset import round1_local_solves
    centers, m, assign, _, w_eff = round1_local_solves(
        keys, site_points, w_site, k=k, objective=objective,
        lloyd_iters=lloyd_iters, backend=backend)
    s = _refine_batch(m, assign, w_eff, k=k)
    return Round1State(centers, s, assign, s.sum(axis=1), w_eff)


@functools.partial(jax.jit, static_argnames=("k",))
def _refine_batch(m, assign, w_eff, k):
    return jax.vmap(lambda mi, ai, wi: _refined_sensitivities(mi, ai, wi, k)
                    )(m, assign, w_eff)


def _scalar_exchange(strat) -> Optional[ExchangeSpec]:
    return ExchangeSpec(unit_scalars=1.0)


def _no_exchange(strat) -> Optional[ExchangeSpec]:
    return None


def _proportional_allocate(strat, costs: Array, t: int) -> Array:
    from repro.core.coreset import proportional_allocation
    return proportional_allocation(costs, t)


def _uniform_allocate(strat, costs: Array, t: int) -> Array:
    """Largest-remainder over uniform shares: locally derivable at every
    site from ``n_sites`` and ``t`` alone (``costs`` contributes only its
    length), which is what lets the mapreduce strategy skip the scalar
    exchange entirely."""
    from repro.core.coreset import proportional_allocation
    return proportional_allocation(jnp.ones_like(costs), t)


def _alg1_local_contribution(strat, keys, site_points, r1: Round1State,
                             t_i, totals, *, k, t, t_buffer, clip_negative):
    from repro.core.coreset import round2_local_samples
    return round2_local_samples(
        keys, site_points, r1.m, r1.w_eff, r1.assign, r1.centers, t_i,
        totals, k=k, t=t, t_buffer=t_buffer, clip_negative=clip_negative)


def _mapreduce_local_contribution(strat, keys, site_points, r1: Round1State,
                                  t_i, totals, *, k, t, t_buffer,
                                  clip_negative):
    """Per-site *standalone* coresets: the weight formula normalizes by
    the site's own sensitivity total (``totals`` carries each site's own
    scalar on no-exchange strategies) and its own ``t_i`` -- each portion
    is an eps-coreset of its site's data alone, and the union is a
    coreset of the union by composability. No cross-site quantity
    appears anywhere, which is what makes the single shuffle sufficient."""
    from repro.core.coreset import round2_local_samples_localized
    return round2_local_samples_localized(
        keys, site_points, r1.m, r1.w_eff, r1.assign, r1.centers, t_i,
        totals, k=k, t_buffer=t_buffer, clip_negative=clip_negative)


def _flatten_assemble(strat, points: Array, weights: Array):
    from repro.core.coreset import Coreset
    d = points.shape[-1]
    return Coreset(points=points.reshape(-1, d),
                   weights=weights.reshape(-1))


def _plain_site_sensitivities(strat, pts, centers, w, *, objective, backend):
    from repro.core.coreset import sensitivities
    return sensitivities(pts, centers, w, objective=objective,
                         backend=backend)


def _refined_site_sensitivities(strat, pts, centers, w, *, objective,
                                backend):
    from repro.core.coreset import sensitivities
    m, assign, w_eff = sensitivities(pts, centers, w, objective=objective,
                                     backend=backend)
    k = centers.shape[0]
    return _refined_sensitivities(m, assign, w_eff, k), assign, w_eff


def _global_t_total(strat, t: int, t_i: Array) -> Array:
    """Exchanging strategies normalize the sample-weight formula by the
    *global* budget ``t`` (round2_local_samples' rule), replicated
    per site."""
    return jnp.full(t_i.shape, float(t), jnp.float32)


def _own_t_total(strat, t: int, t_i: Array) -> Array:
    """Single-shuffle strategies normalize by each site's *own* realized
    draw count (round2_local_samples_localized's rule)."""
    return t_i.astype(jnp.float32)


def _no_validate(strat) -> None:
    pass


# ---------------------------------------------------------------------------
# the descriptor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoresetStrategy:
    """A registered distributed-coreset round protocol. Frozen and
    hashable -- instances are valid static jit arguments, though the
    plumbing passes canonical *names* (resolved once at the public
    boundary), exactly like the backend and objective registries."""

    name: str
    derive_keys_fn: Callable = _split_keys
    local_summary_fn: Callable = _alg1_local_summary
    exchange_spec_fn: Callable = _scalar_exchange
    allocate_fn: Callable = _proportional_allocate
    local_contribution_fn: Callable = _alg1_local_contribution
    assemble_fn: Callable = _flatten_assemble
    site_sensitivities_fn: Callable = _plain_site_sensitivities
    sample_t_total_fn: Callable = _global_t_total
    validate: Callable = _no_validate

    def __post_init__(self):
        self.validate(self)

    # -- convenience wrappers (hooks take the descriptor first) --------------

    def keys(self, key: Array, n_sites: int) -> Array:
        """The all-site ``(n_sites, 2, ...)`` Round-1/Round-2 key table."""
        return self.derive_keys_fn(self, key, n_sites)

    def summary(self, keys: Array, site_points: Array, w_site: Array, *,
                k: int, objective: str, lloyd_iters: int,
                backend: str) -> Round1State:
        """Round 1's local stage over all sites."""
        return self.local_summary_fn(self, keys, site_points, w_site, k=k,
                                     objective=objective,
                                     lloyd_iters=lloyd_iters,
                                     backend=backend)

    def exchange_spec(self) -> Optional[ExchangeSpec]:
        """The declared Round-1 communication shape (``None`` == no
        exchange round at all)."""
        return self.exchange_spec_fn(self)

    @property
    def needs_exchange(self) -> bool:
        return self.exchange_spec() is not None

    def allocate(self, costs: Array, t: int) -> Array:
        """Split the budget: ``sum == t`` exactly, every strategy."""
        return self.allocate_fn(self, costs, t)

    def contribute(self, keys: Array, site_points: Array, r1: Round1State,
                   t_i: Array, totals: Array, *, k: int, t: int,
                   t_buffer: int, clip_negative: bool):
        """Round 2's local stage: batched per-site portions (a vmapped
        :class:`~repro.core.coreset.Coreset`)."""
        return self.local_contribution_fn(
            self, keys, site_points, r1, t_i, totals, k=k, t=t,
            t_buffer=t_buffer, clip_negative=clip_negative)

    def assemble(self, points: Array, weights: Array):
        """Stitch moved portions into one flat coreset."""
        return self.assemble_fn(self, points, weights)

    def site_sensitivities(self, pts: Array, centers: Array, w: Array, *,
                           objective: str, backend: str):
        """Unbatched sampling-mass rule (the SPMD per-device stage)."""
        return self.site_sensitivities_fn(self, pts, centers, w,
                                          objective=objective,
                                          backend=backend)

    def local_totals(self, local_costs: Array) -> Array:
        """The per-site ``totals`` vector engines must feed
        :meth:`contribute` when no exchange round runs: each site
        normalizes by its *own* scalar."""
        return local_costs

    def sample_t_total(self, t: int, t_i: Array) -> Array:
        """The per-site ``t_total`` normalizer of the sample-weight
        formula ``w_q = total_m * w / (t_total * m_q)``: the global
        budget ``t`` for exchanging strategies, each site's own realized
        ``t_i`` for single-shuffle ones. The *staged* coreset engine
        (``repro.core.coreset.staged_distributed_coreset``) consumes this
        hook to finalize per-site weights without re-entering the batched
        ``contribute`` path -- it must stay consistent with
        ``local_contribution_fn``'s normalization rule."""
        return self.sample_t_total_fn(self, t, t_i)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, CoresetStrategy] = {}

StrategyLike = Union[str, CoresetStrategy, None]


def register_strategy(strat: CoresetStrategy) -> CoresetStrategy:
    """Add a strategy to the registry (a new round protocol is one
    ``register_strategy`` call). Re-registering an equal instance is a
    no-op; shadowing a name with a different strategy raises -- jitted
    entry points cache compiled traces keyed on the name, so a silent
    swap would serve stale round protocols."""
    existing = _REGISTRY.get(strat.name)
    if existing is not None and existing != strat:
        raise ValueError(
            f"a different strategy is already registered as "
            f"{strat.name!r}; give this instance a unique name")
    _REGISTRY[strat.name] = strat
    return strat


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


ALGORITHM1 = register_strategy(CoresetStrategy(name="algorithm1"))

COHEN_ADDAD = register_strategy(CoresetStrategy(
    name="cohen_addad",
    local_summary_fn=_cohen_addad_local_summary,
    site_sensitivities_fn=_refined_site_sensitivities))

MAPREDUCE = register_strategy(CoresetStrategy(
    name="mapreduce",
    exchange_spec_fn=_no_exchange,
    allocate_fn=_uniform_allocate,
    local_contribution_fn=_mapreduce_local_contribution,
    sample_t_total_fn=_own_t_total))


def resolve_name(strategy: StrategyLike) -> str:
    """Resolve a selection (canonical name, :class:`CoresetStrategy`
    instance, or ``None`` for the Algorithm-1 default) to a registry
    name, raising ``ValueError`` on unknown strings -- the single
    boundary where the string API meets the descriptor layer, exactly
    like ``objective.resolve_name``."""
    if strategy is None:
        return ALGORITHM1.name
    if isinstance(strategy, CoresetStrategy):
        return register_strategy(strategy).name
    if not isinstance(strategy, str):
        raise TypeError(f"strategy must be a name or CoresetStrategy, got "
                        f"{type(strategy).__name__}")
    if strategy in _REGISTRY:
        return strategy
    raise ValueError(
        f"unknown strategy {strategy!r}; known strategies: "
        f"{', '.join(available_strategies())}")


def get_strategy(strategy: StrategyLike = None) -> CoresetStrategy:
    """Resolve a selection to the descriptor instance. Pure registry
    lookup for already-canonical names -- safe at trace time inside
    jitted functions."""
    if isinstance(strategy, CoresetStrategy):
        register_strategy(strategy)
        return strategy
    return _REGISTRY[resolve_name(strategy)]
