"""Communication-graph topologies used by the paper's experiments.

Numpy-based (host-side orchestration data, never traced). Graphs are
represented by a validated sorted edge list plus ``n``; edges carry optional
per-link **costs** (the heterogeneous-link contract, DESIGN.md Sec. 12) and
the graph can be directed. Helpers derive cached adjacency lists, degrees,
BFS and min-cost (Prim) spanning trees, and diameters. Generators:
Erdos-Renyi G(n,p) (paper: p=0.3), 2D grid, Barabasi-Albert preferential
attachment, ring, star, and ``wan_clusters`` (cheap intra-rack cliques
joined by expensive cross-rack links); ``heterogeneous`` re-prices any
generator's edges through a cost function.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import math
from typing import Callable, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """A communication graph: ``n`` nodes and a sorted edge list.

    ``edges`` are ``(i, j)`` pairs with ``i < j`` (undirected, the default)
    or ordered ``(src, dst)`` pairs (``directed=True``). ``edge_costs``
    optionally prices each link (aligned with ``edges``); ``None`` means the
    uniform unit cost the paper assumes, and every ledger then reproduces
    the unweighted accounting bit-exactly. Validation happens at
    construction: malformed edge lists (self-loops, out-of-range endpoints,
    unsorted/duplicate edges, negative or non-finite costs) used to corrupt
    schedules silently; now they raise immediately.

    ``adjacency()`` / ``adjacency_costs()`` / ``degrees()`` /
    ``weighted_degrees()`` are cached on the frozen instance (schedule
    construction used to rebuild adjacency on every aggregate round) -- the
    returned containers are shared, so treat them as read-only.
    """

    n: int
    edges: Tuple[Tuple[int, int], ...]
    edge_costs: Optional[Tuple[float, ...]] = None
    directed: bool = False

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"graph needs n >= 1 node, got n={self.n}")
        edges = tuple((int(i), int(j)) for i, j in self.edges)
        object.__setattr__(self, "edges", edges)
        prev = None
        for e in edges:
            i, j = e
            if i == j:
                raise ValueError(f"self-loop edge {e} is not allowed")
            if not (0 <= i < self.n and 0 <= j < self.n):
                raise ValueError(f"edge {e} out of range for n={self.n} "
                                 f"nodes")
            if not self.directed and i > j:
                raise ValueError(f"undirected edge {e} must be stored as "
                                 f"(min, max): expected {(j, i)}")
            if prev is not None and e <= prev:
                kind = "duplicate" if e == prev else "unsorted"
                raise ValueError(f"{kind} edge {e} after {prev}: the edge "
                                 f"list must be strictly sorted")
            prev = e
        if self.edge_costs is not None:
            costs = tuple(float(c) for c in self.edge_costs)
            object.__setattr__(self, "edge_costs", costs)
            if len(costs) != len(edges):
                raise ValueError(f"edge_costs has {len(costs)} entries for "
                                 f"{len(edges)} edges")
            for e, c in zip(edges, costs):
                if not math.isfinite(c) or c < 0.0:
                    raise ValueError(f"edge {e} has invalid cost {c!r}: "
                                     f"costs must be finite and >= 0")

    @property
    def m(self) -> int:
        return len(self.edges)

    @property
    def costs(self) -> Tuple[float, ...]:
        """Per-edge costs aligned with ``edges`` (uniform 1.0 when unset)."""
        return self.edge_costs if self.edge_costs is not None \
            else (1.0,) * self.m

    @property
    def is_uniform_cost(self) -> bool:
        """True iff every link prices at the paper's unit cost."""
        return self.edge_costs is None or all(c == 1.0 for c in
                                              self.edge_costs)

    @functools.cached_property
    def _adj(self) -> Tuple[Tuple[Tuple[int, ...], ...],
                            Tuple[Tuple[float, ...], ...]]:
        nbrs: List[List[int]] = [[] for _ in range(self.n)]
        cost: List[List[float]] = [[] for _ in range(self.n)]
        for (i, j), c in zip(self.edges, self.costs):
            nbrs[i].append(j)
            cost[i].append(c)
            if not self.directed:
                nbrs[j].append(i)
                cost[j].append(c)
        return (tuple(tuple(a) for a in nbrs),
                tuple(tuple(c) for c in cost))

    def adjacency(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-node (out-)neighbour lists; cached, read-only."""
        return self._adj[0]

    def adjacency_costs(self) -> Tuple[Tuple[float, ...], ...]:
        """Per-node link costs aligned with :meth:`adjacency`."""
        return self._adj[1]

    @functools.cached_property
    def _degrees(self) -> np.ndarray:
        deg = np.asarray([len(a) for a in self.adjacency()], np.int64)
        deg.setflags(write=False)
        return deg

    def degrees(self) -> np.ndarray:
        """(Out-)degrees; cached, read-only."""
        return self._degrees

    @functools.cached_property
    def _weighted_degrees(self) -> np.ndarray:
        # sequential float64 accumulation in adjacency order: the canonical
        # summation the ledgers price with (DESIGN.md Sec. 12)
        wd = np.asarray([float(sum(cs)) for cs in self.adjacency_costs()],
                        np.float64)
        wd.setflags(write=False)
        return wd

    def weighted_degrees(self) -> np.ndarray:
        """Per-node sums of incident (out-)link costs; cached, read-only.
        Equals ``degrees()`` on uniform costs; sums to ``2m`` (undirected)
        or ``m`` (directed) there."""
        return self._weighted_degrees

    @functools.cached_property
    def _cost_map(self) -> dict:
        cm = {}
        for (i, j), c in zip(self.edges, self.costs):
            cm[(i, j)] = c
            if not self.directed:
                cm[(j, i)] = c
        return cm

    def cost_of(self, i: int, j: int) -> float:
        """Cost of the (directed) link i -> j; KeyError if absent."""
        return self._cost_map[(i, j)]

    @functools.cached_property
    def _distances(self) -> np.ndarray:
        d = all_pairs_distances(self)
        d.setflags(write=False)
        return d

    def distances(self) -> np.ndarray:
        """(n, n) hop-count matrix ``dist[s, v]`` (directed distances on a
        directed graph; -1 for unreachable pairs); cached, read-only. This
        is the synchronous-flood timetable: origin ``s``'s payload reaches
        node ``v`` in exactly ``dist[s, v]`` lossless rounds, which is the
        baseline the WAN runtime's staleness axis is metered against."""
        return self._distances


def _components(n: int, edges) -> List[List[int]]:
    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for i, j in edges:
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj
    comps: dict = {}
    for v in range(n):
        comps.setdefault(find(v), []).append(v)
    return list(comps.values())


def _connect(rng: np.random.Generator, n: int, edges: set) -> set:
    """Add random edges between components until connected."""
    comps = _components(n, edges)
    while len(comps) > 1:
        a = rng.choice(comps[0])
        b = rng.choice(comps[1])
        edges.add((min(a, b), max(a, b)))
        comps = _components(n, edges)
    return edges


def erdos_renyi(n: int, p: float = 0.3, seed: int = 0) -> Graph:
    """G(n, p), forced connected by bridging components (paper Sec. 5)."""
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    edges = {(i, j) for i in range(n) for j in range(i + 1, n) if mask[i, j]}
    edges = _connect(rng, n, edges)
    return Graph(n, tuple(sorted(edges)))


def ring(n: int) -> Graph:
    """Cycle graph 0-1-...-(n-1)-0 (diameter floor(n/2)); n=2 degenerates to
    a single edge. The physical-ICI analogue of ``neighbor_rounds_*``."""
    if n < 2:
        raise ValueError("ring needs n >= 2")
    edges = {(i, i + 1) for i in range(n - 1)}
    edges.add((0, n - 1))
    return Graph(n, tuple(sorted(edges)))


def star(n: int) -> Graph:
    """Star with hub 0 (diameter 2): the paper's most centralized topology,
    the worst case for the 2m-per-message flood bound being tight."""
    if n < 2:
        raise ValueError("star needs n >= 2")
    return Graph(n, tuple((0, i) for i in range(1, n)))


def grid(rows: int, cols: int) -> Graph:
    """rows x cols 2D grid graph (diameter Theta(sqrt(n)))."""
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if c + 1 < cols:
                edges.append((v, v + 1))
            if r + 1 < rows:
                edges.append((v, v + cols))
    return Graph(rows * cols, tuple(sorted(edges)))


def torus(rows: int, cols: int) -> Graph:
    """rows x cols 2-D torus: the grid plus row/column wraparound edges --
    the physical-ICI analogue of ``torus_rounds_gather``'s row-phase /
    column-phase ``ppermute`` schedule (node i = r * cols + c matches the
    collective's flat row-major device order). Diameter
    floor(rows/2) + floor(cols/2), vs the 1-D ring's floor(n/2).

    Wraparound edges degenerate gracefully: a dimension of 2 already has
    its wrap edge in the grid (kept single, as in ``ring(2)``), and a
    dimension of 1 contributes none (a 1 x C torus is the C-cycle)."""
    if rows * cols < 2:
        raise ValueError("torus needs rows * cols >= 2")
    edges = set()
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if cols > 1:
                w = r * cols + (c + 1) % cols
                edges.add((min(v, w), max(v, w)))
            if rows > 1:
                w = ((r + 1) % rows) * cols + c
                edges.add((min(v, w), max(v, w)))
    return Graph(rows * cols, tuple(sorted(edges)))


def preferential(n: int, m_attach: int = 2, seed: int = 0) -> Graph:
    """Barabasi-Albert preferential attachment: each new node attaches to
    ``m_attach`` existing nodes with probability proportional to degree."""
    rng = np.random.default_rng(seed)
    m0 = max(m_attach, 2)
    edges = {(i, j) for i in range(m0) for j in range(i + 1, m0)}  # seed clique
    deg = np.zeros(n, dtype=np.float64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    for v in range(m0, n):
        probs = deg[:v] / deg[:v].sum()
        targets = rng.choice(v, size=min(m_attach, v), replace=False, p=probs)
        for t in targets:
            edges.add((min(v, int(t)), max(v, int(t))))
            deg[v] += 1
            deg[t] += 1
    return Graph(n, tuple(sorted(edges)))


def wan_clusters(n_racks: int, rack_size: int, intra_cost: float = 1.0,
                 cross_cost: float = 16.0, cross_links: int = 2,
                 seed: int = 0) -> Graph:
    """Two-tier WAN topology: racks of cheap links joined by expensive ones.

    Each rack is a clique of ``rack_size`` nodes on ``intra_cost`` links
    (rack ``r`` owns nodes ``r*rack_size .. (r+1)*rack_size - 1``); every
    pair of racks is joined by ``cross_links`` links of ``cross_cost``
    between random endpoints, chosen so the far-side endpoints are distinct
    (up to ``rack_size``). That endpoint spread is what makes hop-count
    (BFS) routing pay: a BFS tree enters a remote rack through *every*
    cross link whose far endpoint it reaches at the shallower depth, while
    a min-cost tree pays for exactly one cross link per rack it attaches.
    Defaults keep costs integer-valued so ledger identities are bit-exact
    (DESIGN.md Sec. 12)."""
    if n_racks < 1 or rack_size < 1:
        raise ValueError(f"wan_clusters needs n_racks >= 1 and rack_size >= "
                         f"1, got {n_racks} x {rack_size}")
    if n_racks > 1 and cross_links < 1:
        raise ValueError("wan_clusters needs cross_links >= 1 to connect "
                         "racks")
    rng = np.random.default_rng(seed)
    cost = {}
    for r in range(n_racks):
        base = r * rack_size
        for a in range(rack_size):
            for b in range(a + 1, rack_size):
                cost[(base + a, base + b)] = float(intra_cost)
    for ra in range(n_racks):
        for rb in range(ra + 1, n_racks):
            n_links = min(cross_links, rack_size)
            vs = rng.choice(rack_size, size=n_links, replace=False)
            us = rng.integers(0, rack_size, size=n_links)
            for u, v in zip(us, vs):
                e = (ra * rack_size + int(u), rb * rack_size + int(v))
                cost[e] = float(cross_cost)
    edges = tuple(sorted(cost))
    return Graph(n_racks * rack_size, edges,
                 edge_costs=tuple(cost[e] for e in edges))


def heterogeneous(g: Graph, cost_fn: Callable[[int, int], float]) -> Graph:
    """Re-price a generator's links: a copy of ``g`` whose ``edge_costs``
    are ``cost_fn(i, j)`` per edge (validated like any constructed graph).
    Composes with every existing generator, e.g.
    ``heterogeneous(grid(4, 4), lambda i, j: 8.0 if j - i > 1 else 1.0)``
    prices vertical grid links 8x the horizontal ones."""
    return Graph(g.n, g.edges,
                 edge_costs=tuple(float(cost_fn(i, j)) for i, j in g.edges),
                 directed=g.directed)


@dataclasses.dataclass(frozen=True)
class SpanningTree:
    """A rooted spanning tree, optionally cost-annotated.

    ``parent_cost[v]`` is the cost of v's parent link (0.0 at the root;
    ``None`` means uniform unit links, the pre-cost behavior).
    :meth:`path_costs` / :meth:`edge_cost_total` are the two pricing axes
    the ledgers consume (DESIGN.md Sec. 12): a gathered/scattered payload
    pays its root-path cost, a broadcast payload pays every tree edge
    once."""

    n: int
    root: int
    parent: Tuple[int, ...]   # parent[root] == -1
    depth: Tuple[int, ...]
    parent_cost: Optional[Tuple[float, ...]] = None

    @property
    def height(self) -> int:
        return int(max(self.depth))

    def children(self) -> List[List[int]]:
        ch: List[List[int]] = [[] for _ in range(self.n)]
        for v, p in enumerate(self.parent):
            if p >= 0:
                ch[p].append(v)
        return ch

    def bottom_up_order(self) -> List[int]:
        """Leaves first, root last."""
        return sorted(range(self.n), key=lambda v: -self.depth[v])

    @functools.cached_property
    def _pc64(self) -> np.ndarray:
        pc = (np.ones(self.n, np.float64) if self.parent_cost is None
              else np.asarray(self.parent_cost, np.float64))
        pc = pc.copy()
        pc[self.root] = 0.0
        pc.setflags(write=False)
        return pc

    def parent_costs(self) -> np.ndarray:
        """float64 per-node parent-link costs (0 at root); cached."""
        return self._pc64

    @functools.cached_property
    def _path_costs(self) -> np.ndarray:
        # accumulate each root path deepest-edge-first: the same float64
        # order the executed gather/scatter rounds are priced in, so the
        # analytic and measured ledgers agree bit-for-bit
        pc = self._pc64
        out = np.zeros(self.n, np.float64)
        for v in range(self.n):
            acc, u = 0.0, v
            while self.parent[u] >= 0:
                acc += float(pc[u])
                u = self.parent[u]
            out[v] = acc
        out.setflags(write=False)
        return out

    def path_costs(self) -> np.ndarray:
        """Cost of each node's path to the root (== ``depth`` when
        uniform); cached, read-only."""
        return self._path_costs

    @functools.cached_property
    def _edge_cost_total(self) -> float:
        # level-major, ascending node id within a level: the order the
        # executed broadcast prices its transmissions in
        pc = self._pc64
        total = 0.0
        for v in sorted(range(self.n), key=lambda u: (self.depth[u], u)):
            if self.parent[v] >= 0:
                total += float(pc[v])
        return total

    def edge_cost_total(self) -> float:
        """Sum of tree-edge costs (== ``n - 1`` when uniform); cached."""
        return self._edge_cost_total


def bfs_spanning_tree(g: Graph, root: int = 0) -> SpanningTree:
    """Breadth-first spanning tree (the paper restricts Zhang et al. to a BFS
    tree from a uniformly random root). Parent links carry the graph's edge
    costs so tree ledgers price heterogeneous links correctly."""
    if g.directed:
        raise ValueError("spanning trees need an undirected graph (tree "
                         "protocols route both up and down each link)")
    adj, adjc = g.adjacency(), g.adjacency_costs()
    parent = [-2] * g.n
    pcost = [0.0] * g.n
    depth = [0] * g.n
    parent[root] = -1
    frontier = [root]
    while frontier:
        nxt = []
        for v in frontier:
            for u, c in zip(adj[v], adjc[v]):
                if parent[u] == -2:
                    parent[u] = v
                    pcost[u] = c
                    depth[u] = depth[v] + 1
                    nxt.append(u)
        frontier = nxt
    if any(p == -2 for p in parent):
        raise ValueError("graph is not connected")
    return SpanningTree(g.n, root, tuple(parent), tuple(depth), tuple(pcost))


def mst_spanning_tree(g: Graph, root: int = 0) -> SpanningTree:
    """Min-cost spanning tree rooted at ``root``: Prim over ``edge_costs``.

    Ties break by discovery order (FIFO), so on uniform costs Prim explores
    in exactly the BFS frontier order and returns the *identical* tree --
    which is what keeps uniform-cost min-cost ledgers bit-compatible with
    the BFS ledgers (asserted in tests). On heterogeneous costs the tree
    minimizes the total edge cost (the broadcast / up-sum price), at the
    expense of possibly deeper paths (the gather price and the quiescence
    bound grow with tree height; DESIGN.md Sec. 12)."""
    if g.directed:
        raise ValueError("spanning trees need an undirected graph (tree "
                         "protocols route both up and down each link)")
    adj, adjc = g.adjacency(), g.adjacency_costs()
    parent = [-2] * g.n
    pcost = [0.0] * g.n
    depth = [0] * g.n
    parent[root] = -1
    heap: list = []
    seq = 0

    def push_edges(v: int) -> None:
        nonlocal seq
        for u, c in zip(adj[v], adjc[v]):
            if parent[u] == -2:
                heapq.heappush(heap, (c, seq, v, u))
                seq += 1

    push_edges(root)
    while heap:
        c, _, v, u = heapq.heappop(heap)
        if parent[u] != -2:
            continue
        parent[u] = v
        pcost[u] = c
        depth[u] = depth[v] + 1
        push_edges(u)
    if any(p == -2 for p in parent):
        raise ValueError("graph is not connected")
    return SpanningTree(g.n, root, tuple(parent), tuple(depth), tuple(pcost))


def spanning_tree(g: Graph, root: int = 0,
                  routing: str = "bfs") -> SpanningTree:
    """Build a spanning tree under a routing policy: ``"bfs"`` minimizes
    hop depth, ``"min_cost"`` minimizes total link cost (Prim). The two
    coincide (bit-exactly) on uniform costs."""
    if routing == "bfs":
        return bfs_spanning_tree(g, root=root)
    if routing == "min_cost":
        return mst_spanning_tree(g, root=root)
    raise ValueError(f"unknown routing {routing!r}: expected "
                     f"'bfs'|'min_cost'")


def all_pairs_distances(g: Graph) -> np.ndarray:
    """(n, n) hop-count matrix by n BFS passes (n is small in all
    experiments): ``dist[s, v]`` is the shortest path from s to v along
    (out-)links, -1 if unreachable. Prefer ``g.distances()`` (the cached
    accessor) over calling this directly."""
    adj = g.adjacency()
    out = np.full((g.n, g.n), -1, np.int64)
    for s in range(g.n):
        dist = out[s]
        dist[s] = 0
        frontier = [s]
        while frontier:
            nxt = []
            for v in frontier:
                for u in adj[v]:
                    if dist[u] < 0:
                        dist[u] = dist[v] + 1
                        nxt.append(u)
            frontier = nxt
    return out


def diameter(g: Graph) -> int:
    """Exact diameter from the cached distance matrix. Directed graphs use
    directed distances and must be strongly connected."""
    dist = g.distances()
    if dist.min() < 0:
        raise ValueError("graph is not connected" if not g.directed
                         else "directed graph is not strongly connected")
    return int(dist.max())


def drop_edges(g: Graph, dropped) -> Graph:
    """A copy of ``g`` with ``dropped`` edges removed (same node set).

    ``dropped`` is an iterable of endpoint pairs; undirected pairs may be
    given in either orientation. Unknown edges raise -- a fault plan that
    names a non-existent link is a bug, not a no-op. This is the
    *surviving graph* constructor of the WAN fault model (DESIGN.md
    Sec. 14); note the result may be disconnected, which ``diameter()`` /
    the quiescence checker will surface."""
    norm = set()
    for i, j in dropped:
        e = (int(i), int(j))
        if not g.directed:
            e = (min(e), max(e))
        if e not in g._cost_map and e not in set(g.edges):
            raise ValueError(f"cannot drop {tuple((int(i), int(j)))}: not an "
                             f"edge of the graph")
        norm.add(e)
    keep = [(e, c) for e, c in zip(g.edges, g.costs) if e not in norm]
    return Graph(g.n, tuple(e for e, _ in keep),
                 edge_costs=(None if g.edge_costs is None
                             else tuple(c for _, c in keep)),
                 directed=g.directed)


def induced_subgraph(g: Graph, keep_nodes) -> Tuple[Graph, np.ndarray]:
    """Subgraph induced on ``keep_nodes`` with compact relabeling.

    Returns ``(sub, index)`` where ``index`` lists the kept original node
    ids in ascending order and ``sub``'s node ``r`` is original node
    ``index[r]``. Edges touching a removed node are dropped (their costs
    ride along). Used to reason about the surviving topology once churned
    nodes are declared permanently dead."""
    index = np.asarray(sorted({int(v) for v in keep_nodes}), np.int64)
    if index.size == 0:
        raise ValueError("induced_subgraph needs at least one kept node")
    if index[0] < 0 or index[-1] >= g.n:
        raise ValueError(f"keep_nodes out of range for n={g.n}")
    relabel = {int(v): r for r, v in enumerate(index)}
    keep = [((relabel[i], relabel[j]), c)
            for (i, j), c in zip(g.edges, g.costs)
            if i in relabel and j in relabel]
    return Graph(len(index), tuple(e for e, _ in keep),
                 edge_costs=(None if g.edge_costs is None
                             else tuple(c for _, c in keep)),
                 directed=g.directed), index
