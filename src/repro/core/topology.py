"""Communication-graph topologies used by the paper's experiments.

Numpy-based (host-side orchestration data, never traced). Graphs are
represented by a sorted edge list ``edges: list[tuple[int,int]]`` with i<j plus
``n``; helpers derive adjacency lists, degrees, BFS spanning trees and
diameters. Generators: Erdos-Renyi G(n,p) (paper: p=0.3), 2D grid, and
Barabasi-Albert preferential attachment.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    n: int
    edges: Tuple[Tuple[int, int], ...]

    @property
    def m(self) -> int:
        return len(self.edges)

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.n)]
        for i, j in self.edges:
            adj[i].append(j)
            adj[j].append(i)
        return adj

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n, dtype=np.int64)
        for i, j in self.edges:
            deg[i] += 1
            deg[j] += 1
        return deg


def _components(n: int, edges) -> List[List[int]]:
    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for i, j in edges:
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj
    comps: dict = {}
    for v in range(n):
        comps.setdefault(find(v), []).append(v)
    return list(comps.values())


def _connect(rng: np.random.Generator, n: int, edges: set) -> set:
    """Add random edges between components until connected."""
    comps = _components(n, edges)
    while len(comps) > 1:
        a = rng.choice(comps[0])
        b = rng.choice(comps[1])
        edges.add((min(a, b), max(a, b)))
        comps = _components(n, edges)
    return edges


def erdos_renyi(n: int, p: float = 0.3, seed: int = 0) -> Graph:
    """G(n, p), forced connected by bridging components (paper Sec. 5)."""
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    edges = {(i, j) for i in range(n) for j in range(i + 1, n) if mask[i, j]}
    edges = _connect(rng, n, edges)
    return Graph(n, tuple(sorted(edges)))


def ring(n: int) -> Graph:
    """Cycle graph 0-1-...-(n-1)-0 (diameter floor(n/2)); n=2 degenerates to
    a single edge. The physical-ICI analogue of ``neighbor_rounds_*``."""
    if n < 2:
        raise ValueError("ring needs n >= 2")
    edges = {(i, i + 1) for i in range(n - 1)}
    edges.add((0, n - 1))
    return Graph(n, tuple(sorted(edges)))


def star(n: int) -> Graph:
    """Star with hub 0 (diameter 2): the paper's most centralized topology,
    the worst case for the 2m-per-message flood bound being tight."""
    if n < 2:
        raise ValueError("star needs n >= 2")
    return Graph(n, tuple((0, i) for i in range(1, n)))


def grid(rows: int, cols: int) -> Graph:
    """rows x cols 2D grid graph (diameter Theta(sqrt(n)))."""
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if c + 1 < cols:
                edges.append((v, v + 1))
            if r + 1 < rows:
                edges.append((v, v + cols))
    return Graph(rows * cols, tuple(sorted(edges)))


def preferential(n: int, m_attach: int = 2, seed: int = 0) -> Graph:
    """Barabasi-Albert preferential attachment: each new node attaches to
    ``m_attach`` existing nodes with probability proportional to degree."""
    rng = np.random.default_rng(seed)
    m0 = max(m_attach, 2)
    edges = {(i, j) for i in range(m0) for j in range(i + 1, m0)}  # seed clique
    deg = np.zeros(n, dtype=np.float64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    for v in range(m0, n):
        probs = deg[:v] / deg[:v].sum()
        targets = rng.choice(v, size=min(m_attach, v), replace=False, p=probs)
        for t in targets:
            edges.add((min(v, int(t)), max(v, int(t))))
            deg[v] += 1
            deg[t] += 1
    return Graph(n, tuple(sorted(edges)))


@dataclasses.dataclass(frozen=True)
class SpanningTree:
    n: int
    root: int
    parent: Tuple[int, ...]   # parent[root] == -1
    depth: Tuple[int, ...]

    @property
    def height(self) -> int:
        return int(max(self.depth))

    def children(self) -> List[List[int]]:
        ch: List[List[int]] = [[] for _ in range(self.n)]
        for v, p in enumerate(self.parent):
            if p >= 0:
                ch[p].append(v)
        return ch

    def bottom_up_order(self) -> List[int]:
        """Leaves first, root last."""
        return sorted(range(self.n), key=lambda v: -self.depth[v])


def bfs_spanning_tree(g: Graph, root: int = 0) -> SpanningTree:
    """Breadth-first spanning tree (the paper restricts Zhang et al. to a BFS
    tree from a uniformly random root)."""
    adj = g.adjacency()
    parent = [-2] * g.n
    depth = [0] * g.n
    parent[root] = -1
    frontier = [root]
    while frontier:
        nxt = []
        for v in frontier:
            for u in adj[v]:
                if parent[u] == -2:
                    parent[u] = v
                    depth[u] = depth[v] + 1
                    nxt.append(u)
        frontier = nxt
    if any(p == -2 for p in parent):
        raise ValueError("graph is not connected")
    return SpanningTree(g.n, root, tuple(parent), tuple(depth))


def diameter(g: Graph) -> int:
    """Exact diameter by n BFS passes (n is small in all experiments)."""
    adj = g.adjacency()
    best = 0
    for s in range(g.n):
        dist = [-1] * g.n
        dist[s] = 0
        frontier = [s]
        while frontier:
            nxt = []
            for v in frontier:
                for u in adj[v]:
                    if dist[u] < 0:
                        dist[u] = dist[v] + 1
                        nxt.append(u)
            frontier = nxt
        best = max(best, max(dist))
    return best
