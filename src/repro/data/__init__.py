from repro.data import selection, synthetic
from repro.data.selection import Selection, embed_examples, gather_selected, select_coreset
from repro.data.synthetic import BigramLM, paper_dataset, paper_dataset_names

__all__ = ["selection", "synthetic", "Selection", "embed_examples",
           "gather_selected", "select_coreset", "BigramLM", "paper_dataset",
           "paper_dataset_names"]
