"""Coreset-based distributed data selection -- the paper's technique as a
first-class feature of the training data pipeline.

Each data-parallel shard holds a pool of candidate examples. Examples are
embedded (mean-pooled token embeddings from the model's own embedding table),
and Algorithm 1 runs over the embedding space: local k-means solves, a single
scalar (local cost) exchanged per shard, then cost-proportional sensitivity
sampling. The selected examples + per-example weights form a
coverage-preserving training subset whose weighted loss approximates the
full-pool loss for *any* model state in the embedding space's cost geometry
-- at a communication cost of one scalar per shard plus the subset itself
(vs shipping every shard's pool).

Returns example *indices* (not just points), because the trainer needs to
fetch the actual sequences.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core.backend import BackendLike
from repro.core.coreset import proportional_allocation

Array = jax.Array
_TINY = 1e-30


def embed_examples(embed_table: Array, tokens: Array) -> Array:
    """Mean-pooled token embeddings: tokens (..., L) -> (..., d) f32."""
    emb = embed_table.astype(jnp.float32)[tokens]
    return jnp.mean(emb, axis=-2)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["indices", "weights", "t_i", "local_costs"],
                   meta_fields=[])
@dataclasses.dataclass
class Selection:
    """Per-site selected example indices and weights. Invalid slots have
    weight exactly 0 (their index is arbitrary)."""

    indices: Array      # (n_sites, t_buffer + k) int32, site-local indices
    weights: Array      # (n_sites, t_buffer + k) f32
    t_i: Array          # (n_sites,)
    local_costs: Array  # (n_sites,)


def select_coreset(
    key: Array,
    embeddings: Array,        # (n_sites, M, d) f32
    mask: Array,              # (n_sites, M) bool
    k: int,
    t: int,
    t_buffer: int | None = None,
    lloyd_iters: int = 5,
    backend: BackendLike = None,
) -> Selection:
    """Algorithm 1 over example embeddings, returning indices.

    The coreset's "solution centers" are mapped back to data: the example
    nearest each local center joins the selection, carrying the center weight
    w_b = |P_b| - sum_{q in P_b cap S} w_q.
    """
    t_buffer = t if t_buffer is None else t_buffer
    return _select_coreset(key, embeddings, mask, k=k, t=t,
                           t_buffer=t_buffer, lloyd_iters=lloyd_iters,
                           backend=backend_mod.resolve_name(backend))


@functools.partial(jax.jit,
                   static_argnames=("k", "t", "t_buffer", "lloyd_iters",
                                    "backend"))
def _select_coreset(key, embeddings, mask, k, t, t_buffer, lloyd_iters,
                    backend):
    n_sites, M, d = embeddings.shape
    w_site = mask.astype(jnp.float32)
    keys = jax.random.split(key, 2 * n_sites).reshape(n_sites, 2, -1)

    def local_solve(ki, pts, w):
        centers = clustering.kmeans_pp_init(ki, pts, k, weights=w,
                                            backend=backend)
        centers, _ = clustering.lloyd(pts, centers, weights=w,
                                      iters=lloyd_iters, backend=backend)
        d2, assign = clustering.min_dist_argmin(pts, centers,
                                                backend=backend)
        m = w * d2
        # nearest real example per center (masked argmin over the column)
        dc = clustering.pairwise_sq_dists(centers, pts)
        dc = jnp.where(w[None, :] > 0, dc, jnp.inf)
        center_idx = jnp.argmin(dc, axis=1).astype(jnp.int32)
        return m, assign, center_idx

    m, assign, center_idx = jax.vmap(local_solve)(
        keys[:, 0], embeddings, w_site)
    local_costs = m.sum(axis=1)
    total_m = jnp.sum(local_costs)
    t_i = proportional_allocation(local_costs, t)

    def local_sample(ki, m_i, w_i, a_i, ti, c_idx):
        from repro.core.coreset import weighted_choice
        idx = weighted_choice(ki, m_i, t_buffer)
        valid = (jnp.arange(t_buffer) < ti) & (total_m > _TINY)
        m_q = m_i[idx]
        w_s = jnp.where(valid & (m_q > _TINY),
                        total_m * w_i[idx] / (float(t) * jnp.maximum(m_q, _TINY)),
                        0.0)
        oh = jax.nn.one_hot(a_i, k, dtype=jnp.float32)
        w_pb = (w_i[:, None] * oh).sum(0)
        w_sb = jnp.zeros((k,), jnp.float32).at[a_i[idx]].add(w_s)
        w_b = w_pb - w_sb
        return (jnp.concatenate([idx.astype(jnp.int32), c_idx]),
                jnp.concatenate([w_s, w_b]))

    indices, weights = jax.vmap(local_sample)(
        keys[:, 1], m, w_site, assign, t_i, center_idx)
    return Selection(indices=indices, weights=weights, t_i=t_i,
                     local_costs=local_costs)


def gather_selected(site_tokens: Array, sel: Selection
                    ) -> Dict[str, Array]:
    """site_tokens (n_sites, M, L) -> selected tokens + weights, flattened
    over sites: {"tokens": (n_sites*(t_buffer+k), L), "weights": (...)}."""
    n_sites = site_tokens.shape[0]
    toks = jax.vmap(lambda tt, ii: tt[ii])(site_tokens, sel.indices)
    return {"tokens": toks.reshape(-1, site_tokens.shape[-1]),
            "weights": sel.weights.reshape(-1)}
