"""Deterministic synthetic data.

* :class:`BigramLM` -- token streams from a fixed random bigram chain over a
  restricted vocabulary slice: a learnable distribution, so the end-to-end
  training examples show real loss reduction.
* :func:`paper_datasets` -- Gaussian-mixture stand-ins shape-matched to the
  paper's evaluation datasets (the UCI files are unavailable offline; see
  DESIGN.md Sec. 7). The ``synthetic`` entry *is* the paper's own synthetic
  setup: k=5 centers ~ N(0, I_10), 20k points per center.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class BigramLM:
    """Fixed random bigram transition matrix over ``active_vocab`` ids."""

    vocab_size: int
    active_vocab: int = 256
    seed: int = 0
    temperature: float = 0.7

    def __post_init__(self):
        self.active_vocab = min(self.active_vocab, self.vocab_size)
        key = jax.random.PRNGKey(self.seed)
        self._logits = (jax.random.normal(
            key, (self.active_vocab, self.active_vocab)) / self.temperature)

    def batch(self, step: int, batch_size: int, seq_len: int
              ) -> Dict[str, Array]:
        """Returns {"tokens": (B, L) i32, "labels": (B, L) i32}; labels are
        the next-token targets."""
        key = jax.random.PRNGKey(hash(("bigram", self.seed, step)) % (2**31))
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (batch_size,), 0, self.active_vocab)

        def gen(carry, k):
            tok = carry
            nxt = jax.random.categorical(k, self._logits[tok], axis=-1)
            return nxt, nxt

        keys = jax.random.split(k1, seq_len)
        _, seq = jax.lax.scan(gen, first, keys)
        seq = jnp.concatenate([first[None], seq], axis=0).T  # (B, L+1)
        return {"tokens": seq[:, :-1].astype(jnp.int32),
                "labels": seq[:, 1:].astype(jnp.int32)}


_PAPER_SHAPES = {
    # name: (n_points, dim, k, n_true_clusters, noise)
    "synthetic": (100_000, 10, 5, 5, 1.0),
    "spam": (4_601, 58, 10, 12, 0.6),
    "pendigits": (10_992, 16, 10, 10, 0.5),
    "letter": (20_000, 16, 10, 26, 0.7),
    "colorhistogram": (68_040, 32, 10, 14, 0.5),
    "yearpredictionmsd": (515_345, 90, 50, 60, 0.8),
}


def paper_dataset(name: str, seed: int = 0, scale: float = 1.0
                  ) -> Tuple[np.ndarray, int]:
    """Gaussian-mixture stand-in matched to the paper dataset's (n, d, k).
    ``scale`` < 1 subsamples n for CI-speed runs. Returns (points, k)."""
    n, d, k, n_clusters, noise = _PAPER_SHAPES[name]
    # subsampling floor: below ~5k points the k=10..50 instances degenerate
    n = max(int(n * scale), min(n, 5000), n_clusters * 10)
    rng = np.random.default_rng(seed)
    if name == "synthetic":
        centers = rng.standard_normal((5, 10))
        per = n // 5
        pts = np.concatenate([
            c + rng.standard_normal((per, 10)) for c in centers])
        return pts.astype(np.float32), k
    centers = rng.standard_normal((n_clusters, d)) * 3.0
    weights = rng.dirichlet(np.ones(n_clusters) * 2.0)
    counts = rng.multinomial(n, weights)
    parts = []
    for c, cnt in zip(centers, counts):
        cov_scale = noise * (0.5 + rng.random())
        parts.append(c + cov_scale * rng.standard_normal((cnt, d)))
    pts = np.concatenate(parts)
    # a few far outliers, as in real UCI tables
    n_out = max(n // 1000, 1)
    pts[:n_out] += rng.standard_normal((n_out, d)) * 20.0
    rng.shuffle(pts)
    return pts.astype(np.float32), k


def paper_dataset_names():
    return list(_PAPER_SHAPES)


def drifting_mixture_stream(
    n_batches: int,
    batch_size: int,
    d: int = 10,
    k: int = 5,
    drift: float = 0.05,
    sigma: float = 0.3,
    seed: int = 0,
) -> Iterator[np.ndarray]:
    """Non-stationary Gaussian-mixture stream for the streaming subsystem:
    the ``k`` mixture centers random-walk by ``drift * N(0, I)`` per batch
    and the mixture weights are re-drawn every batch, so no fixed prefix is
    representative of the whole stream -- exactly the regime merge-and-reduce
    summaries must survive. Deterministic in ``seed``; yields ``n_batches``
    arrays of shape (batch_size, d) float32."""
    rng = np.random.default_rng(seed)
    centers = 3.0 * rng.standard_normal((k, d))
    for _ in range(n_batches):
        probs = rng.dirichlet(np.ones(k) * 2.0)
        comp = rng.choice(k, size=batch_size, p=probs)
        pts = centers[comp] + sigma * rng.standard_normal((batch_size, d))
        yield pts.astype(np.float32)
        centers = centers + drift * rng.standard_normal((k, d))


def contaminated_stream(
    n_batches: int,
    batch_size: int,
    d: int = 10,
    k: int = 5,
    drift: float = 0.05,
    sigma: float = 0.3,
    outlier_frac: float = 0.02,
    outlier_scale: float = 25.0,
    burst_every: int = 0,
    seed: int = 0,
) -> Iterator[np.ndarray]:
    """Adversarially contaminated drifting stream (outliers-workload
    groundwork): each :func:`drifting_mixture_stream` batch has a seeded
    ``outlier_frac`` fraction of its points replaced by far-field outliers
    at radius ~``outlier_scale`` in uniformly random directions -- the
    contamination model under which the paper's k-median objective is the
    robust choice. With ``burst_every > 0``, every ``burst_every``-th
    batch is *fully* adversarial (all points outliers), simulating a
    compromised or faulty site feeding garbage between aggregation rounds
    -- the stream-under-faults scenario the WAN runtime tests exercise.
    Deterministic in ``seed`` (contamination draws are independent of the
    base stream's, so the clean and contaminated streams share their
    inlier points batch for batch)."""
    if not 0.0 <= outlier_frac <= 1.0:
        raise ValueError(f"outlier_frac must be in [0, 1], got "
                         f"{outlier_frac}")
    rng = np.random.default_rng((seed, 0xB4D))
    base = drifting_mixture_stream(n_batches, batch_size, d=d, k=k,
                                   drift=drift, sigma=sigma, seed=seed)
    for b, pts in enumerate(base):
        full_burst = burst_every > 0 and (b + 1) % burst_every == 0
        n_out = batch_size if full_burst else int(
            round(outlier_frac * batch_size))
        if n_out:
            idx = rng.choice(batch_size, size=n_out, replace=False)
            dirs = rng.standard_normal((n_out, d))
            dirs /= np.maximum(np.linalg.norm(dirs, axis=1, keepdims=True),
                               1e-12)
            radii = outlier_scale * (1.0 + rng.random((n_out, 1)))
            pts = pts.copy()
            pts[idx] = (dirs * radii).astype(np.float32)
        yield pts
