"""Pallas TPU kernels for the paper's compute hot-spots: pairwise-distance
assignment, fused Lloyd statistics (k-means) and fused Weiszfeld statistics
(k-median). Validated on CPU in interpret mode; TARGET is TPU (MXU matmul
formulation, VMEM tiling via BlockSpec)."""

from repro.kernels import ops, ref
from repro.kernels.ops import (chunk_queries, lloyd_stats, lloyd_step,
                               min_dist_argmin, min_dist_argmin_batched,
                               pad_queries, query_bucket, weiszfeld_stats)

__all__ = ["ops", "ref", "chunk_queries", "lloyd_stats", "lloyd_step",
           "min_dist_argmin", "min_dist_argmin_batched", "pad_queries",
           "query_bucket", "weiszfeld_stats"]
