"""Fused pairwise-distance + online arg-min Pallas TPU kernel.

The assignment step of Lloyd's algorithm, the D^2 seeding of k-means++ and
the sensitivity computation m_p = cost(p, B_i) of Algorithm 1 all reduce to:
for every point, the min/argmin squared distance over k centers. The naive
formulation materializes an (n, k) distance matrix in HBM; this kernel tiles
points x centers into VMEM, computes the distance tile via a single MXU
matmul (d^2 = |p|^2 + |c|^2 - 2 p.c) and keeps a *running* min/argmin across
center tiles (flash-attention-style online reduction) so the (n, k) matrix
never exists.

Grid layout: (n/bn, k/bk), center axis minor. The two output blocks depend
only on the point-tile index i, so they stay resident in VMEM across the
entire sweep over center tiles j (standard revisiting accumulation).

VMEM per step ~ bn*d + bk*d + bn*bk floats: (256, 256) tiles at d<=512 are
~0.8 MB, comfortably inside the ~16 MB v5e budget; MXU work is the
(bn x d) @ (d x bk) matmul with all dims >= 128-aligned after ops.py padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(p_ref, c_ref, min_ref, arg_ref, *, block_k: int):
    j = pl.program_id(1)

    p = p_ref[...].astype(jnp.float32)          # (bn, d)
    c = c_ref[...].astype(jnp.float32)          # (bk, d)
    p2 = jnp.sum(p * p, axis=1, keepdims=True)  # (bn, 1)
    c2 = jnp.sum(c * c, axis=1)                 # (bk,)
    # MXU: (bn, d) @ (d, bk)
    prod = jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(p2 + c2[None, :] - 2.0 * prod, 0.0)   # (bn, bk)

    local_min = jnp.min(d2, axis=1, keepdims=True)                  # (bn, 1)
    local_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]   # (bn, 1)
    local_arg = local_arg + j * block_k

    @pl.when(j == 0)
    def _init():
        min_ref[...] = local_min
        arg_ref[...] = local_arg

    @pl.when(j > 0)
    def _update():
        prev = min_ref[...]
        better = local_min < prev    # strict: first tile wins ties, matching
        min_ref[...] = jnp.where(better, local_min, prev)   # jnp.argmin
        arg_ref[...] = jnp.where(better, local_arg, arg_ref[...])


def _kernel_batched(p_ref, c_ref, min_ref, arg_ref, *, block_k: int):
    """Stacked-tenant variant: identical math, one extra (leading) grid axis
    selecting the tenant. Block shapes carry a unit tenant dim."""
    j = pl.program_id(2)

    p = p_ref[0].astype(jnp.float32)            # (bn, d)
    c = c_ref[0].astype(jnp.float32)            # (bk, d)
    p2 = jnp.sum(p * p, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    prod = jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(p2 + c2[None, :] - 2.0 * prod, 0.0)

    local_min = jnp.min(d2, axis=1, keepdims=True)
    local_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]
    local_arg = local_arg + j * block_k

    @pl.when(j == 0)
    def _init():
        min_ref[0] = local_min
        arg_ref[0] = local_arg

    @pl.when(j > 0)
    def _update():
        prev = min_ref[0]
        better = local_min < prev
        min_ref[0] = jnp.where(better, local_min, prev)
        arg_ref[0] = jnp.where(better, local_arg, arg_ref[0])


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def distance_argmin_batched(points: Array, centers: Array,
                            block_n: int = 256, block_k: int = 256,
                            interpret: bool = False):
    """Stacked-tenant raw kernel entry: ``(T, m, d), (T, k, d) ->
    (min_d2 (T, m, 1) f32, argmin (T, m, 1) i32)`` in ONE launch over grid
    ``(T, m/bn, k/bk)`` -- the serving tier's fused dispatch (one kernel
    call for T tenants instead of T calls). Same pre-padding contract as
    :func:`distance_argmin` per tenant: m % block_n == 0, k % block_k == 0,
    padded/masked center rows set to a huge sentinel coordinate so they
    never win. Use :func:`repro.kernels.ops.min_dist_argmin_batched` for
    the safe wrapper. The two output blocks depend only on (t, i), so they
    stay VMEM-resident across the center-tile sweep exactly like the
    single-tenant kernel."""
    T, n, d = points.shape
    Tc, k, _ = centers.shape
    assert T == Tc, (T, Tc)
    assert n % block_n == 0 and k % block_k == 0, (n, k, block_n, block_k)
    grid = (T, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel_batched, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d), lambda t, i, j: (t, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda t, i, j: (t, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n, 1), lambda t, i, j: (t, i, 0)),
            pl.BlockSpec((1, block_n, 1), lambda t, i, j: (t, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, n, 1), jnp.float32),
            jax.ShapeDtypeStruct((T, n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(points, centers)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def distance_argmin(points: Array, centers: Array, block_n: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """Raw kernel entry. Requires pre-padded shapes: n % block_n == 0,
    k % block_k == 0 and padded center rows set to a huge coordinate so they
    never win the argmin. Use :func:`repro.kernels.ops.min_dist_argmin` for
    the safe wrapper. Returns (min_d2 (n,1) f32, argmin (n,1) i32)."""
    n, d = points.shape
    k, _ = centers.shape
    assert n % block_n == 0 and k % block_k == 0, (n, k, block_n, block_k)
    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(points, centers)
