"""Fused Lloyd-statistics Pallas TPU kernel.

One pass over the points produces everything a weighted Lloyd iteration (and
Algorithm 1's sensitivity/cost accounting) needs:

    sums[c]   = sum_{p : argmin(p) = c} w_p * p        (k, d)
    counts[c] = sum_{p : argmin(p) = c} w_p            (k,)
    cost      = sum_p w_p * min_d2(p)                  ()

Per point tile: the distance block is computed on the MXU, the argmin is
converted to a one-hot matrix with an iota compare, and the center
accumulation is a second MXU matmul one_hot^T @ points -- i.e. the classic
two-matmul fused E+M statistics step, never materializing (n, k) in HBM.

The centers (k, d) stay fully resident in VMEM, so this kernel targets the
clustering regime (k*d <= ~1M f32 = 4 MB); ops.py falls back to the two-pass
formulation when the resident block would not fit.

Grid: (n/bn,). All three outputs use constant index maps: they are revisited
by every grid step and accumulated in VMEM, written back once at the end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(p_ref, c_ref, w_ref, sums_ref, counts_ref, cost_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        cost_ref[...] = jnp.zeros_like(cost_ref)

    p = p_ref[...].astype(jnp.float32)            # (bn, d)
    c = c_ref[...].astype(jnp.float32)            # (k, d)
    w = w_ref[...].astype(jnp.float32)            # (bn, 1)

    p2 = jnp.sum(p * p, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    prod = jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(p2 + c2[None, :] - 2.0 * prod, 0.0)     # (bn, k)

    min_d2 = jnp.min(d2, axis=1, keepdims=True)              # (bn, 1)
    arg = jnp.argmin(d2, axis=1).astype(jnp.int32)           # (bn,)
    k = c.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (p.shape[0], k), 1)
    onehot = jnp.where(iota == arg[:, None], 1.0, 0.0) * w   # (bn, k)

    # MXU: (k, bn) @ (bn, d)
    sums_ref[...] += jax.lax.dot_general(
        onehot, p, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).T   # (k, 1)
    cost_ref[...] += jnp.sum(w * min_d2, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_stats(points: Array, centers: Array, weights: Array,
                block_n: int = 256, interpret: bool = False):
    """Raw kernel entry; shapes pre-padded (n % block_n == 0, padded points
    have weight 0, padded center rows huge). Returns (sums (k,d) f32,
    counts (k,1) f32, cost (1,1) f32)."""
    n, d = points.shape
    k, _ = centers.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(points, centers, weights)
