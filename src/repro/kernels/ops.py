"""Safe jit'd wrappers around the Pallas kernels.

Handles: shape padding to tile multiples (points padded with zeros + weight
0, centers padded with a huge sentinel coordinate so padded rows never win
the argmin), dtype policy (inputs f32/bf16, accumulation f32), interpret-mode
auto-selection on CPU (the kernels TARGET TPU; on this CPU container they
run under ``interpret=True``), and the VMEM-residency fallback for
:func:`lloyd_stats` / :func:`weiszfeld_stats` when k*d exceeds the
resident budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.distance_argmin import distance_argmin as _distance_argmin
from repro.kernels.lloyd_update import lloyd_stats as _lloyd_stats
from repro.kernels.weiszfeld import weiszfeld_stats as _weiszfeld_stats

Array = jax.Array

_CENTER_SENTINEL = 1.0e15
# (k, d) f32 resident block budget for the fused lloyd kernel (~4 MB).
_LLOYD_RESIDENT_FLOATS = 1 << 20


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _pad_dim(x: Array, axis: int, multiple: int, value: float = 0.0) -> Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pad_queries(points: Array, min_bucket: int = 8) -> Tuple[Array, int]:
    """Pad a query batch ``(n, d)`` to the next power-of-two row count
    (>= ``min_bucket``) with zero rows. Serving traffic arrives in
    arbitrary batch sizes; bucketing bounds the number of jit/kernel
    specializations to O(log n_max) (DESIGN.md Sec. 9). Returns the padded
    batch and the logical count ``n`` -- callers slice outputs back with
    it. Zero-row padding is inert: padded queries get *some* assignment but
    are sliced off before anything consumes them. Always returns >=
    ``min_bucket`` rows (an empty batch pads up, never through, so the
    kernels see a nonzero shape)."""
    n = points.shape[0]
    cap = max(min_bucket, 1 << max(n - 1, 0).bit_length())
    return jnp.pad(points, ((0, cap - n), (0, 0))), n


def min_dist_argmin(points: Array, centers: Array, block_n: int = 256,
                    block_k: int = 256,
                    interpret: Optional[bool] = None
                    ) -> Tuple[Array, Array]:
    """Fused min-distance/argmin: (n,d),(k,d) -> ((n,) f32, (n,) i32)."""
    n, d = points.shape
    k = centers.shape[0]
    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (k - 1).bit_length()))
    p = _pad_dim(_pad_dim(points, 1, 128), 0, block_n)
    c = _pad_dim(centers, 1, 128)
    c = _pad_dim(c, 0, block_k, value=_CENTER_SENTINEL)
    md, am = _distance_argmin(p, c, block_n=block_n, block_k=block_k,
                              interpret=_auto_interpret(interpret))
    return md[:n, 0], am[:n, 0]


def lloyd_stats(points: Array, centers: Array,
                weights: Optional[Array] = None, block_n: int = 256,
                interpret: Optional[bool] = None
                ) -> Tuple[Array, Array, Array]:
    """Fused Lloyd statistics: returns (sums (k,d) f32, counts (k,) f32,
    cost () f32). Falls back to kernel-1 + jnp segment ops when the (k, d)
    center block cannot stay VMEM-resident."""
    n, d = points.shape
    k = centers.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights
    d_pad = -(-d // 128) * 128
    k_pad = -(-k // 8) * 8
    if k_pad * d_pad > _LLOYD_RESIDENT_FLOATS:
        # two-pass fallback: fused assignment kernel + XLA one-hot matmul
        min_d2, assign = min_dist_argmin(points, centers, block_n=block_n,
                                         interpret=interpret)
        oh = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
        sums = oh.T @ points.astype(jnp.float32)
        counts = jnp.sum(oh, axis=0)
        cost = jnp.sum(w * min_d2)
        return sums, counts, cost

    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    p = _pad_dim(_pad_dim(points, 1, 128), 0, block_n)
    c = _pad_dim(centers, 1, 128)
    c = _pad_dim(c, 0, 8, value=_CENTER_SENTINEL)
    wp = _pad_dim(w.astype(jnp.float32)[:, None], 0, block_n)
    sums, counts, cost = _lloyd_stats(p, c, wp, block_n=block_n,
                                      interpret=_auto_interpret(interpret))
    return sums[:k, :d], counts[:k, 0], cost[0, 0]


def weiszfeld_stats(points: Array, centers: Array,
                    weights: Optional[Array] = None, block_n: int = 256,
                    interpret: Optional[bool] = None
                    ) -> Tuple[Array, Array, Array]:
    """Fused Weiszfeld statistics (k-median): returns (nums (k,d) f32,
    denoms (k,) f32, cost () f32). Falls back to kernel-1 + jnp one-hot ops
    when the (k, d) center block cannot stay VMEM-resident."""
    n, d = points.shape
    k = centers.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights
    d_pad = -(-d // 128) * 128
    k_pad = -(-k // 8) * 8
    if k_pad * d_pad > _LLOYD_RESIDENT_FLOATS:
        # two-pass fallback: fused assignment kernel + the shared normative
        # XLA reduction (exact-form distance + eta smoothing)
        _, assign = min_dist_argmin(points, centers, block_n=block_n,
                                    interpret=interpret)
        return ref.weiszfeld_reduce(points, centers, w, assign)

    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    p = _pad_dim(_pad_dim(points, 1, 128), 0, block_n)
    c = _pad_dim(centers, 1, 128)
    c = _pad_dim(c, 0, 8, value=_CENTER_SENTINEL)
    wp = _pad_dim(w.astype(jnp.float32)[:, None], 0, block_n)
    nums, denoms, cost = _weiszfeld_stats(p, c, wp, block_n=block_n,
                                          interpret=_auto_interpret(interpret))
    return nums[:k, :d], denoms[:k, 0], cost[0, 0]


def lloyd_step(points: Array, centers: Array,
               weights: Optional[Array] = None,
               interpret: Optional[bool] = None) -> Tuple[Array, Array]:
    """One full weighted Lloyd iteration via the fused kernel: returns
    (new_centers (k,d), cost ()). Empty / non-positive-mass clusters keep
    their previous center (matches repro.core.clustering semantics)."""
    sums, counts, cost = lloyd_stats(points, centers, weights,
                                     interpret=interpret)
    eps = 1e-12
    new = sums / jnp.where(counts > eps, counts, 1.0)[:, None]
    new = jnp.where((counts > eps)[:, None], new, centers.astype(jnp.float32))
    return new.astype(centers.dtype), cost
