"""Safe jit'd wrappers around the Pallas kernels.

Handles: shape padding to tile multiples (points padded with zeros + weight
0, centers padded with a huge sentinel coordinate so padded rows never win
the argmin), dtype policy (inputs f32/bf16, accumulation f32), interpret-mode
auto-selection on CPU (the kernels TARGET TPU; on this CPU container they
run under ``interpret=True``), and the VMEM-residency fallback for
:func:`lloyd_stats` / :func:`weiszfeld_stats` when k*d exceeds the
resident budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.distance_argmin import distance_argmin as _distance_argmin
from repro.kernels.distance_argmin import \
    distance_argmin_batched as _distance_argmin_batched
from repro.kernels.lloyd_update import lloyd_stats as _lloyd_stats
from repro.kernels.weiszfeld import weiszfeld_stats as _weiszfeld_stats

Array = jax.Array

_CENTER_SENTINEL = ref.CENTER_SENTINEL
# (k, d) f32 resident block budget for the fused lloyd kernel (~4 MB).
_LLOYD_RESIDENT_FLOATS = 1 << 20


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _pad_dim(x: Array, axis: int, multiple: int, value: float = 0.0) -> Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def query_bucket(n: int, min_bucket: int = 8,
                 max_bucket: Optional[int] = None) -> int:
    """The padded row count serving uses for an ``n``-query (chunk of a)
    batch: next power of two, clamped to ``[min_bucket, max_bucket]``. With
    a ``max_bucket`` bound the reachable bucket set is
    ``{min_bucket, 2*min_bucket, ..., max_bucket}`` -- O(log max_bucket)
    compiled specializations no matter how adversarial the traffic sizes
    are. ``n`` may exceed ``max_bucket`` only through chunking
    (:func:`chunk_queries`)."""
    b = max(min_bucket, 1 << max(n - 1, 0).bit_length())
    if max_bucket is not None:
        if max_bucket < min_bucket:
            raise ValueError(f"max_bucket {max_bucket} < min_bucket "
                             f"{min_bucket}")
        b = min(b, max_bucket)
    return b


def pad_queries(points: Array, min_bucket: int = 8,
                max_bucket: Optional[int] = None) -> Tuple[Array, int]:
    """Pad a query batch ``(n, d)`` to the next power-of-two row count
    (>= ``min_bucket``) with zero rows. Serving traffic arrives in
    arbitrary batch sizes; bucketing bounds the number of jit/kernel
    specializations to O(log n_max) (DESIGN.md Sec. 9). Returns the padded
    batch and the logical count ``n`` -- callers slice outputs back with
    it. Zero-row padding is inert: padded queries get *some* assignment but
    are sliced off before anything consumes them. Always returns >=
    ``min_bucket`` rows (an empty batch pads up, never through, so the
    kernels see a nonzero shape).

    ``max_bucket`` caps the largest specialization this function will ever
    produce: a batch that does not fit must be split into chunks instead
    (:func:`chunk_queries`) -- padding a one-off 10M-row burst to the next
    power of two would compile (and allocate) an unboundedly large kernel
    specialization."""
    n = points.shape[0]
    cap = query_bucket(n, min_bucket, max_bucket)
    if n > cap:
        raise ValueError(
            f"query batch of {n} rows exceeds max_bucket={max_bucket}; "
            f"split it with chunk_queries() instead")
    return jnp.pad(points, ((0, cap - n), (0, 0))), n


def site_bucket_lengths(site_counts, max_len: int,
                        min_bucket: int = 64) -> Tuple[int, ...]:
    """Per-site padded solve lengths for the staged coreset engine: each
    site's valid-point count rounded up to its :func:`query_bucket` power
    of two, clamped at the lockstep pad length ``max_len``. The lockstep
    vmap pads *every* site to ``max_len``; solving each site at its own
    bucket instead is where the staged path's wall-clock win on skewed
    partitions comes from, while the O(log max_len) bucket set bounds the
    number of compiled per-site specializations exactly as serving's query
    bucketing does (DESIGN.md Sec. 9)."""
    return tuple(min(query_bucket(int(c), min_bucket=min_bucket),
                     int(max_len)) for c in site_counts)


def chunk_queries(points: Array, min_bucket: int = 8,
                  max_bucket: Optional[int] = None
                  ) -> list:
    """Split a query batch ``(n, d)`` into ``max_bucket``-row chunks, each
    padded to its own power-of-two bucket (the tail chunk pads to the
    smallest bucket that holds it). Returns ``[(padded, n_chunk, offset),
    ...]`` where ``offset`` is the chunk's row offset into the original
    batch; an empty batch yields one all-padding chunk (``n_chunk == 0``),
    mirroring :func:`pad_queries`. Under any adversarial sweep of batch
    sizes the set of emitted padded shapes stays within the bounded bucket
    set of :func:`query_bucket`."""
    n = points.shape[0]
    step = max_bucket if max_bucket is not None else max(n, 1)
    out = []
    off = 0
    while True:
        part = points[off:off + step]
        out.append(pad_queries(part, min_bucket, max_bucket)
                   + (off,))
        off += part.shape[0]
        if off >= n:
            return out


def min_dist_argmin(points: Array, centers: Array, block_n: int = 256,
                    block_k: int = 256,
                    interpret: Optional[bool] = None
                    ) -> Tuple[Array, Array]:
    """Fused min-distance/argmin: (n,d),(k,d) -> ((n,) f32, (n,) i32)."""
    n, d = points.shape
    k = centers.shape[0]
    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (k - 1).bit_length()))
    p = _pad_dim(_pad_dim(points, 1, 128), 0, block_n)
    c = _pad_dim(centers, 1, 128)
    c = _pad_dim(c, 0, block_k, value=_CENTER_SENTINEL)
    md, am = _distance_argmin(p, c, block_n=block_n, block_k=block_k,
                              interpret=_auto_interpret(interpret))
    return md[:n, 0], am[:n, 0]


def min_dist_argmin_batched(points: Array, centers: Array,
                            block_n: int = 256, block_k: int = 256,
                            interpret: Optional[bool] = None
                            ) -> Tuple[Array, Array]:
    """Stacked-tenant fused min-distance/argmin: ``(T, m, d), (T, k, d) ->
    ((T, m) f32, (T, m) i32)`` in one kernel launch (the multi-tenant
    serving hot path). Per-tenant semantics match :func:`min_dist_argmin`;
    ragged tenants arrive pre-masked -- padded center rows filled with the
    sentinel (``backend.query_assignments_batched`` does this from a
    boolean mask) so they never win the argmin."""
    T, m, d = points.shape
    k = centers.shape[1]
    block_n = min(block_n, max(8, 1 << (m - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (k - 1).bit_length()))
    p = _pad_dim(_pad_dim(points, 2, 128), 1, block_n)
    c = _pad_dim(centers, 2, 128)
    c = _pad_dim(c, 1, block_k, value=_CENTER_SENTINEL)
    md, am = _distance_argmin_batched(p, c, block_n=block_n,
                                      block_k=block_k,
                                      interpret=_auto_interpret(interpret))
    return md[:, :m, 0], am[:, :m, 0]


def lloyd_stats(points: Array, centers: Array,
                weights: Optional[Array] = None, block_n: int = 256,
                interpret: Optional[bool] = None
                ) -> Tuple[Array, Array, Array]:
    """Fused Lloyd statistics: returns (sums (k,d) f32, counts (k,) f32,
    cost () f32). Falls back to kernel-1 + jnp segment ops when the (k, d)
    center block cannot stay VMEM-resident."""
    n, d = points.shape
    k = centers.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights
    d_pad = -(-d // 128) * 128
    k_pad = -(-k // 8) * 8
    if k_pad * d_pad > _LLOYD_RESIDENT_FLOATS:
        # two-pass fallback: fused assignment kernel + XLA one-hot matmul
        min_d2, assign = min_dist_argmin(points, centers, block_n=block_n,
                                         interpret=interpret)
        oh = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
        sums = oh.T @ points.astype(jnp.float32)
        counts = jnp.sum(oh, axis=0)
        cost = jnp.sum(w * min_d2)
        return sums, counts, cost

    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    p = _pad_dim(_pad_dim(points, 1, 128), 0, block_n)
    c = _pad_dim(centers, 1, 128)
    c = _pad_dim(c, 0, 8, value=_CENTER_SENTINEL)
    wp = _pad_dim(w.astype(jnp.float32)[:, None], 0, block_n)
    sums, counts, cost = _lloyd_stats(p, c, wp, block_n=block_n,
                                      interpret=_auto_interpret(interpret))
    return sums[:k, :d], counts[:k, 0], cost[0, 0]


def weiszfeld_stats(points: Array, centers: Array,
                    weights: Optional[Array] = None, block_n: int = 256,
                    interpret: Optional[bool] = None
                    ) -> Tuple[Array, Array, Array]:
    """Fused Weiszfeld statistics (k-median): returns (nums (k,d) f32,
    denoms (k,) f32, cost () f32). Falls back to kernel-1 + jnp one-hot ops
    when the (k, d) center block cannot stay VMEM-resident."""
    n, d = points.shape
    k = centers.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights
    d_pad = -(-d // 128) * 128
    k_pad = -(-k // 8) * 8
    if k_pad * d_pad > _LLOYD_RESIDENT_FLOATS:
        # two-pass fallback: fused assignment kernel + the shared normative
        # XLA reduction (exact-form distance + eta smoothing)
        _, assign = min_dist_argmin(points, centers, block_n=block_n,
                                    interpret=interpret)
        return ref.weiszfeld_reduce(points, centers, w, assign)

    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    p = _pad_dim(_pad_dim(points, 1, 128), 0, block_n)
    c = _pad_dim(centers, 1, 128)
    c = _pad_dim(c, 0, 8, value=_CENTER_SENTINEL)
    wp = _pad_dim(w.astype(jnp.float32)[:, None], 0, block_n)
    nums, denoms, cost = _weiszfeld_stats(p, c, wp, block_n=block_n,
                                          interpret=_auto_interpret(interpret))
    return nums[:k, :d], denoms[:k, 0], cost[0, 0]


def lloyd_step(points: Array, centers: Array,
               weights: Optional[Array] = None,
               interpret: Optional[bool] = None) -> Tuple[Array, Array]:
    """One full weighted Lloyd iteration via the fused kernel: returns
    (new_centers (k,d), cost ()). Empty / non-positive-mass clusters keep
    their previous center (matches repro.core.clustering semantics)."""
    sums, counts, cost = lloyd_stats(points, centers, weights,
                                     interpret=interpret)
    eps = 1e-12
    new = sums / jnp.where(counts > eps, counts, 1.0)[:, None]
    new = jnp.where((counts > eps)[:, None], new, centers.astype(jnp.float32))
    return new.astype(centers.dtype), cost
