"""Pure-jnp oracles for the Pallas kernels.

These are the semantics the kernels must match (assert_allclose in
tests/test_kernels.py across shape/dtype sweeps). They materialize the full
(n, k) distance matrix -- exactly what the fused kernels avoid.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def min_dist_argmin_ref(points: Array, centers: Array
                        ) -> Tuple[Array, Array]:
    """(n,d),(k,d) -> min squared distance (n,) f32 and argmin (n,) i32."""
    p = points.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    p2 = jnp.sum(p * p, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = jnp.maximum(p2 + c2[None, :] - 2.0 * (p @ c.T), 0.0)
    return jnp.min(d2, axis=-1), jnp.argmin(d2, axis=-1).astype(jnp.int32)


def lloyd_stats_ref(points: Array, centers: Array,
                    weights: Optional[Array] = None
                    ) -> Tuple[Array, Array, Array]:
    """One fused Lloyd statistics pass.

    Returns (sums (k,d) f32, counts (k,) f32, cost () f32) where
    sums[c] = sum_{p: argmin(p)=c} w_p * p, counts[c] = sum w_p,
    cost = sum_p w_p * min_d2(p).
    """
    p = points.astype(jnp.float32)
    w = (jnp.ones((p.shape[0],), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    min_d2, assign = min_dist_argmin_ref(points, centers)
    k = centers.shape[0]
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
    sums = oh.T @ p
    counts = jnp.sum(oh, axis=0)
    cost = jnp.sum(w * min_d2)
    return sums, counts, cost
