"""Pure-jnp oracles for the Pallas kernels.

These are the semantics the kernels must match (assert_allclose in
tests/test_kernels.py across shape/dtype sweeps). They materialize the full
(n, k) distance matrix -- exactly what the fused kernels avoid.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def min_dist_argmin_ref(points: Array, centers: Array
                        ) -> Tuple[Array, Array]:
    """(n,d),(k,d) -> min squared distance (n,) f32 and argmin (n,) i32."""
    p = points.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    p2 = jnp.sum(p * p, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = jnp.maximum(p2 + c2[None, :] - 2.0 * (p @ c.T), 0.0)
    return jnp.min(d2, axis=-1), jnp.argmin(d2, axis=-1).astype(jnp.int32)


# Masking sentinel for padded / masked-out center rows: a center at
# coordinate 1e15 is ~30 orders of magnitude farther than any real data, so
# it can never win an argmin, yet its squared distance stays finite in f32
# (d * 1e30 << 3.4e38) -- no inf/NaN propagation through min reductions.
# Shared by ops.py shape padding and the stacked-tenant masking contract of
# backend.query_assignments_batched (DESIGN.md Sec. 13).
CENTER_SENTINEL = 1.0e15


def min_dist_argmin_batched_ref(points: Array, centers: Array
                                ) -> Tuple[Array, Array]:
    """Stacked-tenant oracle: ``(T, m, d), (T, k, d) -> ((T, m) f32,
    (T, m) i32)`` -- tenant t's queries reduced over tenant t's centers
    only, as a plain per-tenant loop over :func:`min_dist_argmin_ref`.
    Masked-out / ragged center rows are expected pre-filled with
    :data:`CENTER_SENTINEL` (they never win the argmin)."""
    outs = [min_dist_argmin_ref(points[t], centers[t])
            for t in range(points.shape[0])]
    return (jnp.stack([md for md, _ in outs]),
            jnp.stack([am for _, am in outs]))


def lloyd_stats_ref(points: Array, centers: Array,
                    weights: Optional[Array] = None
                    ) -> Tuple[Array, Array, Array]:
    """One fused Lloyd statistics pass.

    Returns (sums (k,d) f32, counts (k,) f32, cost () f32) where
    sums[c] = sum_{p: argmin(p)=c} w_p * p, counts[c] = sum w_p,
    cost = sum_p w_p * min_d2(p).
    """
    p = points.astype(jnp.float32)
    w = (jnp.ones((p.shape[0],), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    min_d2, assign = min_dist_argmin_ref(points, centers)
    k = centers.shape[0]
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
    sums = oh.T @ p
    counts = jnp.sum(oh, axis=0)
    cost = jnp.sum(w * min_d2)
    return sums, counts, cost


# Squared smoothing length eta^2 of the Weiszfeld inverse distance:
# dist = sqrt(d2 + eta^2). The classic iteration is undefined at data
# points, and k-means++ seeds ARE data points; eta bounds the pull of a
# center-coincident point at w/eta instead of an unbounded (and float32-
# noise-amplified) spike, so the iterate escapes its seed in O(1) passes
# and all backends agree bit-for-bit on the clamp (DESIGN.md Sec. 10).
WEISZFELD_ETA2 = 1e-6


def weiszfeld_reduce(points: Array, centers: Array,
                     weights: Optional[Array], assign: Array
                     ) -> Tuple[Array, Array, Array]:
    """The normative Weiszfeld reduction given an assignment (DESIGN.md
    Sec. 10), shared by the jnp backends, the ops.py two-pass fallback and
    the oracle so the numerics rules cannot desynchronize:

    * exact-form assigned distance d2(p) = sum((p - c_assign(p))^2) -- the
      |p|^2 + |c|^2 - 2 p.c matmul trick cancels catastrophically near
      zero and the inverse distance amplifies that float32 noise by orders
      of magnitude across backends;
    * eta-smoothed inverse dist(p) = sqrt(d2(p) + WEISZFELD_ETA2) with
      max(w, 0) membership mass and the signed, unsmoothed cost.

    Returns (nums (k,d) f32, denoms (k,) f32, cost () f32).
    """
    p = points.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    w = (jnp.ones((p.shape[0],), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    diff = p - c[assign]
    d2 = jnp.sum(diff * diff, axis=-1)
    dist = jnp.sqrt(d2 + WEISZFELD_ETA2)
    inv = jnp.maximum(w, 0.0) / dist
    k = centers.shape[0]
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32) * inv[:, None]
    nums = oh.T @ p
    denoms = jnp.sum(oh, axis=0)
    cost = jnp.sum(w * jnp.sqrt(d2))
    return nums, denoms, cost


def weiszfeld_stats_ref(points: Array, centers: Array,
                        weights: Optional[Array] = None
                        ) -> Tuple[Array, Array, Array]:
    """One fused Weiszfeld statistics pass (k-median).

    Returns (nums (k,d) f32, denoms (k,) f32, cost () f32) where, with
    dist(p) = sqrt(d2(p) + eta^2) the smoothed exact-form distance to the
    nearest center,
    nums[c] = sum_{p: argmin(p)=c} max(w_p, 0) * p / dist(p),
    denoms[c] = sum_{p: argmin(p)=c} max(w_p, 0) / dist(p),
    cost = sum_p w_p * sqrt(d2(p))  (signed weights, unsmoothed metric).
    """
    _, assign = min_dist_argmin_ref(points, centers)
    return weiszfeld_reduce(points, centers, weights, assign)
