"""Fused Weiszfeld-statistics Pallas TPU kernel (the k-median peer of
``lloyd_update.py``).

One pass over the points produces everything a fused k-median refinement
pass (assign + one Weiszfeld geometric-median update) needs:

    nums[c]   = sum_{p : argmin(p) = c} max(w_p, 0) * p / d(p, y_c)   (k, d)
    denoms[c] = sum_{p : argmin(p) = c} max(w_p, 0) / d(p, y_c)       (k,)
    cost      = sum_p w_p * d(p, Y)                                   ()

where d(p, y_c) = sqrt(d2(p) + eta^2) is the smoothed euclidean distance of
a point to its *nearest* center -- the only distance a Weiszfeld step over
the argmin partition ever divides by, which is why the (n, k) distance
matrix never needs to exist. Membership mass is clamped to max(w, 0)
(optimizing against the negative part of a signed coreset measure admits
spurious minima) while the reported cost keeps the signed weights, matching
``repro.core.clustering`` semantics (DESIGN.md Sec. 10).

Numerics: the argmin is selected on the MXU |p|^2 + |c|^2 - 2 p.c distance
block (robust -- ties are the only casualties of its cancellation noise),
but the distance fed to the *inverse* is recomputed in the exact
subtraction form sum((p - c_arg)^2): near zero the matmul trick is pure
cancellation noise (~1e-6 at unit scale), and 1/sqrt amplifies that into
orders-of-magnitude cross-backend disagreement exactly where k-means++
seeds sit (seeds are data points). ``ref.WEISZFELD_ETA2`` bounds the pull
of a truly coincident point at w/eta.

Per point tile: the distance block is computed on the MXU, the argmin is
converted to a one-hot matrix with an iota compare, the assigned center is
gathered back with a one-hot matmul (exact: one 1.0 per row), and the
numerator accumulation is a third MXU matmul (1/d-scaled one_hot)^T @
points -- the two-matmul structure of the Lloyd-statistics kernel plus one
gather matmul.

The centers (k, d) stay fully resident in VMEM, so this kernel targets the
clustering regime (k*d <= ~1M f32 = 4 MB); ops.py falls back to the two-pass
formulation when the resident block would not fit.

Grid: (n/bn,). All three outputs use constant index maps: they are revisited
by every grid step and accumulated in VMEM, written back once at the end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import WEISZFELD_ETA2

Array = jax.Array


def _kernel(p_ref, c_ref, w_ref, nums_ref, denoms_ref, cost_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        nums_ref[...] = jnp.zeros_like(nums_ref)
        denoms_ref[...] = jnp.zeros_like(denoms_ref)
        cost_ref[...] = jnp.zeros_like(cost_ref)

    p = p_ref[...].astype(jnp.float32)            # (bn, d)
    c = c_ref[...].astype(jnp.float32)            # (k, d)
    w = w_ref[...].astype(jnp.float32)            # (bn, 1)

    p2 = jnp.sum(p * p, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    prod = jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(p2 + c2[None, :] - 2.0 * prod, 0.0)     # (bn, k)
    arg = jnp.argmin(d2, axis=1).astype(jnp.int32)           # (bn,)

    k = c.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (p.shape[0], k), 1)
    onehot = jnp.where(iota == arg[:, None], 1.0, 0.0)       # (bn, k)

    # exact-form distance to the assigned center: gather on the MXU
    # (exactly one 1.0 per row, padded sentinel rows multiplied by 0.0),
    # then subtract -- no cancellation near zero.
    c_at = jax.lax.dot_general(
        onehot, c, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (bn, d)
    diff = p - c_at
    min_d2 = jnp.sum(diff * diff, axis=1, keepdims=True)     # (bn, 1)
    dist = jnp.sqrt(min_d2 + WEISZFELD_ETA2)                 # (bn, 1)
    inv = jnp.maximum(w, 0.0) / dist                         # (bn, 1)
    onehot = onehot * inv                                    # (bn, k)

    # MXU: (k, bn) @ (bn, d)
    nums_ref[...] += jax.lax.dot_general(
        onehot, p, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    denoms_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).T   # (k, 1)
    cost_ref[...] += jnp.sum(w * jnp.sqrt(min_d2), keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def weiszfeld_stats(points: Array, centers: Array, weights: Array,
                    block_n: int = 256, interpret: bool = False):
    """Raw kernel entry; shapes pre-padded (n % block_n == 0, padded points
    have weight 0, padded center rows huge). Returns (nums (k,d) f32,
    denoms (k,1) f32, cost (1,1) f32)."""
    n, d = points.shape
    k, _ = centers.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(points, centers, weights)
