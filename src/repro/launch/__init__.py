from repro.launch import ft, mesh, shapes
from repro.launch.mesh import make_mesh, make_production_mesh

__all__ = ["ft", "mesh", "shapes", "make_mesh", "make_production_mesh"]
