import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: for every (architecture x input shape) cell, lower +
compile the real step function against the production mesh with abstract
inputs (ShapeDtypeStruct -- zero device allocation), print the memory and
cost analysis, and persist the roofline quantities parsed from the
post-SPMD HLO.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The XLA_FLAGS line above MUST run before any jax import (jax locks the
device count at first init); this module is the only place it is set.
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, all_cells, cells_for
from repro.launch.specs import build_cell
from repro.roofline.report import HBM_PER_CHIP, build_report


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             verbose: bool = True) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    pod_block = 256 if mesh_name == "multi" else None
    cell = build_cell(arch, shape_name, mesh)

    lowered = cell.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # old jax returns [dict]
            ca = ca[0] if ca else {}
    except Exception:
        ca = {}
    hlo_text = compiled.as_text()

    peak = float(getattr(ma, "temp_size_in_bytes", 0)
                 + getattr(ma, "argument_size_in_bytes", 0)
                 + getattr(ma, "output_size_in_bytes", 0)
                 - getattr(ma, "alias_size_in_bytes", 0))
    rep = build_report(
        arch, shape_name, mesh_name, cell.cfg, cell.shape.kind,
        cell.shape.seq_len, cell.shape.global_batch,
        n_devices=mesh.size, hlo_text=hlo_text, xla_cost=dict(ca) if ca else {},
        peak_memory=peak, pod_block=pod_block,
        microbatches=cell.microbatches)

    result = rep.to_dict()
    result.update({
        "lower_s": t_lower, "compile_s": t_compile,
        "arg_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
        "out_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
        "fits_hbm": peak <= HBM_PER_CHIP,
        "status": "ok",
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"compile={t_compile:.1f}s peak={peak/1e9:.2f}GB "
              f"fits={result['fits_hbm']} "
              f"compute={rep.compute_s:.3e}s memory={rep.memory_s:.3e}s "
              f"collective={rep.collective_s:.3e}s -> {rep.bottleneck} "
              f"useful={rep.useful_flop_ratio:.2f} "
              f"roofline={rep.roofline_fraction:.2f}")
        print(f"  memory_analysis: args={result['arg_bytes']/1e9:.2f}GB "
              f"out={result['out_bytes']/1e9:.2f}GB "
              f"temp={result['temp_bytes']/1e9:.2f}GB "
              f"aliased={result['alias_bytes']/1e9:.2f}GB")
        print(f"  cost_analysis: xla_flops={rep.xla_flops:.3e} "
              f"hlo_dot_flops={rep.hlo_dot_flops:.3e} "
              f"model_flops/dev="
              f"{rep.model_flops_total/mesh.size:.3e}")
        print(f"  collectives: {rep.collective_counts} "
              f"ici={rep.ici_bytes/1e6:.1f}MB dcn={rep.dcn_bytes/1e6:.1f}MB")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(fname, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch, "--arch required unless --all"
        shapes = [args.shape] if args.shape else cells_for(args.arch)
        cells = [(args.arch, s) for s in shapes]

    failures = []
    for arch, shape_name in cells:
        for mesh_name in meshes:
            try:
                run_cell(arch, shape_name, mesh_name, args.out)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape_name, mesh_name, str(e)))
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    with open(os.path.join(
                            args.out,
                            f"{arch}__{shape_name}__{mesh_name}.json"),
                            "w") as f:
                        json.dump({"status": "fail", "error": str(e)}, f)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
