"""Fault tolerance: supervised training with heartbeat monitoring,
restart-from-checkpoint, and straggler detection.

The Supervisor runs the training driver as a subprocess. The trainer writes
a heartbeat file every step; the supervisor kills + restarts the run (from
the latest complete checkpoint -- the trainer auto-resumes) when the
heartbeat goes stale (hang/crash/straggler) or the process dies. Restart
count and backoff are bounded. Failure injection for tests:
``REPRO_FAIL_AT_STEP`` makes the trainer crash at a given step, proving the
checkpoint/restart path end to end (tests/test_fault_tolerance.py).

At 1000+ node scale the same supervisor runs per-pod under the cluster
scheduler; the heartbeat file becomes the coordination-service key and
elastic restore (repro.checkpoint.restore with new-mesh shardings) handles
shrunken meshes. Straggler mitigation: per-step wall time is logged; steps
slower than ``straggler_factor`` x the running median raise an alert (and,
under the supervisor, an optional restart on a healthy replica set).
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys
import time
from typing import List, Optional


@dataclasses.dataclass
class SupervisorConfig:
    heartbeat_path: str
    heartbeat_timeout_s: float = 120.0
    max_restarts: int = 5
    backoff_s: float = 1.0
    poll_s: float = 0.5


class Heartbeat:
    """Trainer side: call ``beat(step)`` every step."""

    def __init__(self, path: str):
        self.path = path
        self._t0 = time.time()
        self._times: List[float] = []

    def beat(self, step: int, metrics: Optional[dict] = None):
        now = time.time()
        self._times.append(now)
        payload = {"step": step, "time": now,
                   "uptime": now - self._t0}
        if metrics:
            payload.update({k: float(v) for k, v in metrics.items()})
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)

    def step_times(self) -> List[float]:
        return [b - a for a, b in zip(self._times, self._times[1:])]


def detect_straggler(step_times: List[float], factor: float = 3.0
                     ) -> Optional[int]:
    """Index of the first step slower than ``factor`` x running median."""
    if len(step_times) < 5:
        return None
    sorted_t = sorted(step_times)
    median = sorted_t[len(sorted_t) // 2]
    for i, t in enumerate(step_times):
        if t > factor * median:
            return i
    return None


class Supervisor:
    """Run ``argv`` under heartbeat supervision; restart on crash or stale
    heartbeat, up to ``max_restarts`` times."""

    def __init__(self, argv: List[str], cfg: SupervisorConfig,
                 env: Optional[dict] = None):
        self.argv = argv
        self.cfg = cfg
        self.env = env or dict(os.environ)
        self.restarts = 0
        self.events: List[str] = []

    def _heartbeat_age(self) -> float:
        try:
            with open(self.cfg.heartbeat_path) as f:
                return time.time() - json.load(f)["time"]
        except Exception:
            return 0.0  # no heartbeat yet: grace

    def run(self) -> int:
        while True:
            proc = subprocess.Popen(self.argv, env=self.env)
            start = time.time()
            while True:
                ret = proc.poll()
                if ret is not None:
                    break
                if (time.time() - start > self.cfg.heartbeat_timeout_s
                        and self._heartbeat_age()
                        > self.cfg.heartbeat_timeout_s):
                    self.events.append("stale-heartbeat-kill")
                    proc.send_signal(signal.SIGKILL)
                    proc.wait()
                    ret = -9
                    break
                time.sleep(self.cfg.poll_s)
            if ret == 0:
                self.events.append("clean-exit")
                return 0
            self.restarts += 1
            self.events.append(f"restart-{self.restarts}(ret={ret})")
            if self.restarts > self.cfg.max_restarts:
                self.events.append("gave-up")
                return ret
            time.sleep(self.cfg.backoff_s * self.restarts)
