"""Production meshes. A FUNCTION, not a module-level constant: importing
this module never touches jax device state (the dry run must set XLA_FLAGS
before the first jax call)."""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); only data
    parallelism (gradient all-reduce) crosses the pod (DCN) axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper for tests / small runs."""
    return _mesh(shape, axes)
