"""Serving driver: batched generation with the slot Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
        --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import init_params
from repro.serve import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b", choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(
        0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
        max_new=args.max_new) for _ in range(args.requests)]

    eng = Engine(params, cfg, n_slots=args.slots, max_len=args.max_len)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) - len(r.prompt) for r in done)
    print(f"[serve] {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s on CPU)")
    for i, r in enumerate(done):
        print(f"  req{i}: prompt={r.prompt[:4]}... out_len={len(r.out)}")
    return done


if __name__ == "__main__":
    main()
