"""Assigned input shapes x per-arch cell table.

``long_500k`` lowers ``serve_step`` with a 512k-token cache and needs
sub-quadratic sequence mixing: it runs only for gemma3 (5/6 local layers +
length-sharded global cache), mamba2 (O(1) state) and recurrentgemma
(RG-LRU + 2048-window local attention). Pure full-attention archs skip it
(DESIGN.md Sec. 5). ``decode_*`` shapes lower serve_step (one token against
a seq_len cache), not train_step.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs import ARCH_IDS


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs with sub-quadratic sequence mixing -> run long_500k
LONG_CONTEXT_OK = {"gemma3_27b", "mamba2_370m", "recurrentgemma_2b"}


def cells_for(arch: str) -> List[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_OK:
        names.append("long_500k")
    return names


def all_cells() -> List[tuple]:
    return [(a, s) for a in ARCH_IDS for s in cells_for(a)]
