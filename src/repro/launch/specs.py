"""Abstract input specs (ShapeDtypeStruct + NamedSharding) for every
(architecture x input shape) cell -- the dry run lowers against these; no
device memory is ever allocated for the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.shapes import SHAPES, ShapeSpec
from repro.models import cache_spec, forward, init_params, make_positions
from repro.models.config import ModelConfig
from repro.models.sharding import param_shardings, resolve, set_mesh
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step

PyTree = Any


def _sds(tree: PyTree, shardings: PyTree) -> PyTree:
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree, shardings)


def _divisible_spec(dims, shape, mesh: Mesh, layout: str = "tp") -> P:
    fixed = []
    for d, size in zip(dims, shape):
        r = resolve(d, mesh, layout)
        names = (r,) if isinstance(r, str) else (r or ())
        total = 1
        for nm in names:
            total *= mesh.shape[nm]
        fixed.append(r if total > 1 and size % total == 0 else None)
    return P(*fixed)


def _cache_shardings(cache_abs: PyTree, mesh: Mesh) -> PyTree:
    """KV caches: batch over data, *length over model* (flash-decode layout;
    works for MQA where heads cannot shard). States: heads/width over
    model."""

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        last = names[-1]
        lead = len(leaf.shape)

        def dims(*ds):
            return (None,) * (lead - len(ds)) + ds

        if last in ("k", "v"):
            d = dims("data", "model", None, None)
        elif last in ("k_scale", "v_scale"):
            d = dims("data", "model", None)
        elif last == "pos":
            d = dims("data", "model")
        elif last == "conv":
            d = dims("data", None, "model")
        elif last == "ssm":
            d = dims("data", "model", None, None)
        elif last == "h":
            d = dims("data", "model")
        else:
            d = (None,) * lead
        return NamedSharding(mesh, _divisible_spec(d, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_abs)


def _opt_shardings(params_shardings: PyTree, mesh: Mesh) -> PyTree:
    return {"m": params_shardings, "v": params_shardings,
            "step": NamedSharding(mesh, P())}


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                         mesh: Mesh) -> int:
    """Per-microbatch global batch of 32 sequences at 4k (activation
    memory; see DESIGN.md Sec. 6); 16 for >50B-param models -- but never
    below the batch-sharding ways (microbatches must still shard over
    pod x data)."""
    if shape.kind != "train":
        return 1
    ways = mesh.shape["data"] * mesh.shape.get("pod", 1)
    per_mb = 16 if cfg.param_count() > 50e9 else 32
    per_mb = max(per_mb, ways)
    return max(shape.global_batch // per_mb, 1)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Callable
    args: Tuple            # ShapeDtypeStructs (sharded)
    donate: Tuple[int, ...]
    microbatches: int = 1

    def lower(self):
        return jax.jit(self.fn, donate_argnums=self.donate).lower(*self.args)


def _serve_param_sds(params_abs, pshard, mesh: Mesh,
                     cfg: Optional[ModelConfig] = None):
    """Serving params: bf16 (no f32 master / optimizer state at inference)
    and -- when the TP-sharded weights fit comfortably -- replicated over
    the data axis instead of FSDP, killing the per-layer parameter
    all-gathers that otherwise dominate the decode collective term."""
    def to_bf16(a):
        dt = jnp.bfloat16 if jnp.issubdtype(a.dtype, jnp.floating) else a.dtype
        return jax.ShapeDtypeStruct(a.shape, dt)

    p16 = jax.tree.map(to_bf16, params_abs)
    bytes_per_model_shard = sum(
        a.size * a.dtype.itemsize for a in jax.tree.leaves(p16)
    ) / mesh.shape["model"]
    # 2.5 GB replication threshold: conservative for the CPU dry-run (XLA
    # CPU hoists a one-off f32 copy of loop-invariant bf16 weights; TPU has
    # native bf16 dots and could replicate up to ~10 GB/shard). MoE archs
    # keep FSDP: their expert tables dwarf the per-token active weights.
    is_moe = cfg is not None and cfg.n_experts > 0
    if bytes_per_model_shard <= 2.5e9 and not is_moe:
        def drop_data(ns):
            spec = P(*[None if r in ("data", ("data",)) or
                       (isinstance(r, tuple) and "data" in r) else r
                       for r in ns.spec])
            return NamedSharding(mesh, spec)
        pshard = jax.tree.map(drop_data, pshard)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        p16, pshard)


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               tc: Optional[TrainConfig] = None,
               cfg_override: Optional[ModelConfig] = None,
               layout: str = "tp") -> Cell:
    shape = SHAPES[shape_name]
    cfg = cfg_override or configs.get(arch)
    params_abs = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    pshard = param_shardings(params_abs, mesh, layout)
    if shape.kind in ("prefill", "decode"):
        params_sds = _serve_param_sds(params_abs, pshard, mesh, cfg)
    else:
        params_sds = _sds(params_abs, pshard)
    batch_spec = _divisible_spec(("batch", None),
                                 (shape.global_batch, shape.seq_len), mesh,
                                 layout)
    bsh = NamedSharding(mesh, batch_spec)

    if shape.kind == "train":
        mb = default_microbatches(cfg, shape, mesh)
        tc = tc or TrainConfig(microbatches=mb, remat="full")
        if tc.bf16_params:
            opt_abs = jax.eval_shape(
                lambda p: adamw.init(p, keep_master=True), params_abs)
            params_abs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(
                    a.shape, jnp.bfloat16
                    if jnp.issubdtype(a.dtype, jnp.floating) else a.dtype),
                params_abs)
            params_sds = _sds(params_abs, pshard)
            opt_sh = _opt_shardings(pshard, mesh)
            opt_sh["master"] = pshard
            opt_sds = _sds(opt_abs, opt_sh)
        else:
            opt_abs = jax.eval_shape(lambda: adamw.init(params_abs))
            opt_sds = _sds(opt_abs, _opt_shardings(pshard, mesh))
        batch = {
            "tokens": jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len), jnp.int32, sharding=bsh),
            "labels": jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len), jnp.int32, sharding=bsh),
        }
        step = jax.ShapeDtypeStruct((), jnp.int32)
        ts = make_train_step(cfg, tc)

        def fn(params, opt_state, batch, step):
            with set_mesh(mesh, layout):
                return ts(params, opt_state, batch, step)

        return Cell(arch, shape, cfg, fn,
                    (params_sds, opt_sds, batch, step), donate=(0, 1),
                    microbatches=tc.microbatches)

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32, sharding=bsh)
        cache_abs0 = cache_spec(cfg, shape.global_batch, shape.seq_len)
        cache_bytes0 = sum(a.size * a.dtype.itemsize
                           for a in jax.tree.leaves(cache_abs0)) / mesh.size
        if cache_bytes0 > 2.5e9 and cfg.kv_cache_dtype != "int8":
            cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")

        def fn(params, tokens):
            from repro.models import init_cache
            with set_mesh(mesh, layout):
                B = tokens.shape[0]
                cache = init_cache(cfg, B, shape.seq_len)
                pos = make_positions(tokens, cfg)
                logits, cache, _ = forward(params, tokens, pos, cfg,
                                           cache=cache)
                return logits[:, -1], cache

        return Cell(arch, shape, cfg, fn, (params_sds, tokens), donate=())

    # decode: one new token against a seq_len cache. If the bf16 cache alone
    # would eat most of the 16 GB HBM budget, serve with the int8-quantized
    # cache (2x saving; accuracy impact tested in tests/test_models.py).
    cache_abs = cache_spec(cfg, shape.global_batch, shape.seq_len)
    cache_bytes = sum(a.size * a.dtype.itemsize
                      for a in jax.tree.leaves(cache_abs)) / mesh.size
    if cache_bytes > 2.5e9 and cfg.kv_cache_dtype != "int8":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
        cache_abs = cache_spec(cfg, shape.global_batch, shape.seq_len)
    cache_sds = _sds(cache_abs, _cache_shardings(cache_abs, mesh))
    tok_spec = _divisible_spec(("batch", None), (shape.global_batch, 1), mesh)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                 sharding=NamedSharding(mesh, tok_spec))
    pos_spec = _divisible_spec(("batch",), (shape.global_batch,), mesh)
    positions = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                                     sharding=NamedSharding(mesh, pos_spec))

    def fn(params, token, positions):
        with set_mesh(mesh, layout):
            pos = positions[:, None]
            if cfg.mrope_sections is not None:
                pos = jnp.broadcast_to(pos[:, None, :],
                                       (token.shape[0], 3, 1))
            def run(cache):
                logits, cache, _ = forward(params, token, pos, cfg,
                                           cache=cache)
                return logits[:, 0], cache
            return run

    # close over cache as a positional arg for donation
    def fn2(params, token, positions, cache):
        return fn(params, token, positions)(cache)

    return Cell(arch, shape, cfg, fn2,
                (params_sds, token, positions, cache_sds), donate=(3,))


def input_specs(arch: str, shape_name: str, mesh: Mesh) -> Tuple:
    """The (fn, kwargs) pair the dry run lowers: fn is the jit-able step
    (train_step / prefill_step / decode_step) and the returned structs are
    weak-type-correct, shardable, allocation-free stand-ins."""
    cell = build_cell(arch, shape_name, mesh)
    return cell.fn, cell.args
