"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features: arbitrary (data, model) mesh on the local devices, resume from the
latest checkpoint, async checkpointing, heartbeat for the fault-tolerance
supervisor, failure injection (REPRO_FAIL_AT_STEP), and coreset-based data
selection (--data-selection coreset) -- the paper's technique in the
training data plane.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data import BigramLM, embed_examples, gather_selected, select_coreset
from repro.launch.ft import Heartbeat
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.models.sharding import param_shardings, set_mesh
from repro.optim import adamw
from repro.train import TrainConfig, make_train_step


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b", choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) config")
    ap.add_argument("--width", type=int, default=0,
                    help="override d_model/d_ff scale for ~100M runs")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL, e.g. 2x4 (needs that many devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--heartbeat", default="")
    ap.add_argument("--data-selection", choices=["none", "coreset"],
                    default="none")
    ap.add_argument("--selection-pool", type=int, default=512,
                    help="candidate pool size per selection round")
    ap.add_argument("--selection-frac", type=float, default=0.25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    return ap.parse_args(argv)


def build_cfg(args):
    import dataclasses as dc
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get(args.arch))
    if args.width:
        cfg = dc.replace(
            cfg, d_model=args.width,
            d_ff=args.width * 4 if cfg.d_ff else 0,
            head_dim=max(args.width // max(cfg.n_heads, 1), 8)
            if cfg.n_heads else 0,
            lru_width=args.width if cfg.lru_width else 0)
    if args.layers:
        cfg = dc.replace(cfg, n_layers=args.layers)
    return cfg


def main(argv=None):
    args = parse_args(argv)
    cfg = build_cfg(args)
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model"))
    tc = TrainConfig(peak_lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 5),
                     microbatches=args.microbatches, remat="full")

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt_state = adamw.init(params)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir, keep_last=3)
        if latest_step(args.ckpt_dir) is not None:
            (params, opt_state), start_step = restore(
                args.ckpt_dir, target=(params, opt_state))
            print(f"[train] resumed from step {start_step}")

    pshard = param_shardings(params, mesh)
    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(
        opt_state, {"m": pshard, "v": pshard,
                    "step": NamedSharding(mesh, P())})

    ts = make_train_step(cfg, tc)

    def stepper(params, opt_state, batch, step):
        with set_mesh(mesh):
            return ts(params, opt_state, batch, step)

    step_fn = jax.jit(stepper, donate_argnums=(0, 1))
    data = BigramLM(cfg.vocab_size)
    hb = Heartbeat(args.heartbeat) if args.heartbeat else None
    fail_at = int(os.environ.get("REPRO_FAIL_AT_STEP", "-1"))
    bsh = NamedSharding(mesh, P("data", None))

    sel_batches = None
    if args.data_selection == "coreset":
        sel_batches = _coreset_pool(args, cfg, params, mesh, data)

    metrics_log = []
    t_last = time.time()
    for step in range(start_step, args.steps):
        if step == fail_at:
            print(f"[train] INJECTED FAILURE at step {step}", flush=True)
            os._exit(42)
        if sel_batches is not None:
            batch = sel_batches[step % len(sel_batches)]
        else:
            batch = data.batch(step, args.batch, args.seq)
        batch = jax.device_put(batch, {"tokens": bsh, "labels": bsh})
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.asarray(step, jnp.int32))
        if hb:
            hb.beat(step)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t_last
            t_last = time.time()
            print(f"[train] step={step} loss={m['loss']:.4f} "
                  f"ce={m['ce']:.4f} gnorm={m['grad_norm']:.3f} "
                  f"lr={m['lr']:.2e} ({dt:.2f}s)", flush=True)
            metrics_log.append({"step": step, **m})
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt:
        ckpt.save(args.steps, (params, opt_state))
        ckpt.wait()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f)
    print("[train] done")
    return metrics_log


def _coreset_pool(args, cfg, params, mesh, data):
    """Build a coreset-selected training set from a candidate pool
    (Algorithm 1 over example embeddings; see repro.data.selection)."""
    n_sites = max(mesh.shape["data"], 2)
    pool = data.batch(10_000_019, args.selection_pool, args.seq)
    toks = np.asarray(pool["tokens"])
    labs = np.asarray(pool["labels"])
    per = args.selection_pool // n_sites
    site_tokens = jnp.asarray(
        toks[: per * n_sites].reshape(n_sites, per, -1))
    emb = embed_examples(params["embed"]["table"], site_tokens)
    mask = jnp.ones(emb.shape[:2], bool)
    t = max(int(args.selection_frac * per * n_sites), 8)
    sel = select_coreset(jax.random.PRNGKey(1), emb, mask, k=8, t=t)
    chosen = gather_selected(site_tokens, sel)
    keep = np.asarray(chosen["weights"]) > 0
    sel_toks = np.asarray(chosen["tokens"])[keep]
    print(f"[train] coreset selection kept {keep.sum()} / "
          f"{args.selection_pool} examples "
          f"(comm: {n_sites} scalars + selection)")
    # rebuild batches from the selected subset (labels = shifted tokens of
    # the same bigram stream, recomputed by lookup)
    lab_lookup = {tuple(t): l for t, l in zip(toks.tolist(), labs.tolist())}
    sel_labs = np.asarray([lab_lookup[tuple(t)] for t in sel_toks.tolist()])
    batches = []
    B = args.batch
    for i in range(max(len(sel_toks) // B, 1)):
        sl = slice(i * B, (i + 1) * B)
        if len(sel_toks[sl]) < B:
            break
        batches.append({"tokens": jnp.asarray(sel_toks[sl]),
                        "labels": jnp.asarray(sel_labs[sl])})
    return batches or None


if __name__ == "__main__":
    main()
