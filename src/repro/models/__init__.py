"""Model zoo: unified block-pattern LM covering dense / MoE / SSM / hybrid /
VLM-backbone / audio-backbone families."""

from repro.models import blocks, config, layers, model, moe, rglru, sharding, ssd
from repro.models.config import ModelConfig
from repro.models.model import (cache_spec, forward, init_cache, init_params,
                                make_positions)

__all__ = [
    "blocks", "config", "layers", "model", "moe", "rglru", "sharding", "ssd",
    "ModelConfig", "cache_spec", "forward", "init_cache", "init_params",
    "make_positions",
]
