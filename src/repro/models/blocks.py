"""Residual blocks: one sequence-mixer ("attn" | "local" | "ssd" | "rglru")
plus -- for attention and RG-LRU blocks -- a (dense or MoE) MLP."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.config import ModelConfig
from repro.models.layers import (AttnCacheSpec, attention_apply,
                                 attention_init, mlp_apply, mlp_init,
                                 rmsnorm_apply, rmsnorm_init)
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rglru_apply, rglru_cache_init, rglru_init
from repro.models.ssd import ssd_apply, ssd_cache_init, ssd_init

Array = jax.Array
Params = Dict[str, Any]


def _has_mlp(cfg: ModelConfig, kind: str) -> bool:
    if kind in ("attn", "local"):
        return cfg.d_ff > 0 or cfg.n_experts > 0
    if kind == "rglru":
        return cfg.d_ff > 0
    return False


def block_init(key: Array, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"ln1": rmsnorm_init(d, cfg)}
    if kind in ("attn", "local"):
        p["attn"] = attention_init(ks[0], cfg)
    elif kind == "ssd":
        p["ssd"] = ssd_init(ks[0], cfg)
    elif kind == "rglru":
        p["rec"] = rglru_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        p["post_ln1"] = rmsnorm_init(d, cfg)
    if _has_mlp(cfg, kind):
        p["ln2"] = rmsnorm_init(d, cfg)
        if cfg.n_experts and kind in ("attn", "local"):
            p["moe"] = moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], cfg)
        if cfg.post_norms:
            p["post_ln2"] = rmsnorm_init(d, cfg)
    return p


def block_apply(
    p: Params,
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    kind: str,
    cache: Optional[Params] = None,
) -> Tuple[Array, Optional[Params], Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm_apply(p["ln1"], x, cfg.rms_eps)
    if kind in ("attn", "local"):
        h, new_cache = attention_apply(p["attn"], h, positions, cfg, kind,
                                       cache)
    elif kind == "ssd":
        h, new_cache = ssd_apply(p["ssd"], h, cfg, cache)
    else:  # rglru
        h, new_cache = rglru_apply(p["rec"], h, cfg, cache)
    if cfg.post_norms:
        h = rmsnorm_apply(p["post_ln1"], h, cfg.rms_eps)
    # sequence-parallel residual (Megatron-SP): the stream lives sharded
    # (batch, seq/model); mixers gather the sequence dim on entry and
    # reduce-scatter on exit. Constraining the mixer OUTPUT (not just the
    # post-add residual) pins the boundary exactly at the row-parallel
    # matmul so GSPMD emits a reduce-scatter, never a full all-reduce.
    # Keeps the remat activation stash 1/model_axis of the naive size.
    if h.shape[1] > 1:
        h = sharding.constrain(h, "batch", "model", None)
    x = x + h
    x = sharding.constrain(x, "batch", "model", None)

    if _has_mlp(cfg, kind):
        h = rmsnorm_apply(p["ln2"], x, cfg.rms_eps)
        if "moe" in p:
            h, aux = moe_apply(p["moe"], h, cfg)
        else:
            h = mlp_apply(p["mlp"], h, cfg)
        if cfg.post_norms:
            h = rmsnorm_apply(p["post_ln2"], h, cfg.rms_eps)
        if h.shape[1] > 1:
            h = sharding.constrain(h, "batch", "model", None)
        x = x + h
        x = sharding.constrain(x, "batch", "model", None)
    return x, new_cache, aux


def block_cache_init(batch: int, max_len: int, cfg: ModelConfig,
                     kind: str) -> Params:
    if kind == "attn":
        return AttnCacheSpec(max_len).init(batch, cfg)
    if kind == "local":
        return AttnCacheSpec(min(cfg.window, max_len)).init(batch, cfg)
    if kind == "ssd":
        return ssd_cache_init(batch, cfg)
    if kind == "rglru":
        return rglru_cache_init(batch, cfg)
    raise ValueError(kind)
