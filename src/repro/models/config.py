"""Unified model configuration covering all assigned architecture families.

A model is a stack of ``n_layers`` blocks whose sequence-mixer kind follows a
repeating ``pattern`` (period p):

    dense transformers      pattern = ("attn",)
    gemma3 local:global 5:1 pattern = ("local",)*5 + ("attn",)
    recurrentgemma 2:1      pattern = ("rglru", "rglru", "local")
    mamba2                  pattern = ("ssd",)

``n_layers`` need not be a multiple of p: the stack is scan(n_layers // p
periods) + the remaining ``n_layers % p`` blocks applied explicitly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _group_runs(kinds) -> Tuple[Tuple[str, int], ...]:
    runs = []
    for k in kinds:
        if runs and runs[-1][0] == k:
            runs[-1][1] += 1
        else:
            runs.append([k, 1])
    return tuple((k, n) for k, n in runs)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab_size: int
    # -- attention ----------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int = 0                  # local-attention window (tokens)
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    mrope_sections: Optional[Tuple[int, ...]] = None   # qwen2-vl M-RoPE
    attn_logit_softcap: float = 0.0
    post_norms: bool = False         # gemma-style sandwich norms
    # -- mlp ------------------------------------------------------------------
    d_ff: int = 0
    mlp_act: str = "silu"            # silu (swiglu) | gelu (geglu)
    mlp_gated: bool = True           # False = classic 2-matrix FFN
    # -- block pattern --------------------------------------------------------
    pattern: Tuple[str, ...] = ("attn",)
    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # -- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    # -- RG-LRU (recurrentgemma) ----------------------------------------------
    lru_width: int = 0
    # -- embedding / output ----------------------------------------------------
    tie_embeddings: bool = False
    emb_scale_by_sqrt_dim: bool = False   # gemma-style
    final_logit_softcap: float = 0.0
    # -- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_cache_dtype: str = "bfloat16"   # "int8": quantized serving cache
    rms_eps: float = 1e-6

    # ------------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_full_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def remainder_kinds(self) -> Tuple[str, ...]:
        return self.pattern[: self.n_layers % self.period]

    def runs(self) -> Tuple[Tuple[str, int], ...]:
        """The pattern grouped into maximal runs of one kind, e.g. gemma3's
        ("local",)*5+("attn",) -> (("local", 5), ("attn", 1)). Each run is
        executed as an inner scan so only ONE layer's gradients are live at
        a time (memory; see model.py)."""
        return _group_runs(self.pattern)

    def remainder_runs(self) -> Tuple[Tuple[str, int], ...]:
        return _group_runs(self.remainder_kinds)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the embedding table shards evenly over a
        16-way tensor axis (Megatron-style padding; padded ids are never
        emitted by the pipeline and are masked out of the loss)."""
        return _round_up(self.vocab_size, 256)

    @property
    def ssm_dinner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_dinner // self.ssm_headdim

    def validate(self) -> "ModelConfig":
        for kind in self.pattern:
            assert kind in ("attn", "local", "ssd", "rglru"), kind
        if any(k in ("attn", "local") for k in self.pattern):
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.head_dim > 0
            assert self.n_heads % self.n_kv_heads == 0
        if "local" in self.pattern:
            assert self.window > 0
        if "ssd" in self.pattern:
            assert self.ssm_state > 0
            assert self.ssm_dinner % self.ssm_headdim == 0
        if "rglru" in self.pattern:
            assert self.lru_width > 0
        if self.n_experts:
            assert 0 < self.top_k <= self.n_experts
        return self

    # -- analytics used by the roofline (6*N*D rule) --------------------
    def param_count(self) -> int:
        """Exact parameter count (embedding included once, untied head extra)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        return _count_params(self, active_only=True)


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    n_mats = 3 if cfg.mlp_gated else 2
    total = cfg.vocab_padded * d                      # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_padded * d                 # lm head
    kinds = list(cfg.pattern) * cfg.n_full_periods + list(cfg.remainder_kinds)
    for kind in kinds:
        has_mlp = (kind in ("attn", "local") and (cfg.d_ff or cfg.n_experts)
                   ) or (kind == "rglru" and cfg.d_ff)
        total += d + (d if has_mlp else 0)            # pre norms
        if cfg.post_norms:
            total += d + (d if has_mlp else 0)        # sandwich norms
        if kind in ("attn", "local"):
            qd = cfg.n_heads * cfg.head_dim
            kvd = cfg.n_kv_heads * cfg.head_dim
            total += d * qd + 2 * d * kvd + qd * d
            if cfg.qkv_bias:
                total += qd + 2 * kvd
            if cfg.qk_norm:
                total += 2 * cfg.head_dim
        elif kind == "ssd":
            din, h, g, n = (cfg.ssm_dinner, cfg.ssm_nheads, cfg.ssm_ngroups,
                            cfg.ssm_state)
            conv_dim = din + 2 * g * n
            total += d * (2 * din + 2 * g * n + h)    # in_proj
            total += (cfg.conv_width + 1) * conv_dim  # conv w + b
            total += 3 * h                            # A_log, D, dt_bias
            total += din                              # gated norm
            total += din * d                          # out_proj
        elif kind == "rglru":
            w = cfg.lru_width
            total += 3 * d * w                        # w_gate, w_x, w_out
            total += (cfg.conv_width + 1) * w         # conv w + b
            total += 2 * w * w + w                    # gates W_a, W_i, Lambda
        # MLP (attention and rglru blocks carry one)
        if kind in ("attn", "local") and cfg.n_experts:
            e = cfg.top_k if active_only else cfg.n_experts
            total += e * 3 * d * cfg.d_ff + d * cfg.n_experts  # experts+router
        elif has_mlp:
            total += n_mats * d * cfg.d_ff
    total += d                                        # final norm
    return int(total)
