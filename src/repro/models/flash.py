"""Memory-efficient causal attention with a flash-style custom VJP.

Pure-JAX autodiff of online-softmax attention saves every probability block
(the full B x H x L^2 matrix, ~4.3 GB/layer for qwen2-72b at 4k) across the
backward -- even under remat, because the inner scans stash their carries.
This custom_vjp stores only (q, k, v, out, m, l) -- O(B L H hd) -- and
*recomputes* the probability blocks chunk-by-chunk in the backward, exactly
like the FlashAttention backward pass.

Forward math matches layers._attention_rect (same chunking, same masking);
assumes attn_logit_softcap == 0 (true for every assigned arch -- gemma3
uses QK-norm, not soft-capping); layers.attention_apply falls back to the
plain path when a softcap is set.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
_NEG_INF = -1e30


def _fit(chunk: int, length: int) -> int:
    chunk = min(chunk, length)
    while length % chunk:
        chunk -= 1
    return chunk


def _fwd_impl(q, k, v, q_pos, k_pos, q_chunk, kv_chunk):
    """Returns out (B, Lq, KV, G, hd) f32 plus (m, l) (B, KV, G, Lq) f32."""
    B, Lq, KV, G, hd = q.shape
    kc = _fit(kv_chunk, k.shape[1])
    qc = _fit(q_chunk, Lq)
    nk = k.shape[1] // kc
    nq = Lq // qc
    scale = 1.0 / math.sqrt(hd)
    ks = k.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(nk, kc)

    def per_q(args):
        q_blk, qp = args

        def body(carry, inp):
            acc, m, l = carry
            k_blk, v_blk, kp = inp
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = kp[None, :] <= qp[:, None]
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v_blk.dtype),
                            v_blk, preferred_element_type=jnp.float32)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, qc, KV, G, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, qc), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out, m, l

    if nq == 1:
        out, m, l = per_q((q, q_pos))
        return out, m, l
    qs = q.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qps = q_pos.reshape(nq, qc)
    outs, ms, ls = jax.lax.map(per_q, (qs, qps))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Lq, KV, G, hd)
    m = jnp.concatenate(list(ms.transpose(0, 1, 2, 3, 4)), axis=-1) \
        if False else ms.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Lq)
    l = ls.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Lq)
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention(q, k, v, q_pos, k_pos, q_chunk=2048, kv_chunk=4096):
    """q (B, Lq, KV, G, hd) f32/bf16; k, v (B, Lkv, KV, hd); positions 1-D.
    Returns (B, Lq, KV, G, hd) in q.dtype."""
    out, _, _ = _fwd_impl(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), q_pos, k_pos, q_chunk,
                          kv_chunk)
    return out.astype(q.dtype)


def _flash_fwd(q, k, v, q_pos, k_pos, q_chunk, kv_chunk):
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    out, m, l = _fwd_impl(qf, kf, vf, q_pos, k_pos, q_chunk, kv_chunk)
    dtype_token = jnp.zeros((0,), q.dtype)   # carries the primal dtype
    return out.astype(q.dtype), (qf, kf, vf, q_pos, k_pos, out, m, l,
                                 dtype_token)


def _flash_bwd(q_chunk, kv_chunk, res, dout):
    qf, kf, vf, q_pos, k_pos, out, m, l, dtype_token = res
    in_dtype = dtype_token.dtype
    B, Lq, KV, G, hd = qf.shape
    Lk = kf.shape[1]
    kc = _fit(kv_chunk, Lk)
    nk = Lk // kc
    scale = 1.0 / math.sqrt(hd)
    do = dout.astype(jnp.float32)
    linv = 1.0 / jnp.maximum(l, 1e-30)                     # (B,KV,G,Lq)
    # delta = sum_h dout * out  (B, KV, G, Lq)
    delta = jnp.einsum("bqkgh,bqkgh->bkgq", do, out)

    ks = kf.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = vf.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(nk, kc)

    def body(dq_acc, inp):
        k_blk, v_blk, kp = inp
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k_blk,
                       preferred_element_type=jnp.float32) * scale
        mask = kp[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        p = jnp.exp(s - m[..., None]) * linv[..., None]    # (B,KV,G,Lq,kc)
        # dv_j = p^T dout
        dv = jnp.einsum("bkgqs,bqkgh->bskh", p, do)
        # dp = dout v^T ; ds = p * (dp - delta)
        dp = jnp.einsum("bqkgh,bskh->bkgqs", do, v_blk)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("bkgqs,bskh->bqkgh", ds,
                                     k_blk) * scale
        dk = jnp.einsum("bkgqs,bqkgh->bskh", ds, qf) * scale
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros_like(qf)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks, vs, kps))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Lk, KV, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Lk, KV, hd)
    return (dq.astype(in_dtype), dk.astype(in_dtype), dv.astype(in_dtype),
            None, None)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
