"""Common neural layers, pure JAX pytrees (no flax).

Conventions:
* params are nested dicts of jnp arrays; ``*_init(key, cfg)`` builds them,
  ``*_apply(params, ...)`` runs them.
* activations flow in ``cfg.dtype`` (bf16), norms/softmax/rope accumulate in
  f32, params live in ``cfg.param_dtype`` (f32 master copies).
* attention is *chunked* (online-softmax over KV blocks, flash-style in pure
  XLA) so the (L, L) score matrix never materializes in HBM -- required for
  the 32k-prefill dry-run cells to fit. Local (sliding-window) attention uses
  banded slicing: O(L * window) compute, not masked O(L^2).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]

_NEG_INF = -1e30


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key: Array, d_in: int, d_out: int, cfg: ModelConfig,
               bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), _pdtype(cfg)) / math.sqrt(d_in)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), _pdtype(cfg))
    return p


def dense_apply(p: Params, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int, cfg: ModelConfig) -> Params:
    return {"scale": jnp.ones((d,), _pdtype(cfg))}


def rmsnorm_apply(p: Params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _rope_angles(positions: Array, head_dim: int, theta: float) -> Array:
    """positions (..., L) -> angles (..., L, head_dim//2) in f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * freqs


def _mrope_angles(positions: Array, head_dim: int, theta: float,
                  sections: Tuple[int, ...]) -> Array:
    """M-RoPE (Qwen2-VL): positions (B, 3, L) carry (temporal, h, w) ids;
    the head_dim//2 frequency slots are split into per-axis sections."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (B,3,L,half)
    parts = []
    off = 0
    for axis, sec in enumerate(sections):
        parts.append(ang_all[:, axis, :, off:off + sec])
        off += sec
    return jnp.concatenate(parts, axis=-1)                       # (B, L, half)


def apply_rope(x: Array, positions: Array, theta: float,
               sections: Optional[Tuple[int, ...]] = None) -> Array:
    """x (B, L, H, hd); positions (B, L) or (B, 3, L) for M-RoPE."""
    hd = x.shape[-1]
    if sections is not None:
        ang = _mrope_angles(positions, hd, theta, sections)
    else:
        ang = _rope_angles(positions, hd, theta)
    cos = jnp.cos(ang)[:, :, None, :]                            # (B, L, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_init(key: Array, cfg: ModelConfig) -> Params:
    d, qd = cfg.d_model, cfg.n_heads * cfg.head_dim
    kvd = cfg.n_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, qd, cfg, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, kvd, cfg, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, kvd, cfg, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], qd, d, cfg),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, cfg)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, cfg)
    return p


def _qkv(p: Params, x: Array, positions: Array, cfg: ModelConfig,
         kind: str) -> Tuple[Array, Array, Array]:
    B, L, _ = x.shape
    q = dense_apply(p["wq"], x).reshape(B, L, cfg.n_heads, cfg.head_dim)
    k = dense_apply(p["wk"], x).reshape(B, L, cfg.n_kv_heads, cfg.head_dim)
    v = dense_apply(p["wv"], x).reshape(B, L, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.rms_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.rms_eps)
    theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
    q = apply_rope(q, positions, theta, cfg.mrope_sections)
    k = apply_rope(k, positions, theta, cfg.mrope_sections)
    return q, k, v


def _fit_chunk(chunk: int, length: int) -> int:
    """Largest divisor of ``length`` that is <= chunk (static shapes)."""
    chunk = min(chunk, length)
    while length % chunk:
        chunk -= 1
    return chunk


def _scores(q: Array, k: Array, softcap: float) -> Array:
    """q (B, qc, KV, G, hd), k (B, kc, KV, hd) -> (B, KV, G, qc, kc) f32."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(q.shape[-1])
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _attention_rect(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                    cfg: ModelConfig, kv_chunk: int,
                    q_chunk: int = 2048) -> Array:
    """Online-softmax over KV chunks (full causal rectangle with masking),
    processed one Q chunk at a time so the f32 accumulator is
    (B, q_chunk, H, hd), never (B, Lq, H, hd).

    q (B, Lq, H, hd); k, v (B, Lkv, KV, hd); q_pos (Lq,), k_pos (Lkv,).
    Masked positions cost FLOPs (the rectangle is computed then masked) --
    the exact-triangle variant is a Perf-iteration option, see DESIGN.md.
    """
    B, Lq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q_chunk = _fit_chunk(q_chunk, Lq)
    nq = Lq // q_chunk
    nk = k.shape[1] // kv_chunk
    ks = k.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(nk, kv_chunk)

    def per_q_chunk(args):
        q_blk, qp = args                     # (B, qc, H, hd), (qc,)
        qg = q_blk.reshape(B, q_chunk, KV, G, hd)

        def body(carry, inp):
            acc, m, l = carry
            k_blk, v_blk, kp = inp
            s = _scores(qg, k_blk, cfg.attn_logit_softcap)  # (B,KV,G,qc,kc)
            mask = kp[None, :] <= qp[:, None]               # (qc, kc)
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v_blk.dtype),
                            v_blk, preferred_element_type=jnp.float32)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.reshape(B, q_chunk, H, hd).astype(q.dtype)

    if nq == 1:
        return per_q_chunk((q, q_pos))
    qs = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qps = q_pos.reshape(nq, q_chunk)
    outs = jax.lax.map(per_q_chunk, (qs, qps))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Lq, H, hd)


def _attention_banded(q: Array, k: Array, v: Array, q_pos: Array,
                      k_pos: Array, cfg: ModelConfig, q_chunk: int) -> Array:
    """Sliding-window attention: each q chunk attends to a static-width band
    [chunk_start - window, chunk_end). O(L * window) compute."""
    B, L, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    w = cfg.window
    q_chunk = min(q_chunk, L)
    nq = L // q_chunk
    # pad keys left by w so every band slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    kpos_p = jnp.pad(k_pos + 1, (w, 0)) - 1   # padded slots get pos -1 (invalid)

    qg = q.reshape(B, nq, q_chunk, KV, G, hd)
    qpg = q_pos.reshape(nq, q_chunk)

    def per_chunk(i, q_blk, qp):
        start = i * q_chunk
        k_band = jax.lax.dynamic_slice_in_dim(kp, start, w + q_chunk, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(vp, start, w + q_chunk, axis=1)
        kp_band = jax.lax.dynamic_slice_in_dim(kpos_p, start, w + q_chunk)
        s = _scores(q_blk, k_band, cfg.attn_logit_softcap)  # (B,KV,G,qc,w+qc)
        mask = ((kp_band[None, :] <= qp[:, None]) &
                (kp_band[None, :] > qp[:, None] - w) &
                (kp_band[None, :] >= 0))
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v_band.dtype), v_band,
                        preferred_element_type=jnp.float32)
        out = pv / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.reshape(B, q_chunk, H, hd).astype(q.dtype)

    outs = jax.lax.map(
        lambda args: per_chunk(*args),
        (jnp.arange(nq), qg.transpose(1, 0, 2, 3, 4, 5), qpg))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, L, H, hd)


def _attention_decode(q: Array, k_cache: Array, v_cache: Array,
                      slot_pos: Array, cur_pos: Array, cfg: ModelConfig,
                      kind: str) -> Array:
    """Single-token decode against a cache. q (B, 1, H, hd);
    k/v_cache (B, S, KV, hd); slot_pos (B, S) absolute position held by each
    cache slot (-1 = empty); cur_pos (B,) per-sequence positions (slots may
    be at different generation depths -- continuous batching)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    s = _scores(qg, k_cache, cfg.attn_logit_softcap)  # (B,KV,G,1,S)
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos[:, None])
    if kind == "local":
        valid &= slot_pos > (cur_pos[:, None] - cfg.window)
    s = jnp.where(valid[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def _quant_kv(x: Array) -> Tuple[Array, Array]:
    """int8 KV quantization, per (batch, slot, head) absmax scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequant_kv(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


@dataclasses.dataclass(frozen=True)
class AttnCacheSpec:
    """Cache layout for one attention layer: ring buffer of ``size`` slots
    (size == window for local layers, max_len for global). With
    cfg.kv_cache_dtype == "int8" the K/V payloads are quantized (2x HBM
    saving vs bf16) with per-(slot, head) f32 scales."""
    size: int

    def init(self, batch: int, cfg: ModelConfig) -> Params:
        kvd = (batch, self.size, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_dtype == "int8":
            sc = (batch, self.size, cfg.n_kv_heads)
            return {
                "k": jnp.zeros(kvd, jnp.int8),
                "v": jnp.zeros(kvd, jnp.int8),
                "k_scale": jnp.zeros(sc, jnp.float32),
                "v_scale": jnp.zeros(sc, jnp.float32),
                "pos": jnp.full((batch, self.size), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros(kvd, _dtype(cfg)),
            "v": jnp.zeros(kvd, _dtype(cfg)),
            "pos": jnp.full((batch, self.size), -1, jnp.int32),
        }


def attention_apply(
    p: Params,
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    kind: str,                      # "attn" | "local"
    cache: Optional[Params] = None,
    q_chunk: int = 2048,
    kv_chunk: int = 4096,
) -> Tuple[Array, Optional[Params]]:
    """Modes: cache is None -> training/scoring full pass (returns y, None).
    cache given & L > 1 -> prefill (fills cache). cache given & L == 1 ->
    single-token decode (updates ring cache)."""
    B, L, _ = x.shape
    q, k, v = _qkv(p, x, positions, cfg, kind)

    int8_cache = cfg.kv_cache_dtype == "int8"
    if cache is not None and L == 1:
        cur = positions[:, -1] if positions.ndim == 2 else positions[:, 0, -1]
        S = cache["pos"].shape[1]
        slot = cur % S                                           # (B,)
        bidx = jnp.arange(B)
        new_cache = {}
        if int8_cache:
            kq, ksc = _quant_kv(k[:, 0])
            vq, vsc = _quant_kv(v[:, 0])
            kc8 = cache["k"].at[bidx, slot].set(kq)
            vc8 = cache["v"].at[bidx, slot].set(vq)
            ks8 = cache["k_scale"].at[bidx, slot].set(ksc)
            vs8 = cache["v_scale"].at[bidx, slot].set(vsc)
            k_cache = _dequant_kv(kc8, ks8, k.dtype)
            v_cache = _dequant_kv(vc8, vs8, v.dtype)
            new_cache.update({"k": kc8, "v": vc8, "k_scale": ks8,
                              "v_scale": vs8})
        else:
            k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
            v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
            new_cache.update({"k": k_cache, "v": v_cache})
        pos_arr = cache["pos"].at[bidx, slot].set(cur.astype(jnp.int32))
        y = _attention_decode(q, k_cache, v_cache, pos_arr, cur, cfg, kind)
        new_cache["pos"] = pos_arr
    else:
        q_pos = positions[0] if positions.ndim == 2 else positions[0, 0]
        kv_chunk = _fit_chunk(kv_chunk, L)
        q_chunk = _fit_chunk(q_chunk, L)
        if kind == "local":
            y = _attention_banded(q, k, v, q_pos, q_pos, cfg, q_chunk)
        elif cfg.attn_logit_softcap == 0.0:
            # flash custom-VJP path: O(B L H hd) residuals, probability
            # blocks recomputed in the backward (repro.models.flash)
            from repro.models.flash import flash_attention
            KV = k.shape[2]
            qg = q.reshape(B, L, KV, cfg.n_heads // KV, cfg.head_dim)
            y = flash_attention(qg, k, v, q_pos, q_pos, q_chunk,
                                kv_chunk).reshape(B, L, cfg.n_heads,
                                                  cfg.head_dim)
        else:
            y = _attention_rect(q, k, v, q_pos, q_pos, cfg, kv_chunk)
        new_cache = None
        if cache is not None:
            S = cache["pos"].shape[1]
            kw, vw = k, v
            scales = {}
            if int8_cache:
                kw, ksc = _quant_kv(k)
                vw, vsc = _quant_kv(v)
            if S >= L:
                kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], kw, 0, 1)
                vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], vw, 0, 1)
                if int8_cache:
                    scales = {
                        "k_scale": jax.lax.dynamic_update_slice_in_dim(
                            cache["k_scale"], ksc, 0, 1),
                        "v_scale": jax.lax.dynamic_update_slice_in_dim(
                            cache["v_scale"], vsc, 0, 1),
                    }
                prow = jnp.broadcast_to(q_pos.astype(jnp.int32)[None], (B, L))
                pc = jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], prow, 0, 1)
            else:  # ring: keep last S tokens, aligned so slot == pos % S
                shift = (L - S) % S
                kc = jnp.roll(kw[:, L - S:], shift, axis=1)
                vc = jnp.roll(vw[:, L - S:], shift, axis=1)
                if int8_cache:
                    scales = {"k_scale": jnp.roll(ksc[:, L - S:], shift, 1),
                              "v_scale": jnp.roll(vsc[:, L - S:], shift, 1)}
                prow = jnp.roll(q_pos[L - S:].astype(jnp.int32), shift)
                pc = jnp.broadcast_to(prow[None], (B, S))
            new_cache = {"k": kc, "v": vc, "pos": pc, **scales}

    y = y.reshape(B, L, cfg.n_heads * cfg.head_dim)
    return dense_apply(p["wo"], y), new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key: Array, cfg: ModelConfig, d_ff: Optional[int] = None
             ) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[1], cfg.d_model, d_ff, cfg),
        "w_out": dense_init(ks[2], d_ff, cfg.d_model, cfg),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[0], cfg.d_model, d_ff, cfg)
    return p


def mlp_apply(p: Params, x: Array, cfg: ModelConfig) -> Array:
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    if cfg.mlp_gated:
        g = act(dense_apply(p["w_gate"], x))
        return dense_apply(p["w_out"], g * dense_apply(p["w_in"], x))
    return dense_apply(p["w_out"], act(dense_apply(p["w_in"], x)))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embedding_init(key: Array, cfg: ModelConfig) -> Params:
    p = {"table": jax.random.normal(
        key, (cfg.vocab_padded, cfg.d_model), _pdtype(cfg)) * 0.02}
    return p


def embedding_apply(p: Params, tokens: Array, cfg: ModelConfig) -> Array:
    x = p["table"].astype(_dtype(cfg))[tokens]
    if cfg.emb_scale_by_sqrt_dim:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def lm_head_apply(p: Params, x: Array, cfg: ModelConfig) -> Array:
    """x (B, L, d) -> logits (B, L, vocab_padded) in f32."""
    logits = jnp.einsum("bld,vd->blv", x, p["table"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap > 0.0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
