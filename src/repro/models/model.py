"""Language model: embedding -> scan(periods of the block pattern) ->
remainder blocks -> final norm -> (tied or untied) LM head.

Execution structure (compile-time + memory critical):

* outer ``lax.scan`` over ``n_layers // period`` periods (params stacked on
  a leading dim) keeps HLO size flat in depth;
* within a period, each maximal *run* of one block kind (gemma3: 5 local +
  1 global; recurrentgemma: 2 rglru + 1 local) executes as an **inner
  scan**, so only ONE layer's parameter gradients are materialized at a
  time in the backward pass -- without this, a 6-layer period holds six
  full unsharded f32 weight-gradient sets live simultaneously (~10 GB for
  gemma3-27b) and blows the per-device HBM budget;
* the ``n_layers % period`` remainder blocks run the same way (remat'd).

Works in three modes:
  * train/score:   forward(params, tokens, positions)          -> logits
  * prefill:       forward(..., cache=init_cache(...))         -> logits, cache
  * decode:        forward with L == 1 and a cache             -> logits, cache
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.blocks import block_apply, block_cache_init, block_init
from repro.models.config import ModelConfig
from repro.models.layers import (embedding_apply, embedding_init,
                                 lm_head_apply, rmsnorm_apply, rmsnorm_init)

Array = jax.Array
Params = Dict[str, Any]


def _stack_init(key: Array, cfg: ModelConfig, kind: str, *lead: int
                ) -> Params:
    """Init a block stacked over leading dims (n_periods and/or run_len)."""
    if not lead:
        return block_init(key, cfg, kind)
    n = lead[0]
    ks = jax.random.split(key, n)
    return jax.vmap(lambda k: _stack_init(k, cfg, kind, *lead[1:]))(ks)


def init_params(key: Array, cfg: ModelConfig) -> Params:
    cfg.validate()
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    params: Params = {
        "embed": embedding_init(k_embed, cfg),
        "final_norm": rmsnorm_init(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embedding_init(k_head, cfg)

    scan_params: Params = {}
    if cfg.n_full_periods > 0:
        for r, (kind, rlen) in enumerate(cfg.runs()):
            kr = jax.random.fold_in(k_layers, r)
            if rlen == 1:
                scan_params[str(r)] = _stack_init(kr, cfg, kind,
                                                  cfg.n_full_periods)
            else:
                scan_params[str(r)] = _stack_init(kr, cfg, kind,
                                                  cfg.n_full_periods, rlen)
    rem_params: Params = {}
    for r, (kind, rlen) in enumerate(cfg.remainder_runs()):
        kr = jax.random.fold_in(k_layers, 1000 + r)
        rem_params[str(r)] = (_stack_init(kr, cfg, kind, rlen) if rlen > 1
                              else block_init(kr, cfg, kind))
    params["layers"] = {"scan": scan_params, "rem": rem_params}
    return params


def _stack_cache(one: Params, *lead: int) -> Params:
    for n in reversed(lead):
        one = jax.tree.map(
            lambda a, n=n: jnp.broadcast_to(a[None],
                                            (n,) + a.shape).copy(), one)
    return one


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    scan_cache: Params = {}
    if cfg.n_full_periods > 0:
        for r, (kind, rlen) in enumerate(cfg.runs()):
            one = block_cache_init(batch, max_len, cfg, kind)
            lead = ((cfg.n_full_periods,) if rlen == 1
                    else (cfg.n_full_periods, rlen))
            scan_cache[str(r)] = _stack_cache(one, *lead)
    rem_cache: Params = {}
    for r, (kind, rlen) in enumerate(cfg.remainder_runs()):
        one = block_cache_init(batch, max_len, cfg, kind)
        rem_cache[str(r)] = _stack_cache(one, rlen) if rlen > 1 else one
    return {"scan": scan_cache, "rem": rem_cache}


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract (ShapeDtypeStruct) cache pytree -- used by the dry run."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def _run_apply(run_params: Params, x: Array, positions: Array,
               cfg: ModelConfig, kind: str, rlen: int,
               run_cache: Optional[Params], remat: str):
    """Apply one run: a single block (rlen == 1) or an inner scan over the
    run's stacked layers (one layer's grads live at a time)."""
    if rlen == 1:
        body = block_apply
        if remat == "full":
            body = jax.checkpoint(block_apply, prevent_cse=False,
                                  static_argnums=(3, 4))
        return body(run_params, x, positions, cfg, kind, run_cache)

    def scan_body(carry, xs):
        x, aux = carry
        pp, pc = xs
        x, nc, a = block_apply(pp, x, positions, cfg, kind, pc)
        return (x, aux + a), nc

    body = scan_body
    if remat == "full":
        body = jax.checkpoint(scan_body, prevent_cse=False)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (run_params, run_cache))
    return x, new_cache, aux


def forward(
    params: Params,
    tokens: Array,
    positions: Array,
    cfg: ModelConfig,
    cache: Optional[Params] = None,
    remat: str = "none",             # "none" | "full"
    head: bool = True,               # False: return final-norm hidden state
) -> Tuple[Array, Optional[Params], Array]:
    """Returns (logits (B, L, vocab_padded) f32, new_cache | None, aux).
    With ``head=False`` the first element is the normalized hidden state
    (B, L, d) instead (the chunked-CE loss applies the head itself)."""
    x = embedding_apply(params["embed"], tokens, cfg)
    x = sharding.constrain(x, "batch", "model", None)   # sequence-parallel
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Params = {"scan": {}, "rem": {}}
    runs = cfg.runs()

    if cfg.n_full_periods > 0:
        def period_body(carry, xs):
            x, aux = carry
            pp, pc = xs
            ncs: Params = {}
            for r, (kind, rlen) in enumerate(runs):
                c_r = None if pc is None else pc.get(str(r))
                x, nc, a = _run_apply(pp[str(r)], x, positions, cfg, kind,
                                      rlen, c_r, remat)
                ncs[str(r)] = nc
                aux = aux + a
            return (x, aux), ncs

        scan_cache_in = None if cache is None else cache["scan"]
        (x, aux_total), scan_cache_out = jax.lax.scan(
            period_body, (x, aux_total),
            (params["layers"]["scan"], scan_cache_in))
        new_cache["scan"] = scan_cache_out

    for r, (kind, rlen) in enumerate(cfg.remainder_runs()):
        c_r = None if cache is None else cache["rem"].get(str(r))
        x, nc, a = _run_apply(params["layers"]["rem"][str(r)], x, positions,
                              cfg, kind, rlen, c_r, remat)
        new_cache["rem"][str(r)] = nc
        aux_total = aux_total + a

    x = rmsnorm_apply(params["final_norm"], x, cfg.rms_eps)
    if not head:
        return x, (new_cache if cache is not None else None), aux_total
    head_p = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = lm_head_apply(head_p, x, cfg)
    logits = sharding.constrain(logits, "batch", None, "model")
    return logits, (new_cache if cache is not None else None), aux_total


def make_positions(tokens: Array, cfg: ModelConfig,
                   offset: Array | int = 0) -> Array:
    """Default position ids. (B, L) for standard RoPE; (B, 3, L) with
    identical t/h/w ids for M-RoPE text-only inputs (the VLM frontend stub
    supplies real 3-axis ids for image patches)."""
    B, L = tokens.shape
    pos = jnp.arange(L, dtype=jnp.int32)[None] + offset
    pos = jnp.broadcast_to(pos, (B, L))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[:, None, :], (B, 3, L))
    return pos
