"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is *local per sequence row*: every (token, choice) gets a position
inside its expert via a per-row cumulative count, and the scatter into the
(B, E, C_row, d) dispatch buffer is vmapped over the batch dim -- so with
batch-sharded activations the scatter never crosses devices. The buffer is
then sharding-constrained to (batch, model/EP, ...), which GSPMD realizes as
the canonical expert-parallel all-to-all (dispatch) and its inverse
(combine). Tokens beyond the per-row capacity C = ceil(L * k / E * cf) are
dropped (residual passes through -- Switch/GShard semantics, accounted per
row).

EP requires E % model_axis == 0 (dbrx: 16/16). When E does not divide the
axis (granite-moe: 40 experts), the expert dim stays replicated and the
sharding rules fall back to FSDP on d_model -- correct, just not
expert-parallel (see DESIGN.md Sec. 6; EP-vs-TP is a perf-iteration knob).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array
Params = Dict[str, Any]


def moe_init(key: Array, cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    pdt = jnp.dtype(cfg.param_dtype)

    def expert_w(k, din, dout):
        return jax.random.normal(k, (E, din, dout), pdt) / math.sqrt(din)

    return {
        "router": dense_init(ks[0], d, E, cfg),
        "experts": {
            "w_gate": expert_w(ks[1], d, ff),
            "w_in": expert_w(ks[2], d, ff),
            "w_out": expert_w(ks[3], ff, d),
        },
    }


def _row_capacity(seq_len: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(seq_len * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return -(-c // 8) * 8


def moe_apply(p: Params, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """x (B, L, d) -> (y (B, L, d), aux_loss scalar f32)."""
    B, L, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _row_capacity(L, cfg)

    logits = (x @ p["router"]["w"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, L, E)
    gate, idx = jax.lax.top_k(probs, K)                          # (B, L, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    def dispatch_row(xr, idxr, gater):
        """xr (L, d); idxr (L, K); gater (L, K) -> buffer (E, C, d) plus
        combine metadata. Entirely local to one batch row; the scatter runs
        one routing choice at a time so no (L*K, d) replica of the
        activations is ever materialized."""
        eid = idxr.reshape(-1)                                   # (L*K,)
        onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                                  eid[:, None], axis=1)[:, 0]
        keep = pos < C
        pos_c = jnp.minimum(pos, C - 1)
        eid_k = eid.reshape(L, K)
        pos_k = pos_c.reshape(L, K)
        keep_k = keep.reshape(L, K)
        buf = jnp.zeros((E, C, d), xr.dtype)
        for j in range(K):
            buf = buf.at[eid_k[:, j], pos_k[:, j]].add(
                xr * keep_k[:, j, None].astype(xr.dtype))
        return buf, (eid_k, pos_k, keep_k)

    buf, meta = jax.vmap(dispatch_row)(x, idx, gate)             # (B,E,C,d)
    buf = sharding.constrain(buf, "batch", "model", None, None)  # EP a2a

    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    w = p["experts"]
    hg = act(jnp.einsum("becd,edf->becf", buf, w["w_gate"].astype(x.dtype)))
    hi = jnp.einsum("becd,edf->becf", buf, w["w_in"].astype(x.dtype))
    ho = jnp.einsum("becf,efd->becd", hg * hi, w["w_out"].astype(x.dtype))
    ho = sharding.constrain(ho, "batch", "model", None, None)

    def combine_row(hor, metar, gater):
        eid_k, pos_k, keep_k = metar
        y = jnp.zeros((L, d), hor.dtype)
        for j in range(K):
            vals = hor[eid_k[:, j], pos_k[:, j]]                  # (L, d)
            scale = (gater[:, j, None] * keep_k[:, j, None]
                     ).astype(hor.dtype)
            y = y + vals * scale
        return y

    y = jax.vmap(combine_row)(ho, meta, gate)                    # (B, L, d)
    y = sharding.constrain(y, "batch", "model", None)

    # Switch-style load-balance aux loss
    frac = jnp.mean(
        jax.nn.one_hot(idx[..., 0].reshape(-1), E, dtype=jnp.float32),
        axis=0)
    mean_prob = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(frac * mean_prob)
    return y, aux
