"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Temporal mixing: y = W_out( GeLU(W_g x) * RG-LRU(conv1d(W_x x)) ), where the
RG-LRU is the gated diagonal linear recurrence

    r_t = sigmoid(W_a xi_t + b_a)          recurrence gate
    i_t = sigmoid(W_i xi_t + b_i)          input gate
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

Training uses an associative scan over the sequence (the recurrence is
diagonal, so (a, b) pairs compose associatively); decode is a single O(1)
state update -- which is why recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_apply, dense_init

Array = jax.Array
Params = Dict[str, Any]

_C = 8.0


def rglru_init(key: Array, cfg: ModelConfig) -> Params:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    pdt = jnp.dtype(cfg.param_dtype)
    # Lambda init so that a^c spans ~(0.9, 0.999) as in Griffin
    u = jax.random.uniform(ks[0], (w,), pdt, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2.0 * _C)) - 1.0)
    return {
        "w_gate": dense_init(ks[1], d, w, cfg),      # GeLU branch
        "w_x": dense_init(ks[2], d, w, cfg),         # recurrent branch
        "conv_w": jax.random.normal(ks[3], (cfg.conv_width, w), pdt)
        / math.sqrt(cfg.conv_width),
        "conv_b": jnp.zeros((w,), pdt),
        "w_a": dense_init(ks[4], w, w, cfg),
        "w_i": dense_init(ks[5], w, w, cfg),
        "Lambda": lam,
        "w_out": dense_init(jax.random.fold_in(key, 7), w, d, cfg),
    }


def _gates(p: Params, xi: Array) -> Tuple[Array, Array]:
    """Returns (log_a (B,L,W) f32, gated_input (B,L,W) f32)."""
    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(dense_apply(p["w_a"], xi).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["w_i"], xi).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["Lambda"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xf)
    return log_a, b


def _conv_causal(x: Array, w: Array, b: Array,
                 state: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv; returns (y, new_state (B, W-1, C))."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    return y + b.astype(x.dtype), xp[:, -(W - 1):]


def rglru_apply(p: Params, u: Array, cfg: ModelConfig,
                cache: Optional[Params] = None
                ) -> Tuple[Array, Optional[Params]]:
    """u (B, L, d). Cache = {"conv": (B, W-1, lru), "h": (B, lru) f32}."""
    B_, L, _ = u.shape
    gate = jax.nn.gelu(dense_apply(p["w_gate"], u))
    xi = dense_apply(p["w_x"], u)

    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _conv_causal(xi, p["conv_w"], p["conv_b"], conv_state)

    log_a, b = _gates(p, xi)

    if cache is not None and L == 1:
        h = cache["h"] * jnp.exp(log_a[:, 0]) + b[:, 0]          # (B, W)
        y = h[:, None, :]
        new_cache = {"conv": new_conv, "h": h}
    else:
        h0 = cache["h"] if cache is not None else jnp.zeros(
            (B_, cfg.lru_width), jnp.float32)
        # prepend h0 as a pseudo-step: h_t = a_t h_{t-1} + b_t
        a_seq = jnp.exp(log_a)
        a_all = jnp.concatenate([jnp.ones((B_, 1, cfg.lru_width)), a_seq], 1)
        b_all = jnp.concatenate([h0[:, None, :], b], 1)

        def combine(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
        y = hs[:, 1:]                                            # (B, L, W)
        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv, "h": hs[:, -1]}

    out = dense_apply(p["w_out"], (y.astype(u.dtype) * gate))
    return out, new_cache


def rglru_cache_init(batch: int, cfg: ModelConfig) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width),
                          jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
