"""Mesh-aware sharding rules.

``set_mesh(mesh)`` installs a mesh for the duration of a ``with`` block;
``constrain(x, *dims)`` applies ``with_sharding_constraint`` using *logical*
dim names resolved against that mesh (no-op when no mesh is installed, so
model code runs unchanged on a single device).

Logical dims:
    "batch"  -> ("pod", "data") when the mesh has a pod axis else ("data",)
    "data"   -> FSDP/ZeRO axis
    "model"  -> tensor/expert-parallel axis
    None     -> replicated

Layouts (the beyond-paper §Perf lever):
    "tp"   (default) -- Megatron-style: TP+SP over "model", FSDP over
           "data", batch over (pod, data).
    "fsdp" -- ZeRO-3 only: no tensor parallelism; batch shards over EVERY
           axis (pod, data, model) and parameters FSDP over (data, model)
           jointly. No activation collectives at all; parameters stream
           layer-by-layer.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def _current_layout() -> str:
    return getattr(_state, "layout", "tp")


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh], layout: str = "tp"):
    prev = _current_mesh()
    prev_layout = _current_layout()
    _state.mesh = mesh
    _state.layout = layout
    try:
        yield
    finally:
        _state.mesh = prev
        _state.layout = prev_layout


def resolve(dim: Optional[str], mesh: Mesh, layout: Optional[str] = None):
    layout = layout or _current_layout()
    if dim is None:
        return None
    if dim == "batch":
        axes = ("pod",) if "pod" in mesh.axis_names else ()
        axes += ("data",)
        if layout == "fsdp":
            axes += ("model",)
        return axes
    if layout == "fsdp":
        if dim == "model":
            return None                    # no tensor parallelism
        if dim == "data":
            return ("data", "model")       # ZeRO over both axes
    return dim


def spec(*dims: Optional[str], mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or _current_mesh()
    if mesh is None:
        return P()
    return P(*[resolve(d, mesh) for d in dims])


def constrain(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """Sharding constraint by logical dim names; no-op without a mesh, and
    skips axes whose size does not divide the mesh axis."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    resolved = []
    for d, size in zip(dims, x.shape):
        r = resolve(d, mesh)
        names = (r,) if isinstance(r, str) else (r or ())
        total = 1
        for nm in names:
            total *= mesh.shape[nm]
        resolved.append(r if total > 0 and size % max(total, 1) == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def named_sharding(mesh: Mesh, *dims: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, P(*[resolve(d, mesh) for d in dims]))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

def _rule_for(path: Tuple[str, ...], shape: Tuple[int, ...]) -> Tuple:
    """Map a param path to logical dims. FSDP ("data") on one large dim, TP
    ("model") on the head/ff/vocab/expert dim."""
    name = "/".join(path)
    nd = len(shape)

    def lead(*dims):
        """Pad with None for stacked scan dims (leading extras)."""
        return (None,) * (nd - len(dims)) + tuple(dims)

    if name.endswith("/b") or "norm" in name or name.endswith("scale"):
        return (None,) * nd
    if "embed/table" in name or "lm_head/table" in name:
        return lead("model", "data")                     # vocab TP, d FSDP
    if "experts" in name:
        # (E, d, ff) or (E, ff, d)
        if "w_out" in name:
            return lead("model", None, "data")           # EP on E
        return lead("model", "data", None)
    if "router" in name:
        return lead("data", None)
    if any(s in name for s in ("wq/w", "wk/w", "wv/w", "w_gate/w", "w_in/w",
                               "in_proj/w", "w_x/w", "w_a/w", "w_i/w")):
        return lead("data", "model")                     # col-parallel
    if any(s in name for s in ("wo/w", "w_out/w", "out_proj/w")):
        return lead("model", "data")                     # row-parallel
    if "conv_w" in name:
        return lead(None, "model")
    if name.endswith("Lambda") or "A_log" in name or name.endswith("/D") \
            or "dt_bias" in name:
        return lead("model") if nd >= 1 else ()
    if nd >= 2:
        return lead("data", None)
    return (None,) * nd


def param_specs(params: Any, mesh: Mesh, layout: Optional[str] = None):
    """PartitionSpec pytree matching ``params``; dims that do not divide the
    mesh axis fall back to replicated."""
    layout = layout or _current_layout()

    def one(path, leaf):
        names = tuple(
            getattr(k, "key", getattr(k, "name", str(k))) for k in path)
        dims = _rule_for(names, leaf.shape)
        fixed = []
        for d, size in zip(dims, leaf.shape):
            r = resolve(d, mesh, layout)
            ax = (r,) if isinstance(r, str) else (r or ())
            total = 1
            for nm in ax:
                total *= mesh.shape[nm]
            fixed.append(d if size % max(total, 1) == 0 else None)
        return P(*[resolve(d, mesh, layout) for d in fixed])

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, mesh: Mesh, layout: Optional[str] = None):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, layout),
        is_leaf=lambda s: isinstance(s, P))
