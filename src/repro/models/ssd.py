"""Mamba-2 SSD (state-space duality) mixer, chunked for the MXU.

The chunked formulation (Dao & Gu 2024, Sec. 6) splits the sequence into
chunks: intra-chunk interactions are a masked (chunk x chunk) matmul -- MXU
friendly -- and inter-chunk interactions flow through a tiny (H, P, N) state
carried by a scan over chunks. Decode maintains (conv_state, ssm_state) and
costs O(1) per token -- this is why mamba2 runs the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_apply, dense_init, rmsnorm_apply

Array = jax.Array
Params = Dict[str, Any]


def _conv_dim(cfg: ModelConfig) -> int:
    return cfg.ssm_dinner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def ssd_init(key: Array, cfg: ModelConfig) -> Params:
    d, din, h = cfg.d_model, cfg.ssm_dinner, cfg.ssm_nheads
    gn = cfg.ssm_ngroups * cfg.ssm_state
    ks = jax.random.split(key, 4)
    pdt = jnp.dtype(cfg.param_dtype)
    return {
        "in_proj": dense_init(ks[0], d, 2 * din + 2 * gn + h, cfg),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, _conv_dim(cfg)),
                                    pdt) / math.sqrt(cfg.conv_width),
        "conv_b": jnp.zeros((_conv_dim(cfg),), pdt),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=pdt)),
        "D": jnp.ones((h,), pdt),
        "dt_bias": jnp.zeros((h,), pdt),
        "norm_scale": jnp.ones((din,), pdt),
        "out_proj": dense_init(ks[2], din, d, cfg),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv via shifted adds: x (B, L, C), w (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    return y + b.astype(x.dtype)


def _segsum(x: Array) -> Array:
    """x (..., c) -> (..., c, c): out[i, j] = sum_{j < k <= i} x[k], -inf
    above the diagonal."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, initial_state: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x (b, l, h, p); dt (b, l, h) (post-softplus); A (h,) negative;
    B, C (b, l, h, n) (already expanded from groups to heads).
    Returns (y (b, l, h, p), final_state (b, h, p, n)). All f32.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    nc = l // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, h, n)
    Cc = C.reshape(b, nc, chunk, h, n)

    x_dt = xc * dtc[..., None]
    dA = dtc * A                                     # (b, nc, c, h)
    dA_h = dA.transpose(0, 1, 3, 2)                  # (b, nc, h, c)
    dA_cs = jnp.cumsum(dA_h, axis=-1)                # (b, nc, h, c)

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA_h))                       # (b, nc, h, c, c)
    CB = jnp.einsum("bzchn,bzshn->bzhcs", Cc, Bc)
    y_diag = jnp.einsum("bzhcs,bzshp->bzchp", CB * L, x_dt)

    # chunk summaries -> inter-chunk recurrence
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)  # (b, nc, h, c)
    states = jnp.einsum("bzchn,bzhc,bzchp->bzhpn", Bc, decay_states, x_dt)
    chunk_decay = jnp.exp(dA_cs[..., -1])            # (b, nc, h)

    s0 = (jnp.zeros((b, h, p, n), x.dtype) if initial_state is None
          else initial_state)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                            # emit state *entering* chunk

    final, prev = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)             # (b, nc, h, p, n)

    decay_out = jnp.exp(dA_cs)                       # (b, nc, h, c)
    y_off = jnp.einsum("bzchn,bzhpn,bzhc->bzchp", Cc, prev, decay_out)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def _split_in_proj(cfg: ModelConfig, zxbcdt: Array):
    din, h = cfg.ssm_dinner, cfg.ssm_nheads
    gn = cfg.ssm_ngroups * cfg.ssm_state
    z, xBC, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * gn], axis=-1)
    return z, xBC, dt


def _expand_groups(v: Array, cfg: ModelConfig) -> Array:
    """(..., G*N) -> (..., H, N): heads within a group share B/C."""
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    v = v.reshape(v.shape[:-1] + (g, n))
    return jnp.repeat(v, h // g, axis=-2)


def ssd_apply(p: Params, u: Array, cfg: ModelConfig,
              cache: Optional[Params] = None
              ) -> Tuple[Array, Optional[Params]]:
    """Full SSD block: in_proj -> causal conv -> SSD -> gated norm ->
    out_proj. u (B, L, d). With a cache and L == 1, runs the O(1) decode
    step; with a cache and L > 1, runs chunked prefill and writes the final
    (conv, ssm) states into the cache."""
    B_, L, _ = u.shape
    h, pdim, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    din = cfg.ssm_dinner
    zxbcdt = dense_apply(p["in_proj"], u)
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is not None and L == 1:
        window = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B, W, C)
        conv_out = (jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                               p["conv_w"].astype(jnp.float32))
                    + p["conv_b"].astype(jnp.float32))
        xBC_t = jax.nn.silu(conv_out)[:, None, :]               # (B, 1, C)
        new_conv = window[:, 1:]
        x, Bv, Cv = jnp.split(
            xBC_t, [din, din + cfg.ssm_ngroups * n], axis=-1)
        x = x.reshape(B_, 1, h, pdim).astype(jnp.float32)
        Bh = _expand_groups(Bv, cfg).astype(jnp.float32)        # (B,1,H,N)
        Ch = _expand_groups(Cv, cfg).astype(jnp.float32)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))  # (B,1,H)
        dA = jnp.exp(dt[..., 0, :] * A)                           # (B,H)
        x_dt = x[:, 0] * dt[:, 0, :, None]                        # (B,H,P)
        new_state = (cache["ssm"] * dA[..., None, None]
                     + jnp.einsum("bhn,bhp->bhpn", Bh[:, 0], x_dt))
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, 0], new_state)[:, None]
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x
        new_cache = {"conv": new_conv, "ssm": new_state}
    else:
        conv = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        x, Bv, Cv = jnp.split(conv, [din, din + cfg.ssm_ngroups * n], axis=-1)
        x = x.reshape(B_, L, h, pdim).astype(jnp.float32)
        Bh = _expand_groups(Bv, cfg).astype(jnp.float32)
        Ch = _expand_groups(Cv, cfg).astype(jnp.float32)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        chunk = min(cfg.ssm_chunk, L)
        while L % chunk:
            chunk -= 1
        y, final_state = ssd_chunked(x * 1.0, dt, A, Bh, Ch, chunk)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x
        new_cache = None
        if cache is not None:
            W = cache["conv"].shape[1]
            tail = jnp.pad(xBC, ((0, 0), (max(W - L, 0), 0), (0, 0)))[:, -W:]
            new_cache = {"conv": tail, "ssm": final_state}

    y = y.reshape(B_, L, din)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm_apply({"scale": p["norm_scale"]}, y.astype(u.dtype),
                      cfg.rms_eps)
    return dense_apply(p["out_proj"], y), new_cache


def ssd_cache_init(batch: int, cfg: ModelConfig) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, _conv_dim(cfg)),
                          jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                          cfg.ssm_state), jnp.float32),
    }
