from repro.optim import adamw, compression, schedule
from repro.optim.adamw import AdamWConfig

__all__ = ["adamw", "compression", "schedule", "AdamWConfig"]
