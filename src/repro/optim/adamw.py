"""AdamW from scratch (no optax): decoupled weight decay, bias-corrected
moments, global-norm clipping. Optimizer state inherits the parameter
sharding (FSDP over ``data``), i.e. ZeRO-style sharded optimizer state."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    # params whose path contains any of these substrings skip weight decay
    no_decay: Tuple[str, ...] = ("scale", "norm", "b", "Lambda", "A_log",
                                 "D", "dt_bias", "pos")


def init(params: PyTree, keep_master: bool = False) -> Dict[str, PyTree]:
    """``keep_master=True``: mixed-precision training -- compute params are
    bf16 and the optimizer carries the f32 master copy (+ f32 moments)."""
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if keep_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: PyTree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _decay_mask(params: PyTree, cfg: AdamWConfig) -> PyTree:
    def one(path, leaf):
        name = "/".join(
            getattr(k, "key", getattr(k, "name", str(k))) for k in path)
        skip = any(s in name.split("/")[-1] or s in name
                   for s in cfg.no_decay) or leaf.ndim <= 1
        return 0.0 if skip else 1.0

    return jax.tree_util.tree_map_with_path(one, params)


def update(
    grads: PyTree,
    state: Dict[str, PyTree],
    params: PyTree,
    lr: Array,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    decay = _decay_mask(params, cfg)
    masters = state.get("master", params)

    def upd(g, m, v, p, dm):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        pf = p.astype(jnp.float32)
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * dm * pf
        return pf - lr * step_vec, m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], masters, decay)
    new_master = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                              new_master, params)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
