"""Gradient compression for cross-pod (DCN) data parallelism -- the same
communication-reduction theme as the paper, applied to the training plane.

Two schemes, both with error feedback (the residual of the lossy step is
carried to the next step, preserving convergence):

* int8 quantization: per-tensor absmax scale, 4x fewer bytes on the wire
  than f32 (2x vs bf16).
* top-k sparsification: keep the k largest-|g| entries per tensor.

``compressed_psum`` applies quantize -> psum -> dequantize so the collective
itself moves int8 -- visible in the dry-run HLO as an i8 all-reduce (the
hillclimb measures this in the collective roofline term).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def quantize_int8(g: Array) -> Tuple[Array, Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def qdq_int8(g: Array) -> Array:
    q, s = quantize_int8(g)
    return dequantize_int8(q, s)


def topk_mask(g: Array, frac: float) -> Array:
    """Keep the top-``frac`` fraction of entries by magnitude."""
    flat = jnp.abs(g.reshape(-1))
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_with_feedback(
    grads: PyTree,
    error: Optional[PyTree],
    scheme: str = "int8",
    topk_frac: float = 0.01,
) -> Tuple[PyTree, PyTree]:
    """Returns (compressed_grads, new_error). ``error`` accumulates what the
    lossy representation dropped; it is added back before compressing."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if scheme == "int8":
            comp = qdq_int8(gf)
        elif scheme == "topk":
            comp = gf * topk_mask(gf, topk_frac)
        else:
            raise ValueError(scheme)
        return comp.astype(g.dtype), gf - comp

    out = jax.tree.map(one, grads, error)
    comp = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_err


def compressed_psum(grads: PyTree, axis_name: str) -> PyTree:
    """int8-on-the-wire gradient all-reduce: quantize -> psum(int32 partial
    sums of int8 payloads) -> dequantize with psum'd scales. Call inside
    shard_map over the DP/pod axis."""

    def one(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(s, axis_name)  # shared scale approximation
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (qsum.astype(jnp.float32) * (ssum / n)).astype(g.dtype)

    return jax.tree.map(one, grads)
