from repro.roofline import hlo, report
from repro.roofline.report import RooflineReport, build_report

__all__ = ["hlo", "report", "RooflineReport", "build_report"]
