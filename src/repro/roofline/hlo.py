"""Loop-aware HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a scan of length 10 reports the same flops as length 1), and our
models run everything -- layers, microbatches, attention chunks -- under
``lax.scan``. This module therefore parses the post-partitioning HLO text
into a computation graph, extracts while-loop trip counts from their
condition computations, and accumulates:

  * dot FLOPs (matmul-dominated models; elementwise flops are reported
    separately as result-element counts),
  * per-collective link bytes with the standard algorithmic factors
      all-reduce        2 (N-1)/N x bytes
      all-gather        (N-1)/N x result bytes
      reduce-scatter    (N-1) x result bytes   (= (N-1)/N x operand)
      all-to-all        (N-1)/N x bytes
      collective-permute  bytes
  * DCN vs ICI classification: a replica group whose device ids span
    multiple pod blocks crosses the data-center network.

All quantities are per device: the post-SPMD module is the per-device
program and operand shapes are shard shapes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(.*?)\s([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    rest: str            # operands + attrs (text after "opcode(")
    raw: str             # the full line


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, str]        # op/param name -> result type string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if _COMP_RE.match(line):
            cur = Computation(_COMP_RE.match(line).group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        stripped = line.strip()
        if stripped.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, tail = m.groups()
        # split "<result type> <opcode>(<rest>"; the type may itself be a
        # tuple "(...)", but only the opcode is a word directly followed by
        # "(" -- earliest such match after a space wins
        m2 = _OPCODE_RE.match(tail)
        if not m2:
            continue
        rtype, opcode, rest = m2.groups()
        cur.ops.append(Op(name, opcode, rtype.strip(), rest, line))
        cur.symtab[name] = rtype.strip()
    return comps


_CALL_RE = re.compile(
    r"(?:to_apply|body|condition|calls|branch_computations)=\{?%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _trip_count(cond: Computation,
                comps: Optional[Dict[str, "Computation"]] = None,
                depth: int = 0) -> int:
    """Heuristic: the largest integer constant in the loop condition
    computation (jax's scan lowers to `lt(iv, constant(T))`), following
    fused/called sub-computations."""
    best = 1
    for op in cond.ops:
        for c in _CONST_RE.findall(op.raw):
            best = max(best, int(c))
        if comps is not None and depth < 3:
            for called in _CALL_RE.findall(op.raw):
                if called in comps:
                    best = max(best, _trip_count(comps[called], comps,
                                                 depth + 1))
    return best


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    """2 * batch * M * N * K from operand shapes + contracting/batch dims."""
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    if len(operands) < 2:
        return 0.0
    lhs_t = symtab.get(operands[0])
    rhs_t = symtab.get(operands[1])
    if not lhs_t or not rhs_t:
        return 0.0
    lhs = _shape_dims(lhs_t)
    rhs = _shape_dims(rhs_t)
    if not lhs or not rhs:
        return 0.0
    _, ld = lhs
    _, rd = rhs

    def dims_attr(key):
        m = re.search(key + r"=\{([\d,]*)\}", op.rest)
        if not m or not m.group(1):
            return []
        return [int(x) for x in m.group(1).split(",")]

    lc = dims_attr("lhs_contracting_dims")
    lb = dims_attr("lhs_batch_dims")
    k = 1
    for i in lc:
        if i < len(ld):
            k *= ld[i]
    b = 1
    for i in lb:
        if i < len(ld):
            b *= ld[i]
    m_dim = 1
    for i, d in enumerate(ld):
        if i not in lc and i not in lb:
            m_dim *= d
    rc = dims_attr("rhs_contracting_dims")
    rb = dims_attr("rhs_batch_dims")
    n_dim = 1
    for i, d in enumerate(rd):
        if i not in rc and i not in rb:
            n_dim *= d
    return 2.0 * b * m_dim * n_dim * k


def _group_size_and_span(op: Op, pod_block: Optional[int]
                         ) -> Tuple[int, bool]:
    """(replica group size, crosses_pod). ``pod_block`` = devices per pod."""
    m = _GROUPS_BRACE_RE.search(op.rest)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        crosses = (pod_block is not None and
                   len({i // pod_block for i in ids}) > 1)
        return max(len(ids), 1), crosses
    m = _GROUPS_IOTA_RE.search(op.rest)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        # iota order: contiguous ids in a group unless a transpose follows;
        # conservative: crosses pod iff the group span exceeds the pod block
        crosses = (pod_block is not None and group_size > pod_block)
        return max(group_size, 1), crosses
    return 1, False


@dataclasses.dataclass
class Analysis:
    dot_flops: float = 0.0
    elementwise_flops: float = 0.0
    result_bytes: float = 0.0           # sum of op result buffer bytes
    ici_collective_bytes: float = 0.0
    dcn_collective_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Analysis", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.elementwise_flops += other.elementwise_flops * mult
        self.result_bytes += other.result_bytes * mult
        self.ici_collective_bytes += other.ici_collective_bytes * mult
        self.dcn_collective_bytes += other.dcn_collective_bytes * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (
                self.collective_counts.get(k, 0.0) + v * mult)
        for k, v in other.collective_bytes_by_kind.items():
            self.collective_bytes_by_kind[k] = (
                self.collective_bytes_by_kind.get(k, 0.0) + v * mult)


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exp",
    "tanh", "negate", "abs", "power", "rsqrt", "sqrt", "log", "select",
    "compare", "and", "or", "convert", "floor", "clamp", "sign",
}

_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')


def _collective_link(op: Op, pod_block: Optional[int]
                     ) -> Optional[Tuple[str, float, float, bool]]:
    """(kind, link_bytes, result_bytes, crosses_pod) for a collective op
    (including async ``*-start`` halves), else ``None``. The link factors
    are the standard algorithmic ones from the module docstring."""
    if not (op.opcode in COLLECTIVES
            or (op.opcode.endswith("-start")
                and op.opcode[:-6] in COLLECTIVES)):
        return None
    kind = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
    rb = _shape_bytes(op.result_type)
    if op.opcode.endswith("-start"):
        # async result tuples carry (operand, result[, ...]): use the
        # result buffer only
        shapes = _SHAPE_RE.findall(op.result_type)
        if len(shapes) >= 2:
            dtype, dims = shapes[1]
            rb = _DTYPE_BYTES.get(dtype, 4)
            for d in dims.split(","):
                if d:
                    rb *= int(d)
    n, crosses = _group_size_and_span(op, pod_block)
    if kind == "all-reduce":
        link = 2.0 * (n - 1) / max(n, 1) * rb
    elif kind == "all-gather":
        link = (n - 1) / max(n, 1) * rb
    elif kind == "reduce-scatter":
        link = (n - 1) * rb
    elif kind in ("all-to-all", "ragged-all-to-all"):
        link = (n - 1) / max(n, 1) * rb
    else:  # collective-permute
        link = rb
    return kind, link, float(rb), crosses


def _op_phase(op: Op, phases: Tuple[str, ...]) -> Optional[str]:
    """The phase scope segment of an op's ``metadata={op_name="..."}``.

    ``jax.named_scope("round1")`` survives jit+compile as a ``/round1/``
    path segment in the op_name of every op traced under it -- including
    the ``ppermute``s inside a ``fori_loop`` while-body -- which is what
    makes per-phase collective attribution possible on compiled HLO."""
    m = _OP_NAME_RE.search(op.raw)
    if not m:
        return None
    segs = m.group(1).split("/")
    for p in phases:
        if p in segs:
            return p
    return None


def analyze(hlo: str, pod_block: Optional[int] = None,
            entry: Optional[str] = None) -> Analysis:
    comps = parse_computations(hlo)
    if not comps:
        return Analysis()
    entry_name = entry
    if entry_name is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry_name = m.group(1) if m else next(iter(comps))
    cache: Dict[str, Analysis] = {}

    def visit(name: str, depth: int = 0) -> Analysis:
        if name in cache:
            return cache[name]
        out = Analysis()
        comp = comps.get(name)
        if comp is None or depth > 60:
            return out
        cache[name] = out  # provisional (cycles cannot occur in HLO)
        for op in comp.ops:
            rb = _shape_bytes(op.result_type)
            if op.opcode == "while":
                called = _CALL_RE.findall(op.rest)
                body = None
                cond = None
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                body = mb.group(1) if mb else (called[0] if called else None)
                cond = mc.group(1) if mc else None
                trips = (_trip_count(comps[cond], comps)
                         if cond in comps else 1)
                if body:
                    out.add(visit(body, depth + 1), mult=trips)
            elif op.opcode in ("fusion", "call", "conditional", "map",
                               "reduce", "reduce-window", "sort", "scatter",
                               "select-and-scatter", "custom-call",
                               "async-start"):
                for called in _CALL_RE.findall(op.rest):
                    out.add(visit(called, depth + 1))
                out.result_bytes += rb
                if op.opcode == "reduce":
                    out.elementwise_flops += rb / 4.0
            elif op.opcode == "dot":
                out.dot_flops += _dot_flops(op, comp.symtab)
                out.result_bytes += rb
            elif _collective_link(op, pod_block) is not None:
                kind, link, rb, crosses = _collective_link(op, pod_block)
                if crosses:
                    out.dcn_collective_bytes += link
                else:
                    out.ici_collective_bytes += link
                out.collective_counts[kind] = (
                    out.collective_counts.get(kind, 0.0) + 1)
                out.collective_bytes_by_kind[kind] = (
                    out.collective_bytes_by_kind.get(kind, 0.0) + link)
                out.result_bytes += rb
            else:
                if op.opcode in _ELEMENTWISE:
                    out.elementwise_flops += rb / 4.0
                out.result_bytes += rb
        return out

    res = visit(entry_name)
    cache.pop(entry_name, None)
    return res


def collective_phase_analysis(
    hlo: str,
    phases: Tuple[str, ...] = ("round1", "round2"),
    pod_block: Optional[int] = None,
    entry: Optional[str] = None,
) -> Dict[str, Analysis]:
    """Per-phase collective ledger: loop-aware collective op counts and
    link bytes, attributed to the ``jax.named_scope`` phase each collective
    was traced under (``_op_phase``). Collectives outside every named phase
    land in ``"other"``. Only the collective fields of each
    :class:`Analysis` are populated.

    Counts are *sequential issue* counts: a ``ppermute`` inside a
    ``fori_loop`` while-body counts once per trip, so
    ``collective_counts["collective-permute"]`` of a phase is exactly the
    hop depth of its ring/torus schedule -- the measured counterpart of
    :func:`repro.core.message_passing.collective_hops`, which
    ``bench_collectives`` cross-checks per mode.
    """
    comps = parse_computations(hlo)
    out = {p: Analysis() for p in (*phases, "other")}
    if not comps:
        return out
    entry_name = entry
    if entry_name is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry_name = m.group(1) if m else next(iter(comps))
    cache: Dict[str, Dict[str, Analysis]] = {}

    def merge(dst: Dict[str, Analysis], src: Dict[str, Analysis],
              mult: float = 1.0) -> None:
        for p, a in src.items():
            dst.setdefault(p, Analysis()).add(a, mult)

    def visit(name: str, depth: int = 0) -> Dict[str, Analysis]:
        if name in cache:
            return cache[name]
        acc: Dict[str, Analysis] = {}
        comp = comps.get(name)
        if comp is None or depth > 60:
            return acc
        cache[name] = acc  # provisional (cycles cannot occur in HLO)
        for op in comp.ops:
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                called = _CALL_RE.findall(op.rest)
                body = mb.group(1) if mb else (called[0] if called else None)
                cond = mc.group(1) if mc else None
                trips = (_trip_count(comps[cond], comps)
                         if cond in comps else 1)
                if body:
                    merge(acc, visit(body, depth + 1), mult=trips)
                continue
            link = _collective_link(op, pod_block)
            if link is not None:
                kind, bytes_, rb, crosses = link
                phase = _op_phase(op, phases) or "other"
                a = acc.setdefault(phase, Analysis())
                a.collective_counts[kind] = (
                    a.collective_counts.get(kind, 0.0) + 1)
                a.collective_bytes_by_kind[kind] = (
                    a.collective_bytes_by_kind.get(kind, 0.0) + bytes_)
                if crosses:
                    a.dcn_collective_bytes += bytes_
                else:
                    a.ici_collective_bytes += bytes_
                continue
            for called in _CALL_RE.findall(op.rest):
                if called in comps:
                    merge(acc, visit(called, depth + 1))
        return acc

    merge(out, visit(entry_name))
    cache.pop(entry_name, None)
    return out
