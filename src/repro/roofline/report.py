"""Three-term roofline from a compiled dry-run artifact.

Hardware model (TPU v5e-class, per chip):
    peak bf16 compute   197 TFLOP/s
    HBM bandwidth       819 GB/s
    ICI link bandwidth  ~50 GB/s  (DCN between pods ~25 GB/s assumed)

    compute term   = dot_FLOPs_per_device / 197e12
    memory term    = HBM_bytes_per_device / 819e9
    collective term = ICI link bytes / 50e9 + DCN bytes / 25e9

FLOPs and collective bytes come from the loop-aware HLO parse
(repro.roofline.hlo); the memory term uses min(parsed result-bytes upper
bound, analytic traffic) -- parsed bytes ignore fusion VMEM residency, the
analytic term is the param+activation traffic floor; both are reported.

MODEL_FLOPS = 6 * N(active) * tokens for training, 2 * N(active) * tokens
for inference; the ratio MODEL_FLOPS / HLO_FLOPs shows how much compiled
compute is "useful" (remat and masked-attention waste push it down).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.models.config import ModelConfig
from repro.roofline import hlo as hlo_mod

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9
HBM_PER_CHIP = 16e9


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # parsed, per device
    hlo_dot_flops: float
    hlo_elementwise_flops: float
    hlo_result_bytes: float
    ici_bytes: float
    dcn_bytes: float
    collective_counts: Dict[str, float]
    collective_bytes_by_kind: Dict[str, float]
    # XLA-reported
    xla_flops: float
    xla_bytes: float
    peak_memory_bytes: float
    # analytic
    model_flops_total: float
    analytic_hbm_bytes: float
    # terms (seconds)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flop_ratio: float = 0.0
    roofline_fraction: float = 0.0

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.hlo_dot_flops / PEAK_FLOPS
        mem_bytes = min(self.hlo_result_bytes, self.analytic_hbm_bytes) \
            if self.analytic_hbm_bytes > 0 else self.hlo_result_bytes
        self.memory_s = mem_bytes / HBM_BW
        self.collective_s = self.ici_bytes / ICI_BW + self.dcn_bytes / DCN_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        per_dev_model = self.model_flops_total / max(self.n_devices, 1)
        self.useful_flop_ratio = (per_dev_model
                                  / max(self.hlo_dot_flops, 1.0))
        # fraction of the compute roofline the dominant-term-limited step
        # achieves: useful flops / (peak * step_time_lower_bound)
        step_t = max(terms.values())
        self.roofline_fraction = (per_dev_model / PEAK_FLOPS) / max(step_t,
                                                                    1e-30)
        return self

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},"
                f"{self.compute_s:.4e},{self.memory_s:.4e},"
                f"{self.collective_s:.4e},{self.bottleneck},"
                f"{self.useful_flop_ratio:.3f},{self.roofline_fraction:.3f}")


def model_flops(cfg: ModelConfig, kind: str, seq_len: int,
                global_batch: int) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


def analytic_hbm_bytes(cfg: ModelConfig, kind: str, seq_len: int,
                       global_batch: int, n_devices: int,
                       microbatches: int = 1) -> float:
    """Per-device HBM traffic floor: parameters read (+ optimizer state
    read/write for training) once per step plus KV/state cache traffic for
    decode. Activations are assumed VMEM/fusion resident at the floor."""
    n = cfg.param_count()
    if kind == "train":
        # fwd reads params (bf16 cast) per microbatch; grads + adam m,v f32
        param_traffic = (2.0 * n * microbatches      # fwd+bwd reads, bf16
                         + 4.0 * n * 4               # grad w + m/v rw f32
                         )
        return param_traffic / n_devices
    if kind == "prefill":
        return 2.0 * n / n_devices
    # decode: params once + full KV/state cache read per token
    cache = 0.0
    kinds = (list(cfg.pattern) * cfg.n_full_periods
             + list(cfg.remainder_kinds))
    for k in kinds:
        if k == "attn":
            cache += (2 * global_batch * seq_len * cfg.n_kv_heads
                      * cfg.head_dim * 2)
        elif k == "local":
            cache += (2 * global_batch * min(cfg.window, seq_len)
                      * cfg.n_kv_heads * cfg.head_dim * 2)
        elif k == "ssd":
            cache += (global_batch * cfg.ssm_nheads * cfg.ssm_headdim
                      * cfg.ssm_state * 4)
        elif k == "rglru":
            cache += global_batch * cfg.lru_width * 4
    return (2.0 * cfg.active_param_count() + cache) / n_devices


def build_report(arch: str, shape_name: str, mesh_name: str, cfg: ModelConfig,
                 kind: str, seq_len: int, global_batch: int, n_devices: int,
                 hlo_text: str, xla_cost: Optional[Dict],
                 peak_memory: float, pod_block: Optional[int],
                 microbatches: int = 1) -> RooflineReport:
    ana = hlo_mod.analyze(hlo_text, pod_block=pod_block)
    rep = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, n_devices=n_devices,
        hlo_dot_flops=ana.dot_flops,
        hlo_elementwise_flops=ana.elementwise_flops,
        hlo_result_bytes=ana.result_bytes,
        ici_bytes=ana.ici_collective_bytes,
        dcn_bytes=ana.dcn_collective_bytes,
        collective_counts=ana.collective_counts,
        collective_bytes_by_kind=ana.collective_bytes_by_kind,
        xla_flops=float((xla_cost or {}).get("flops", 0.0)),
        xla_bytes=float((xla_cost or {}).get("bytes accessed", 0.0)),
        peak_memory_bytes=peak_memory,
        model_flops_total=model_flops(cfg, kind, seq_len, global_batch),
        analytic_hbm_bytes=analytic_hbm_bytes(cfg, kind, seq_len,
                                              global_batch, n_devices,
                                              microbatches),
    )
    return rep.finalize()
