"""Serving layer: the LM slot engine (`engine`) and the multi-tenant
coreset-query serving engine (`cluster`, DESIGN.md Sec. 13)."""

from repro.serve.cluster import (ClusterServeEngine, EngineStats,
                                 QueryTicket, StaticCenters)
from repro.serve.engine import Engine, Request, generate, make_serve_steps

__all__ = [
    "ClusterServeEngine", "EngineStats", "QueryTicket", "StaticCenters",
    "Engine", "Request", "generate", "make_serve_steps",
]
