from repro.serve.engine import Engine, Request, generate, make_serve_steps

__all__ = ["Engine", "Request", "generate", "make_serve_steps"]
