"""Multi-tenant coreset-query serving engine (DESIGN.md Sec. 13).

The paper's deployment story makes nearest-center *queries* the hot path: a
small coreset summary stands in for the full data, so a serving tier pays
for assignment dispatches, not solves. :class:`ClusterServeEngine` serves
many concurrent (stream, k, model) tenants by fusing their query traffic
into single device dispatches -- the slot-machinery idea of
:class:`repro.serve.engine.Engine` (admit requests, batch them into one
jit call per step, free capacity as they finish) re-built around the
stacked-center assignment primitive
:func:`repro.core.backend.query_assignments_batched`:

* **admission queue + continuous batching**: ``enqueue(tenant, points)``
  is non-blocking and returns a :class:`QueryTicket`; each ``step()``
  drains the queue, splits oversized batches into ``max_bucket`` chunks
  (:func:`repro.kernels.ops.chunk_queries`), and buckets chunks by
  ``(d, k-bucket, padded-size, objective)`` so arbitrary ragged traffic
  assembles into full stacked batches over a *bounded* set of compiled
  specializations (``compiled_shapes`` records the set).
* **stacked-center dispatch**: each assembled group stacks up to
  ``max_group`` tenants' centers into one ``(T, k_pad, d)`` buffer with a
  live-row mask and launches ONE fused kernel for all of them (the Pallas
  ``distance_argmin_batched`` grid on TPU) instead of T per-tenant calls.
* **per-tenant staleness SLOs**: center freshness is the tenant source's
  policy (e.g. :class:`repro.stream.service.ClusterQueryService`'s
  staleness bound); the engine schedules at most ``refresh_budget``
  re-solves per step, most-stale-first, so one tenant's center re-solve
  never blocks another tenant's query path -- tenants whose refresh is
  deferred keep serving their cached centers (bounded extra staleness),
  and only a tenant that has *never* solved holds its queries to a later
  step.

A center source is any object with ``cached_centers() -> (k, d) | None``,
``is_stale() -> bool`` and ``refresh() -> (k, d)`` (optionally
``staleness() -> float`` for the scheduling order);
:class:`StaticCenters` adapts a fixed center array and
``ClusterQueryService`` conforms directly (single-tenant serving delegates
here -- see ``stream/service.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import objective as objective_mod
from repro.kernels.ops import query_bucket

Array = jax.Array


class StaticCenters:
    """Minimal center source: a fixed center set, never stale."""

    def __init__(self, centers):
        self._centers = jnp.asarray(centers, jnp.float32)

    def cached_centers(self) -> Array:
        return self._centers

    def is_stale(self) -> bool:
        return False

    def refresh(self) -> Array:
        return self._centers


@dataclasses.dataclass(slots=True)
class QueryTicket:
    """Handle for one enqueued query batch. ``assign`` / ``dist`` fill in
    as the engine's steps serve the batch's chunks (``None`` until the
    first chunk lands -- a ticket served whole by one dispatch gets
    zero-copy views of the fused result); ``done`` flips once every row is
    written. ``n_padded`` counts the padding rows the engine shipped on
    this ticket's behalf (the bucket/assembly overhead)."""

    tenant_id: int
    n: int
    assign: np.ndarray = dataclasses.field(default=None, repr=False)
    dist: np.ndarray = dataclasses.field(default=None, repr=False)
    n_padded: int = 0
    _left: int = 0

    @property
    def done(self) -> bool:
        return self._left == 0


@dataclasses.dataclass
class EngineStats:
    """Engine-level serving counters (the benchmark surface)."""

    n_queries: int = 0          # real query rows served
    n_padded: int = 0           # padding rows shipped to fill buckets
    n_tickets: int = 0
    n_steps: int = 0
    n_dispatches: int = 0       # fused device dispatches issued
    n_tenant_dispatches: int = 0  # tenant-chunks served (serial equivalent)
    n_refreshes: int = 0        # center re-solves run by the step loop
    n_deferred_refreshes: int = 0  # stale tenants served cached centers
    refresh_s: float = 0.0
    assign_s: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


class _Tenant:
    """Internal per-tenant record: source + pending work + cached host-side
    padded centers (invalidated by object identity of the source's cached
    array, so an engine-run or out-of-band refresh both re-stage)."""

    __slots__ = ("tid", "k", "d", "objective", "source", "pending",
                 "k_bucket", "stage_epoch", "_staged_from", "_centers_np")

    def __init__(self, tid: int, k: int, d: int, objective: str, source):
        self.tid = tid
        self.k = int(k)
        self.d = int(d)
        self.objective = objective
        self.source = source
        self.pending: List[Tuple[QueryTicket, np.ndarray]] = []
        self.k_bucket = max(8, 1 << (self.k - 1).bit_length())
        self.stage_epoch = 0      # bumps on every re-stage (cache key)
        self._staged_from = None
        self._centers_np: Optional[np.ndarray] = None

    def staged_centers(self) -> Optional[np.ndarray]:
        """Host-staged ``(k_bucket, d)`` centers (rows >= k are dead and
        masked at dispatch); ``None`` until the source first solves."""
        cur = self.source.cached_centers()
        if cur is None:
            return None
        if cur is not self._staged_from:
            c = np.zeros((self.k_bucket, self.d), np.float32)
            c[:self.k] = np.asarray(cur, np.float32)
            self._staged_from = cur
            self._centers_np = c
            self.stage_epoch += 1
        return self._centers_np


class ClusterServeEngine:
    """Continuous-batching serving engine over stacked-center dispatches.

    ``max_bucket`` caps the per-chunk padded query rows (larger enqueues
    split), ``max_group`` caps tenants per fused dispatch, and
    ``refresh_budget`` caps center re-solves per step (``None`` =
    unbounded). The tenant-count axis of each dispatch is padded to a
    power of two as well, so the compiled-specialization set stays bounded
    by O(log max_group * log max_bucket * #distinct (k_bucket, d)) under
    any traffic pattern."""

    def __init__(self, backend: backend_mod.BackendLike = None,
                 min_bucket: int = 8, max_bucket: int = 1024,
                 max_group: int = 256,
                 refresh_budget: Optional[int] = None):
        if max_bucket < min_bucket:
            raise ValueError(f"max_bucket {max_bucket} < min_bucket "
                             f"{min_bucket}")
        self.backend = backend_mod.resolve_name(backend)
        self.min_bucket = int(min_bucket)
        self.max_bucket = int(max_bucket)
        self.max_group = int(max_group)
        self.refresh_budget = refresh_budget
        self.stats = EngineStats()
        self.compiled_shapes: set = set()   # (T_pad, bucket, k_pad, d, obj)
        self._tenants: Dict[int, _Tenant] = {}
        self._next_tid = 0
        self._bucket_cache: Dict[int, int] = {}
        # steady-state traffic re-assembles the same tenant composition
        # every step: cache the stacked (centers, mask) device buffers per
        # composition, invalidated by the tenants' stage epochs
        self._center_cache: Dict[tuple, tuple] = {}

    # -- tenant admission ----------------------------------------------------

    def add_tenant(self, source, k: int, d: int,
                   objective: objective_mod.ObjectiveLike = "kmeans",
                   tenant_id: Optional[int] = None) -> int:
        """Register a center source serving ``k`` centers in R^``d``.
        ``objective`` is any registered objective (name or instance; unknown
        names raise here, before any traffic) -- its *canonical* name rides
        in the bucket/grouping keys and picks the query-distance metric.
        Returns the tenant id (auto-assigned when not given)."""
        objective = objective_mod.resolve_name(objective)
        if tenant_id is None:
            while self._next_tid in self._tenants:
                self._next_tid += 1
            tenant_id = self._next_tid
        tenant_id = int(tenant_id)
        if tenant_id in self._tenants:
            raise ValueError(f"tenant {tenant_id} already registered")
        if k < 1 or d < 1:
            raise ValueError(f"need k >= 1 and d >= 1, got k={k} d={d}")
        for attr in ("cached_centers", "is_stale", "refresh"):
            if not callable(getattr(source, attr, None)):
                raise TypeError(f"center source must provide {attr}()")
        self._tenants[tenant_id] = _Tenant(tenant_id, k, d, objective,
                                           source)
        return tenant_id

    def tenant_ids(self) -> Tuple[int, ...]:
        return tuple(self._tenants)

    # -- admission queue -----------------------------------------------------

    def enqueue(self, tenant_id: int, points) -> QueryTicket:
        """Queue a ``(n, d)`` query batch for a tenant (non-blocking). The
        returned ticket fills in as subsequent :meth:`step` calls serve it;
        an empty batch completes immediately."""
        t = self._tenants.get(int(tenant_id))
        if t is None:
            raise KeyError(f"unknown tenant {tenant_id}")
        q = np.asarray(points, np.float32)
        if q.ndim != 2 or q.shape[1] != t.d:
            raise ValueError(f"expected (n, {t.d}) query points for tenant "
                             f"{tenant_id}, got shape {q.shape}")
        n = q.shape[0]
        # result buffers stay lazy: a single-chunk ticket gets zero-copy
        # views of the fused dispatch output, multi-chunk tickets allocate
        # at first scatter
        ticket = QueryTicket(tenant_id=t.tid, n=n, _left=n)
        self.stats.n_tickets += 1
        if n > 0:
            t.pending.append((ticket, q))
        else:
            ticket.assign = np.zeros((0,), np.int32)
            ticket.dist = np.zeros((0,), np.float32)
        return ticket

    def pending_queries(self) -> int:
        """Query rows currently admitted but not yet served."""
        return sum(q.shape[0] for t in self._tenants.values()
                   for _, q in t.pending)

    # -- step loop -----------------------------------------------------------

    def _refresh_phase(self, budget: Optional[int]) -> None:
        """Budgeted center refresh across tenants with queued work:
        never-solved tenants first (they cannot serve at all), then
        most-stale-first. Deferred tenants keep serving cached centers."""
        need = []
        for t in self._tenants.values():
            if not t.pending:
                continue
            uninit = t.source.cached_centers() is None
            if uninit or t.source.is_stale():
                stale_fn = getattr(t.source, "staleness", None)
                s = float(stale_fn()) if callable(stale_fn) else 0.0
                need.append((not uninit, -s, t))
        if not need:
            return
        need.sort(key=lambda x: x[:2])
        t0 = time.perf_counter()
        n = len(need) if budget is None else min(budget, len(need))
        for _, _, t in need[:n]:
            t.source.refresh()
            self.stats.n_refreshes += 1
        self.stats.n_deferred_refreshes += len(need) - n
        self.stats.refresh_s += time.perf_counter() - t0

    def step(self, refresh_budget: Optional[int] = -1) -> int:
        """Run one serving step: budgeted refresh phase, then assemble and
        launch fused dispatches for everything serveable in the queue.
        Returns the number of query rows served; an empty queue is a
        complete no-op (no refresh, no dispatch, no compilation)."""
        if not any(t.pending for t in self._tenants.values()):
            return 0
        self.stats.n_steps += 1
        self._refresh_phase(self.refresh_budget if refresh_budget == -1
                            else refresh_budget)

        # assembly: tenant-chunks bucketed by (d, k_bucket, padded-size,
        # objective); a tenant whose source has never solved stays queued
        groups: Dict[tuple, list] = {}
        buckets = self._bucket_cache
        for t in self._tenants.values():
            if not t.pending or t.staged_centers() is None:
                continue
            work, t.pending = t.pending, []
            for ticket, q in work:
                n = q.shape[0]
                if n <= self.max_bucket:        # common case: one chunk
                    b = buckets.get(n)
                    if b is None:
                        b = buckets[n] = query_bucket(n, self.min_bucket,
                                                      self.max_bucket)
                    groups.setdefault((t.d, t.k_bucket, b, t.objective),
                                      []).append((t, ticket, 0, q))
                    continue
                off = 0
                while off < n:
                    part = q[off:off + self.max_bucket]
                    m = part.shape[0]
                    b = buckets.get(m)
                    if b is None:
                        b = buckets[m] = query_bucket(m, self.min_bucket,
                                                      self.max_bucket)
                    key = (t.d, t.k_bucket, b, t.objective)
                    groups.setdefault(key, []).append(
                        (t, ticket, off, part))
                    off += m

        served = 0
        t0 = time.perf_counter()
        for (d, kb, b, objective), items in sorted(
                groups.items(), key=lambda kv: kv[0][:3]):
            for s0 in range(0, len(items), self.max_group):
                served += self._dispatch(items[s0:s0 + self.max_group],
                                         d, kb, b, objective)
        self.stats.assign_s += time.perf_counter() - t0
        return served

    def _staged_group_centers(self, items: list, Tp: int, kb: int, d: int):
        """Stacked ``(Tp, kb, d)`` centers + live mask for one dispatch
        group, as device arrays cached per tenant composition: steady
        traffic re-assembles the same group every step, so re-stacking T
        center sets (and re-transferring them) is paid only when a
        tenant's centers actually change (its ``stage_epoch`` bumps)."""
        sig = tuple((t.tid, t.stage_epoch) for t, _, _, _ in items)
        cached = self._center_cache.get((Tp, kb, d))
        if cached is not None and cached[0] == sig:
            return cached[1], cached[2]
        c = np.zeros((Tp, kb, d), np.float32)
        mask = np.zeros((Tp, kb), bool)
        for i, (t, _, _, _) in enumerate(items):
            c[i] = t.staged_centers()
            mask[i, :t.k] = True
        cj, mj = jnp.asarray(c), jnp.asarray(mask)
        self._center_cache[(Tp, kb, d)] = (sig, cj, mj)
        return cj, mj

    def _dispatch(self, items: list, d: int, kb: int, b: int,
                  objective: str) -> int:
        """Launch one fused stacked-center dispatch for up to ``max_group``
        same-bucket tenant-chunks and scatter results into tickets."""
        T = len(items)
        Tp = 1 << (T - 1).bit_length() if T > 1 else 1
        if T == Tp and all(p.shape[0] == b for _, _, _, p in items):
            # full buckets: one vectorized stack, no padding rows
            q = np.stack([p for _, _, _, p in items])
        else:
            q = np.zeros((Tp, b, d), np.float32)
            for i, (_, _, _, part) in enumerate(items):
                q[i, :part.shape[0]] = part
        # padding tenant rows keep mask all-False: every center row becomes
        # the sentinel, the reduction stays finite, results are discarded
        cj, mj = self._staged_group_centers(items, Tp, kb, d)
        assign, dist = backend_mod.query_assignments_batched(
            jnp.asarray(q), cj, mj,
            objective=objective, backend=self.backend)
        assign = np.asarray(assign)
        dist = np.asarray(dist)
        self.stats.n_dispatches += 1
        self.stats.n_tenant_dispatches += T
        self.compiled_shapes.add((Tp, b, kb, d, objective))
        served = 0
        for i, (_, ticket, off, part) in enumerate(items):
            n = part.shape[0]
            if off == 0 and n == ticket.n:
                # ticket served whole by this dispatch: alias the result
                # rows instead of copying them out
                ticket.assign = assign[i, :n]
                ticket.dist = dist[i, :n]
            else:
                if ticket.assign is None:
                    ticket.assign = np.empty((ticket.n,), np.int32)
                    ticket.dist = np.empty((ticket.n,), np.float32)
                ticket.assign[off:off + n] = assign[i, :n]
                ticket.dist[off:off + n] = dist[i, :n]
            ticket.n_padded += b - n
            ticket._left -= n
            served += n
        self.stats.n_queries += served
        self.stats.n_padded += Tp * b - served
        return served

    def run(self, max_steps: int = 10_000) -> int:
        """Step until the admission queue drains; returns rows served.
        Raises if the queue cannot make progress within ``max_steps``
        (e.g. a refresh budget of 0 against a never-solved tenant)."""
        total = 0
        for _ in range(max_steps):
            if not any(t.pending for t in self._tenants.values()):
                return total
            r0 = self.stats.n_refreshes
            s = self.step()
            total += s
            if s == 0 and self.stats.n_refreshes == r0:
                raise RuntimeError(
                    "serve queue cannot make progress (refresh budget 0 "
                    "against a never-solved tenant?)")
        raise RuntimeError(f"serve queue failed to drain in {max_steps} "
                           f"steps")
