"""Serving: prefill + decode steps and a slot-based batched engine.

``make_serve_steps(cfg, batch, max_len)`` builds the two jit-able pure
functions the dry run lowers:

  * ``prefill_step(params, tokens)            -> (last_logits, cache)``
  * ``decode_step(params, token, pos, cache)  -> (logits, cache)``

``Engine`` adds continuous-batching-lite on top: a fixed number of slots,
each with its own sequence; finished sequences free their slot for the next
request. Single-host demo quality -- the production serving story is the
decode_step sharded over the mesh (KV cache length-sharded over ``model``,
batch over ``data``; see DESIGN.md Sec. 6).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward, init_cache, make_positions
from repro.models.config import ModelConfig

Array = jax.Array


def make_serve_steps(cfg: ModelConfig, max_len: int):
    def prefill_step(params, tokens):
        B, L = tokens.shape
        cache = init_cache(cfg, B, max_len)
        pos = make_positions(tokens, cfg)
        logits, cache, _ = forward(params, tokens, pos, cfg, cache=cache)
        return logits[:, -1], cache

    def decode_step(params, token, pos_scalar, cache):
        """token (B, 1); pos_scalar () current position of the new token."""
        pos = make_positions(token, cfg, offset=pos_scalar)
        logits, cache, _ = forward(params, token, pos, cfg, cache=cache)
        return logits[:, 0], cache

    return prefill_step, decode_step


def sample_token(key: Array, logits: Array, temperature: float = 0.0,
                 vocab_size: Optional[int] = None) -> Array:
    if vocab_size is not None and logits.shape[-1] != vocab_size:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < vocab_size,
                           logits, -1e30)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(
    params,
    cfg: ModelConfig,
    prompt: Array,               # (B, Lp)
    n_new: int,
    temperature: float = 0.0,
    key: Optional[Array] = None,
) -> Array:
    """Greedy/temperature generation; returns (B, Lp + n_new)."""
    B, Lp = prompt.shape
    max_len = Lp + n_new
    prefill_step, decode_step = make_serve_steps(cfg, max_len)
    prefill = jax.jit(prefill_step)
    decode = jax.jit(decode_step)
    key = key if key is not None else jax.random.PRNGKey(0)

    logits, cache = prefill(params, prompt)
    toks = [prompt]
    tok = sample_token(key, logits, temperature, cfg.vocab_size)[:, None]
    for t in range(n_new - 1):
        toks.append(tok)
        key, kt = jax.random.split(key)
        logits, cache = decode(params, tok, jnp.asarray(Lp + t), cache)
        tok = sample_token(kt, logits, temperature, cfg.vocab_size)[:, None]
    toks.append(tok)
    return jnp.concatenate(toks, axis=1)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new: int
    out: Optional[np.ndarray] = None


class Engine:
    """Slot-based batched decoding over a shared jit'd decode step.

    All slots decode in lockstep (one jit call per step for the whole batch);
    each slot tracks its own absolute position via per-slot position ids.
    """

    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 max_len: int = 512):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len = n_slots, max_len
        self.cache = init_cache(cfg, n_slots, max_len)
        self.positions = np.zeros(n_slots, np.int64)
        self.active: List[Optional[Request]] = [None] * n_slots
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(self._decode_fn)
        self._prefill_one = jax.jit(self._prefill_fn)

    def _decode_fn(self, params, token, positions, cache):
        # per-slot positions: (B,) -> (B, 1) position ids
        B = token.shape[0]
        pos = positions.astype(jnp.int32)[:, None]
        if self.cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None, :], (B, 3, 1))
        logits, cache, _ = forward(params, token, pos, self.cfg, cache=cache)
        return logits[:, 0], cache

    def _prefill_fn(self, params, tokens):
        # single-request prefill into a fresh single-slot cache
        cache = init_cache(self.cfg, 1, self.max_len)
        pos = make_positions(tokens, self.cfg)
        logits, cache, _ = forward(params, tokens, pos, self.cfg, cache=cache)
        return logits[:, -1], cache

    @staticmethod
    def _merge_slot(full, one, s):
        """Write a 1-sequence cache leaf into slot s of the batched cache.
        The batch axis is wherever the two shapes differ (scan-stacked
        leaves carry a leading period-count dim)."""
        axis = 0
        for i, (a, b) in enumerate(zip(full.shape, one.shape)):
            if a != b:
                axis = i
                break
        idx = [slice(None)] * full.ndim
        idx[axis] = slice(s, s + 1)
        return full.at[tuple(idx)].set(one)

    def submit(self, req: Request) -> bool:
        for s in range(self.n_slots):
            if self.active[s] is None:
                logits, c1 = self._prefill_one(
                    self.params, jnp.asarray(req.prompt[None]))
                self.cache = jax.tree.map(
                    lambda full, one: self._merge_slot(full, one, s),
                    self.cache, c1)
                self.active[s] = req
                req.out = req.prompt.copy()
                self.tokens[s, 0] = int(jnp.argmax(logits[0]))
                self.positions[s] = len(req.prompt)
                return True
        return False

    def step(self):
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens),
            jnp.asarray(self.positions), self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out = np.concatenate([req.out, self.tokens[s]])
            self.tokens[s, 0] = nxt[s]
            self.positions[s] += 1
            if len(req.out) - len(req.prompt) >= req.max_new:
                self.active[s] = None

    def run(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        done: List[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and self.submit(pending[0]):
                done.append(pending.pop(0))
            self.step()
        return done
