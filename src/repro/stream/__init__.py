"""Streaming coreset subsystem (DESIGN.md Sec. 9).

Three layers over the batch pipeline of :mod:`repro.core`:

* :mod:`repro.stream.tree` -- merge-and-reduce coreset tree
  (:class:`CoresetTree`): any-time, bounded-memory eps-coreset of an
  unbounded stream, O(log n) fixed-size buckets.
* :mod:`repro.stream.ingest` -- ingestion state (:class:`StreamState`) and
  the distributed mode (:class:`DistributedStream`): one tree per topology
  node, periodic Algorithm-1 aggregation rounds with per-round
  ``CommLedger`` phases.
* :mod:`repro.stream.service` -- :class:`ClusterQueryService`: live centers
  with a staleness-bounded refresh policy, batched nearest-center queries
  through the fused distance kernels.
"""

from repro.stream.ingest import AggregateResult, DistributedStream, StreamState
from repro.stream.service import ClusterQueryService, ServiceStats
from repro.stream.tree import CoresetTree, TreeConfig

__all__ = [
    "AggregateResult", "DistributedStream", "StreamState",
    "ClusterQueryService", "ServiceStats", "CoresetTree", "TreeConfig",
]
