"""Batched stream ingestion: single-site state and the distributed mode.

:class:`StreamState` wraps one :class:`~repro.stream.tree.CoresetTree`
behind an arbitrary-size ``push(batch)``: points accumulate in a host-side
pending buffer and flush into the tree in fixed ``batch_size`` chunks (one
jit specialization total), so callers can feed ragged arrivals.
``summary()`` is any-time: tree summary plus the pending tail as raw
weight-1 points.

:class:`DistributedStream` is the topology mode: every node of a
communication :class:`~repro.core.topology.Graph` runs its own tree over
its local arrivals (no communication), and a periodic :meth:`aggregate`
round runs **Algorithm 1 over the per-site tree summaries** -- each site's
current summary is its weighted local instance (``site_weights``
generalization of ``distributed_coreset``), Round 1 floods the n local-cost
scalars, Round 2 floods the fixed-size portions, and every node ends the
round holding the same global coreset + centers. Communication is metered
per round into a :class:`~repro.core.comm.CommLedger` phase
(``stream_round_<r>``; ``ledger.as_dict(by_phase=True)``).
``aggregate(transport="tree", routing="bfs"|"min_cost")`` swaps the floods
for a spanning-tree gather + broadcast of the assembled coreset -- the
same every-node-ends-identical contract, but the ledger prices only tree
edges, and min-cost routing keeps the cost-weighted ``link_cost`` small on
heterogeneous (WAN) links.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core import objective as objective_mod
from repro.core import strategy as strategy_mod
from repro.core.strategy import StrategyLike
from repro.core.comm import (CommLedger, flood_cost, flood_portions_cost,
                             link_cost_of, tree_allocation_cost,
                             tree_broadcast_cost, tree_gather_cost,
                             tree_up_cost)
from repro.core.coreset import Coreset, distributed_coreset
from repro.core.distributed import (exec_algorithm1_rounds,
                                    exec_algorithm1_tree_rounds)
from repro.core.message_passing import (GossipSchedule, TreeSchedule,
                                        flood_exec, gossip_schedule,
                                        pack_payload, tree_broadcast_exec,
                                        tree_gather_exec, unpack_payload)
from repro.core.topology import Graph, SpanningTree, spanning_tree
from repro.stream.tree import CoresetTree, TreeConfig

Array = jax.Array


class StreamState:
    """Single-site ingestion state: ``push`` arbitrary-size batches,
    ``summary`` at any time."""

    def __init__(self, config: TreeConfig, key: Optional[Array] = None):
        self.tree = CoresetTree(config, key=key)
        self._pending = np.zeros((0, config.d), np.float32)
        self.n_pushed = 0

    @property
    def config(self) -> TreeConfig:
        return self.tree.config

    def push(self, batch) -> None:
        """Ingest ``(n, d)`` points, any n: full ``batch_size`` chunks go to
        the tree, the remainder stays pending until the next push."""
        batch = np.asarray(batch, np.float32)
        if batch.ndim != 2 or batch.shape[1] != self.config.d:
            raise ValueError(f"expected (n, {self.config.d}) points, got "
                             f"{batch.shape}")
        self.n_pushed += batch.shape[0]
        buf = np.concatenate([self._pending, batch])
        bs = self.config.batch_size
        n_full = buf.shape[0] // bs
        for i in range(n_full):
            self.tree.push(jnp.asarray(buf[i * bs:(i + 1) * bs]))
        self._pending = buf[n_full * bs:]

    def pending(self) -> int:
        return int(self._pending.shape[0])

    def summary(self, include_pending: bool = True) -> Coreset:
        """Any-time weighted summary of everything pushed. With
        ``include_pending`` the sub-batch tail rides along as raw weight-1
        points padded to one batch slot (shape stays constant per config)."""
        s = self.tree.summary()
        if not include_pending:
            return s
        bs = self.config.batch_size
        tail = np.zeros((bs, self.config.d), np.float32)
        w = np.zeros((bs,), np.float32)
        n_p = self.pending()
        tail[:n_p] = self._pending
        w[:n_p] = 1.0
        return Coreset.concat(s, Coreset(points=jnp.asarray(tail),
                                         weights=jnp.asarray(w)))

    def total_weight(self) -> float:
        return self.tree.total_weight + float(self.pending())


@dataclasses.dataclass
class AggregateResult:
    """One streaming aggregation round: the global summary every node holds
    after the round, the centers solved from it, and that round's metered
    communication (also folded into the stream's cumulative ledger).
    ``local_costs`` are the Round-1 scalars of a resample round; ``None``
    for a union round (which communicates no costs)."""

    coreset: Coreset
    centers: Array
    ledger: CommLedger
    local_costs: Optional[Array]


class DistributedStream:
    """Per-site coreset trees over a communication graph + periodic
    Algorithm-1 aggregation rounds with full ledger accounting."""

    def __init__(self, graph: Graph, config: TreeConfig,
                 key: Optional[Array] = None):
        key = jax.random.PRNGKey(0) if key is None else key
        self.graph = graph
        # freeze the ambient backend now, like the per-site trees do --
        # otherwise a later aggregate() could resolve a different ambient
        # default than the pushes ran under; the objective resolves through
        # its registry too (unknown names fail loudly before any push)
        self.config = dataclasses.replace(
            config, backend=backend_mod.resolve_name(config.backend),
            objective=objective_mod.resolve_name(config.objective))
        self.sites: List[StreamState] = [
            StreamState(config, key=jax.random.fold_in(key, i))
            for i in range(graph.n)
        ]
        self._agg_key = jax.random.fold_in(key, graph.n)
        self._schedule: Optional[GossipSchedule] = None   # compiled lazily
        self._trees: dict = {}     # (routing, root) -> (tree, TreeSchedule)
        self.ledger = CommLedger()
        self.rounds = 0

    def push(self, site: int, batch) -> None:
        """Local arrival at one node -- costs zero communication."""
        site = int(site)
        if not 0 <= site < self.graph.n:
            raise ValueError(f"site index {site} out of range for a "
                             f"{self.graph.n}-node topology")
        self.sites[site].push(batch)

    def push_all(self, site_batches) -> None:
        """One arrival per node (length-n sequence of (n_i, d) arrays)."""
        if len(site_batches) != self.graph.n:
            raise ValueError(f"expected {self.graph.n} site batches")
        for i, b in enumerate(site_batches):
            self.push(i, b)

    def total_weight(self) -> float:
        return sum(s.total_weight() for s in self.sites)

    def _tree_schedule(self, routing: str, root: int):
        """Build (and cache) the spanning tree + compiled schedule for a
        tree-transport round."""
        key = (routing, int(root))
        if key not in self._trees:
            tree = spanning_tree(self.graph, root=root, routing=routing)
            self._trees[key] = (tree, TreeSchedule.from_tree(tree))
        return self._trees[key]

    def aggregate(self, k: int, t: int, lloyd_iters: int = 8,
                  clip_negative: bool = False,
                  mode: str = "auto", restarts: int = 3,
                  engine: str = "sim", transport: str = "flood",
                  routing: str = "bfs", root: int = 0,
                  faults=None, wan_mode: Optional[str] = None,
                  wan_seed: Optional[int] = None,
                  wan_p: float = 0.5,
                  strategy: StrategyLike = None) -> AggregateResult:
        """Run one aggregation round over the current per-site summaries.

        Every node's tree summary (fixed ``levels * slot + batch_size``
        points, vacant slots weight-0) is its weighted local instance.
        Two round types:

        * ``"resample"`` -- Algorithm 1 over the summaries: Round 1 floods
          the n local-cost scalars (2mn messages), Round 2 floods the n
          sampled portions (t + nk points). Pays off when the summaries
          outgrow the budget.
        * ``"union"`` -- flood the summaries themselves. The union of
          eps-coresets is an eps-coreset of the union, so this is *exact*
          (no extra sampling error) and strictly better whenever the total
          effective summary size is already <= the t + nk points a resample
          round would ship -- re-sampling a support no larger than the
          sample budget only injects variance (signed weights amplify it).

        ``"auto"`` picks union exactly in that dominance regime. The
        round's ledger (Theorem 2 accounting) is tagged
        ``stream_round_<r>`` and accumulated on ``self.ledger``.

        ``engine="sim"`` computes the round globally with the analytic
        ledger; ``engine="exec"`` runs the same math through the topology
        execution engine (a :class:`GossipSchedule` compiled once per
        stream): summaries / scalars / portions physically flood the graph,
        every node assembles the bit-identical round result, and the round
        ledger is *measured* from the executed schedule (equal to the
        analytic one; the padded vacant slots of a summary ride along
        physically but carry weight 0 and are not metered, matching the
        effective-size accounting).

        ``transport="tree"`` restricts the round's communication to a
        spanning tree of the topology under ``routing`` (``"bfs"``
        hop-minimal | ``"min_cost"`` Prim over ``edge_costs``) rooted at
        ``root``: summaries / portions are gathered to the root and the
        assembled global coreset is broadcast back down, so every node
        still ends the round holding the identical result, but the ledger
        prices only tree edges -- on heterogeneous links min-cost routing
        is what keeps the cost-weighted ``link_cost`` small. Both engines
        support both transports with the same bit-parity contract.

        ``engine="async"`` (or a ``faults=``
        :class:`~repro.wan.faults.FaultPlan` with either engine) runs the
        round's floods on the asynchronous WAN runtime (flood transport
        only): ``wan_mode`` picks the activation schedule (``"clock"``
        default for async, ``"full"`` when faults ride on
        ``engine="exec"``), ``wan_seed`` defaults to the round counter so
        successive rounds draw fresh schedules, and the round's ledger
        carries the measured ``staleness`` axis. The round result is the
        *survivor-restricted* aggregate: every surviving node ends
        holding the bit-identical coreset over surviving sites."""
        cfg = self.config
        g = self.graph
        if engine not in ("sim", "exec", "async"):
            raise ValueError(f"unknown engine {engine!r}: expected "
                             f"'sim'|'exec'|'async'")
        if transport not in ("flood", "tree"):
            raise ValueError(f"unknown transport {transport!r}: expected "
                             f"'flood'|'tree'")
        strategy = strategy_mod.resolve_name(strategy)
        strat = strategy_mod.get_strategy(strategy)
        use_wan = engine == "async" or faults is not None
        if not strat.needs_exchange and transport == "flood" and not use_wan:
            # single-shuffle strategies never flood on synchronous rounds:
            # map -> shuffle -> reduce along the spanning tree instead
            transport = "tree"
        if use_wan:
            if transport != "flood":
                raise ValueError(f"faulty/async rounds support "
                                 f"transport='flood' only, got {transport!r}")
            if engine == "sim":
                raise ValueError("faults require engine='exec'|'async'")
            wan_mode = wan_mode if wan_mode is not None else (
                "full" if engine == "exec" else "clock")
            wan_seed = self.rounds if wan_seed is None else wan_seed
        tree: Optional[SpanningTree] = None
        tsched: Optional[TreeSchedule] = None
        if transport == "tree":
            tree, tsched = self._tree_schedule(routing, root)
        elif engine == "exec" and self._schedule is None:
            self._schedule = gossip_schedule(g)   # process-wide cache
        summaries = [s.summary() for s in self.sites]
        sp = jnp.stack([c.points for c in summaries])     # (n, S, d)
        sw = jnp.stack([c.weights for c in summaries])    # (n, S)
        self._agg_key, kr = jax.random.split(self._agg_key)
        k1, k2 = jax.random.split(kr)

        if mode != "resample":
            # one host sync for the whole round (resample never needs it)
            sum_eff = int(jnp.sum(sw != 0.0))
        if mode == "auto":
            mode = "union" if sum_eff <= t + g.n * k else "resample"

        if mode == "union":
            local_costs = None
            eff = np.asarray(jnp.sum(sw != 0.0, axis=1), np.float64)
            if use_wan:
                from repro.wan.faults import FaultPlan
                from repro.wan.runtime import wan_flood_exec
                plan = faults if faults is not None else FaultPlan()
                payload = pack_payload(sp, sw)
                tables, rr = wan_flood_exec(g, payload, mode=wan_mode,
                                            faults=plan, unit_points=eff,
                                            dim=cfg.d, seed=wan_seed,
                                            p=wan_p)
                surv = plan.surviving_nodes(g.n)
                pts0, w0 = unpack_payload(tables[int(surv[0])][surv])
                cs = Coreset(points=pts0.reshape(-1, cfg.d),
                             weights=w0.reshape(-1))
                round_ledger = rr.ledger
            elif transport == "tree" and engine == "exec":
                payload = pack_payload(sp, sw)
                root_table, gr = tree_gather_exec(tsched, payload,
                                                  unit_points=eff, dim=cfg.d)
                _, br = tree_broadcast_exec(tsched, root_table,
                                            unit_points=float(sum_eff),
                                            dim=cfg.d)
                pts0, w0 = unpack_payload(root_table)
                cs = Coreset(points=pts0.reshape(-1, cfg.d),
                             weights=w0.reshape(-1))
                round_ledger = gr.ledger.add(br.ledger)
            elif transport == "tree":
                cs = Coreset.concat(*summaries)
                round_ledger = tree_gather_cost(
                    tree, unit_points_per_node=eff, dim=cfg.d)
                round_ledger = round_ledger.add(tree_broadcast_cost(
                    tree, unit_points=float(sum_eff), dim=cfg.d))
            elif engine == "exec":
                payload = pack_payload(sp, sw)
                tables, rr = flood_exec(self._schedule, payload,
                                        unit_points=eff, dim=cfg.d)
                pts0, w0 = unpack_payload(tables[0])
                cs = Coreset(points=pts0.reshape(-1, cfg.d),
                             weights=w0.reshape(-1))
                round_ledger = rr.ledger
            else:
                cs = Coreset.concat(*summaries)
                # per-origin link pricing mirrors the engine's measured
                # summation term for term (bit-parity; DESIGN.md Sec. 12)
                w_pm = float(g.weighted_degrees().sum())
                round_ledger = CommLedger(
                    points=2.0 * g.m * float(sum_eff),
                    messages=2.0 * g.m * g.n, dim=cfg.d,
                    link_cost=link_cost_of(np.full(g.n, w_pm),
                                           unit_points=eff, dim=cfg.d))
        elif mode == "resample":
            if use_wan:
                from repro.wan.runtime import async_algorithm1_rounds
                detail, local_costs = async_algorithm1_rounds(
                    g, k1, sp, sw.astype(sp.dtype), k, t, t_buffer=t,
                    objective=cfg.objective, lloyd_iters=lloyd_iters,
                    clip_negative=clip_negative, backend=cfg.backend,
                    mode=wan_mode, faults=faults, seed=wan_seed, p=wan_p,
                    strategy=strategy)
                cs = Coreset(points=detail.node_points[0],
                             weights=detail.node_weights[0])
                round_ledger = detail.rounds["round2"].ledger
                if "round1" in detail.rounds:
                    round_ledger = detail.rounds["round1"].ledger.add(
                        round_ledger)
            elif transport == "tree" and engine == "exec":
                root_pts, root_w, t_i, _, rounds, local_costs = \
                    exec_algorithm1_tree_rounds(
                        tsched, k1, sp, sw.astype(sp.dtype), k, t,
                        t_buffer=t, objective=cfg.objective,
                        lloyd_iters=lloyd_iters,
                        clip_negative=clip_negative, backend=cfg.backend,
                        strategy=strategy)
                table = pack_payload(root_pts, root_w)
                unit_b = float(np.asarray(t_i, np.float64).sum()) + g.n * k
                _, br = tree_broadcast_exec(tsched, table,
                                            unit_points=unit_b, dim=cfg.d)
                cs = Coreset(points=root_pts.reshape(-1, cfg.d),
                             weights=root_w.reshape(-1))
                if "round1_gather" in rounds:
                    round_ledger = (rounds["round1_gather"].ledger
                                    .add(rounds["round1_scatter"].ledger)
                                    .add(rounds["round1_broadcast"].ledger)
                                    .add(rounds["round2_gather"].ledger)
                                    .add(br.ledger))
                else:   # single shuffle: no Round-1 phases at all
                    round_ledger = rounds["round2_gather"].ledger.add(
                        br.ledger)
            elif transport == "tree":
                dc = distributed_coreset(k1, sp, sw != 0.0, k, t,
                                         objective=cfg.objective,
                                         lloyd_iters=lloyd_iters,
                                         clip_negative=clip_negative,
                                         backend=cfg.backend, site_weights=sw,
                                         strategy=strategy)
                cs = dc.flatten()
                local_costs = dc.local_costs
                unit_pts = np.asarray(dc.t_i, np.float64) + k
                unit_b = float(np.asarray(dc.t_i, np.float64).sum()) \
                    + g.n * k
                up = tree_up_cost(tree, unit_pts, dim=cfg.d)
                if strat.needs_exchange:
                    round_ledger = tree_allocation_cost(tree).add(up)
                else:   # the uniform split is derived locally, zero traffic
                    round_ledger = up
                round_ledger = round_ledger.add(tree_broadcast_cost(
                    tree, unit_points=unit_b, dim=cfg.d))
            elif engine == "exec":
                detail, local_costs = exec_algorithm1_rounds(
                    self._schedule, k1, sp, sw.astype(sp.dtype), k, t,
                    t_buffer=t, objective=cfg.objective,
                    lloyd_iters=lloyd_iters, clip_negative=clip_negative,
                    backend=cfg.backend, strategy=strategy)
                cs = Coreset(points=detail.node_points[0],
                             weights=detail.node_weights[0])
                round_ledger = detail.rounds["round1"].ledger.add(
                    detail.rounds["round2"].ledger)
            else:
                dc = distributed_coreset(k1, sp, sw != 0.0, k, t,
                                         objective=cfg.objective,
                                         lloyd_iters=lloyd_iters,
                                         clip_negative=clip_negative,
                                         backend=cfg.backend, site_weights=sw,
                                         strategy=strategy)
                cs = dc.flatten()
                local_costs = dc.local_costs
                round_ledger = flood_cost(g, n_messages=g.n, unit_scalars=1.0)
                round_ledger = round_ledger.add(
                    flood_portions_cost(g, np.asarray(dc.t_i), k, cfg.d))
        else:
            raise ValueError(f"unknown aggregate mode {mode!r}")

        # centers are solved on the *non-negative part* of the measure: the
        # signed summary is unbiased for cost estimation, but optimizing
        # centers against negative mass admits spurious minima (cost can be
        # driven artificially low where cancellation is large), and twice-
        # resampled streaming summaries carry much more cancellation than
        # the batch pipeline's single generation. Restarted seeding matters
        # for the same reason. Empirically the two together are the
        # difference between 1.05x and 10x worst-case cost ratios.
        w_solve = jnp.maximum(cs.weights, 0.0)
        centers, _ = clustering.solve(k2, cs.points, k, weights=w_solve,
                                      lloyd_iters=lloyd_iters,
                                      objective=cfg.objective,
                                      restarts=restarts,
                                      backend=cfg.backend)

        round_ledger = round_ledger.tag(f"stream_round_{self.rounds}")
        self.ledger = self.ledger.add(round_ledger)
        self.rounds += 1
        return AggregateResult(coreset=cs, centers=centers,
                               ledger=round_ledger,
                               local_costs=local_costs)
