"""Clustering-query service over a live stream summary.

:class:`ClusterQueryService` owns a :class:`~repro.stream.ingest.StreamState`
(or any object with the same ``push`` / ``summary`` / ``total_weight`` /
``config`` surface) and serves batched nearest-center queries against
centers solved from the current summary:

* **queries** are served *through the multi-tenant engine*
  (:class:`repro.serve.cluster.ClusterServeEngine`): the service registers
  itself as a center source on a (by default private, single-tenant)
  engine and each ``query()`` is an enqueue + step -- one fused
  ``query_assignments_batched`` dispatch (the Pallas
  ``distance_argmin_batched`` kernel on TPU). Query batches are padded up
  to power-of-two buckets capped at ``max_bucket`` (oversized batches are
  chunked, never compiled at unbounded shapes), so arbitrary traffic
  shapes hit a bounded set of compiled specializations. Passing a shared
  ``engine`` (or ``engine.add_tenant(service, ...)`` on an external one)
  co-batches this stream's queries with other tenants' -- the
  single-tenant path here is the degenerate T=1 case of the same
  machinery, kept as the simple migration surface for existing callers
  (DESIGN.md Sec. 13).
* **freshness** is staleness-bounded: the service re-solves centers from
  the summary (k-means++ + Lloyd on the weighted coreset, one compile --
  the tree summary is constant-shape) whenever the mass ingested since the
  last refresh exceeds ``staleness_frac`` of the total (or an absolute
  ``max_stale_points``), checked lazily on each query batch. Between
  refreshes queries are answered from the cached centers at zero solve
  cost, so worst-case extra error is the cost drift of one staleness
  window. Under a shared engine the refresh is *scheduled* by the engine's
  per-step budget instead of running inline, so one tenant's re-solve
  never stalls another tenant's queries.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.kernels.ops import chunk_queries
from repro.stream.ingest import StreamState

Array = jax.Array

# distinct default-PRNG tenants: each service constructed without an
# explicit key/tenant_id folds a fresh instance id into the seed, so two
# services never replay identical restart draws (the shared-PRNGKey(0)
# hazard)
_INSTANCE_IDS = itertools.count()


@dataclasses.dataclass
class ServiceStats:
    """Serving counters (monitoring surface).

    ``n_padded_queries`` counts padding rows shipped to fill power-of-two
    buckets (padding overhead = ``n_padded_queries / (n_queries +
    n_padded_queries)``); ``refresh_s`` / ``assign_s`` accumulate
    per-phase wall-clock so refresh stalls and padding cost are measurable
    per service (surfaced by ``as_dict`` for the benchmarks)."""

    n_queries: int = 0
    n_batches: int = 0
    n_refreshes: int = 0
    n_padded_queries: int = 0
    refresh_s: float = 0.0
    assign_s: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        total = self.n_queries + self.n_padded_queries
        d["padded_frac"] = self.n_padded_queries / total if total else 0.0
        return d


class ClusterQueryService:
    """Live centers + batched nearest-center queries with bounded staleness.

    ``staleness_frac=0.0`` refreshes on every ingest (always-fresh);
    ``staleness_frac=None`` disables fractional triggering (absolute
    ``max_stale_points`` only, if set).

    Also a valid *center source* for a
    :class:`~repro.serve.cluster.ClusterServeEngine`
    (``cached_centers`` / ``is_stale`` / ``staleness`` / ``refresh``):
    register it on a shared engine to co-batch this stream's queries with
    other tenants'.
    """

    def __init__(self, stream: StreamState, k: int,
                 staleness_frac: Optional[float] = 0.1,
                 max_stale_points: Optional[float] = None,
                 lloyd_iters: int = 8,
                 restarts: int = 2,
                 backend: backend_mod.BackendLike = None,
                 key: Optional[Array] = None,
                 tenant_id: Optional[int] = None,
                 max_bucket: int = 4096,
                 engine=None):
        self.stream = stream
        self.k = k
        self.staleness_frac = staleness_frac
        self.max_stale_points = max_stale_points
        self.lloyd_iters = lloyd_iters
        self.restarts = restarts
        self.backend = backend_mod.resolve_name(
            backend if backend is not None
            else getattr(stream.config, "backend", None))
        self.tenant_id = (next(_INSTANCE_IDS) if tenant_id is None
                          else int(tenant_id))
        # fold the tenant id into the default seed -- a bare PRNGKey(0)
        # default would make every service replay identical restart seeds
        self._key = (jax.random.fold_in(jax.random.PRNGKey(0),
                                        self.tenant_id)
                     if key is None else key)
        self.max_bucket = int(max_bucket)
        self._centers: Optional[Array] = None
        self._weight_at_refresh = 0.0
        self.stats = ServiceStats()
        self._engine = engine
        self._engine_tid: Optional[int] = None

    # -- freshness policy ----------------------------------------------------

    def staleness(self) -> float:
        """Mass ingested since the centers were last solved."""
        return self.stream.total_weight() - self._weight_at_refresh

    def is_stale(self) -> bool:
        if self._centers is None:
            return True
        s = self.staleness()
        total = self.stream.total_weight()
        if self.max_stale_points is not None and s > self.max_stale_points:
            return True
        return (self.staleness_frac is not None
                and s > self.staleness_frac * max(total, 1.0))

    # center-source surface for ClusterServeEngine
    _stale = is_stale

    def cached_centers(self) -> Optional[Array]:
        """Currently cached serving centers (``None`` before first solve);
        never triggers a refresh."""
        return self._centers

    def refresh(self) -> Array:
        """Force a center re-solve from the current summary. Solves on the
        non-negative part of the signed measure -- optimizing centers
        against negative mass admits spurious minima (see
        ``DistributedStream.aggregate``)."""
        t0 = time.perf_counter()
        objective = self.stream.config.objective
        cs = self.stream.summary()
        w_solve = jnp.maximum(cs.weights, 0.0)
        self._key, k1 = jax.random.split(self._key)
        centers, _ = clustering.solve(k1, cs.points, self.k,
                                      weights=w_solve,
                                      lloyd_iters=self.lloyd_iters,
                                      objective=objective,
                                      restarts=self.restarts,
                                      backend=self.backend)
        jax.block_until_ready(centers)
        self._centers = centers
        self._weight_at_refresh = self.stream.total_weight()
        self.stats.n_refreshes += 1
        self.stats.refresh_s += time.perf_counter() - t0
        return centers

    def centers(self) -> Array:
        """Current serving centers, refreshing first if stale."""
        if self.is_stale():
            self.refresh()
        return self._centers

    # -- ingestion + queries -------------------------------------------------

    def push(self, batch) -> None:
        """Ingest through the service (keeps the staleness clock honest)."""
        self.stream.push(batch)

    def _as_batch(self, points) -> Array:
        """Normalize query input to (n, d), n >= 0, with clear errors: a
        single d-vector becomes one row; an empty input (``[]`` or
        ``(0, d)``) becomes the canonical (0, d) batch instead of reaching
        the kernels as a zero-dim point."""
        d = self.stream.config.d
        q = jnp.asarray(points, jnp.float32)
        if q.ndim <= 1 and q.size == 0:      # [] / shape-(0,) ragged empty
            return jnp.zeros((0, d), jnp.float32)
        if q.ndim == 1:
            q = q[None, :]
        # a (0, d) batch falls through unchanged; (0, d') and (n, 0) are
        # malformed and must raise like any other wrong-width batch
        if q.ndim != 2 or q.shape[1] != d:
            raise ValueError(f"expected (n, {d}) query points, got shape "
                             f"{tuple(q.shape)}")
        return q

    def _serve_engine(self):
        """The engine this service serves through: a private single-tenant
        :class:`ClusterServeEngine` unless one was injected, with this
        service registered as its own center source."""
        if self._engine is None:
            from repro.serve.cluster import ClusterServeEngine

            self._engine = ClusterServeEngine(backend=self.backend,
                                              max_bucket=self.max_bucket)
        if self._engine_tid is None:
            self._engine_tid = self._engine.add_tenant(
                self, k=self.k, d=self.stream.config.d,
                objective=self.stream.config.objective,
                tenant_id=self.tenant_id
                if self.tenant_id not in self._engine.tenant_ids()
                else None)
        return self._engine

    def query(self, points) -> Tuple[Array, Array]:
        """Batched nearest-center query: (n, d) -> (assign (n,) i32,
        dist (n,) f32 in the stream objective's metric: squared for z=2 --
        including trimmed objectives -- euclidean for z=1).
        An empty batch returns empty arrays (and costs no solve/refresh).

        Delegates to the serving engine (enqueue + step until this ticket
        completes): the single-tenant migration path of the multi-tenant
        serving tier, numerically identical to the old direct
        ``query_assignments`` call."""
        q = self._as_batch(points)
        if q.shape[0] == 0:
            return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32))
        eng = self._serve_engine()
        ticket = eng.enqueue(self._engine_tid, np.asarray(q))
        r0 = self.stats.refresh_s
        t0 = time.perf_counter()
        while not ticket.done:
            eng.step()
        # engine-run refreshes call back into refresh() (which books its
        # own phase time); attribute the rest of the wall to assignment
        self.stats.assign_s += (time.perf_counter() - t0) \
            - (self.stats.refresh_s - r0)
        self.stats.n_queries += ticket.n
        self.stats.n_batches += 1
        self.stats.n_padded_queries += ticket.n_padded
        return jnp.asarray(ticket.assign), jnp.asarray(ticket.dist)

    def query_load(self, points, weights: Optional[Array] = None) -> Array:
        """Per-center (optionally weighted) query-load histogram (k,) for
        one batch -- a single fused ``lloyd_stats`` pass (counts output),
        useful for shard/center load monitoring. Batches are bucket-padded
        (and chunked at ``max_bucket``) like :meth:`query` (weight-0
        padding keeps counts exact); an empty batch is an all-zero
        histogram."""
        q = self._as_batch(points)
        if q.shape[0] == 0:
            return jnp.zeros((self.k,), jnp.float32)
        w = (jnp.ones((q.shape[0],), jnp.float32) if weights is None
             else jnp.asarray(weights, jnp.float32))
        centers = self.centers()
        be = backend_mod.get_backend(self.backend)
        total = jnp.zeros((self.k,), jnp.float32)
        for qp, n, off in chunk_queries(q, max_bucket=self.max_bucket):
            wp = jnp.zeros((qp.shape[0],), jnp.float32)
            wp = wp.at[:n].set(w[off:off + n])
            _, counts, _ = be.lloyd_stats(qp, centers, wp)
            total = total + counts
        return total
