"""Clustering-query service over a live stream summary.

:class:`ClusterQueryService` owns a :class:`~repro.stream.ingest.StreamState`
(or any object with the same ``push`` / ``summary`` / ``total_weight`` /
``config`` surface) and serves batched nearest-center queries against
centers solved from the current summary:

* **queries** route through :func:`repro.core.backend.query_assignments` --
  one fused ``min_dist_argmin`` pass (the Pallas ``distance_argmin`` kernel
  on TPU). Query batches are padded up to power-of-two buckets so arbitrary
  traffic shapes hit a bounded set of compiled specializations.
* **freshness** is staleness-bounded: the service re-solves centers from
  the summary (k-means++ + Lloyd on the weighted coreset, one compile --
  the tree summary is constant-shape) whenever the mass ingested since the
  last refresh exceeds ``staleness_frac`` of the total (or an absolute
  ``max_stale_points``), checked lazily on each query batch. Between
  refreshes queries are answered from the cached centers at zero solve
  cost, so worst-case extra error is the cost drift of one staleness
  window.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.kernels.ops import pad_queries
from repro.stream.ingest import StreamState

Array = jax.Array


@dataclasses.dataclass
class ServiceStats:
    """Serving counters (monitoring surface)."""

    n_queries: int = 0
    n_batches: int = 0
    n_refreshes: int = 0


class ClusterQueryService:
    """Live centers + batched nearest-center queries with bounded staleness.

    ``staleness_frac=0.0`` refreshes on every ingest (always-fresh);
    ``staleness_frac=None`` disables fractional triggering (absolute
    ``max_stale_points`` only, if set).
    """

    def __init__(self, stream: StreamState, k: int,
                 staleness_frac: Optional[float] = 0.1,
                 max_stale_points: Optional[float] = None,
                 lloyd_iters: int = 8,
                 restarts: int = 2,
                 backend: backend_mod.BackendLike = None,
                 key: Optional[Array] = None):
        self.stream = stream
        self.k = k
        self.staleness_frac = staleness_frac
        self.max_stale_points = max_stale_points
        self.lloyd_iters = lloyd_iters
        self.restarts = restarts
        self.backend = backend_mod.resolve_name(
            backend if backend is not None
            else getattr(stream.config, "backend", None))
        self._key = jax.random.PRNGKey(0) if key is None else key
        self._centers: Optional[Array] = None
        self._weight_at_refresh = 0.0
        self.stats = ServiceStats()

    # -- freshness policy ----------------------------------------------------

    def staleness(self) -> float:
        """Mass ingested since the centers were last solved."""
        return self.stream.total_weight() - self._weight_at_refresh

    def _stale(self) -> bool:
        if self._centers is None:
            return True
        s = self.staleness()
        total = self.stream.total_weight()
        if self.max_stale_points is not None and s > self.max_stale_points:
            return True
        return (self.staleness_frac is not None
                and s > self.staleness_frac * max(total, 1.0))

    def refresh(self) -> Array:
        """Force a center re-solve from the current summary. Solves on the
        non-negative part of the signed measure -- optimizing centers
        against negative mass admits spurious minima (see
        ``DistributedStream.aggregate``)."""
        objective = self.stream.config.objective
        cs = self.stream.summary()
        w_solve = jnp.maximum(cs.weights, 0.0)
        self._key, k1 = jax.random.split(self._key)
        centers, _ = clustering.solve(k1, cs.points, self.k,
                                      weights=w_solve,
                                      lloyd_iters=self.lloyd_iters,
                                      objective=objective,
                                      restarts=self.restarts,
                                      backend=self.backend)
        self._centers = centers
        self._weight_at_refresh = self.stream.total_weight()
        self.stats.n_refreshes += 1
        return centers

    def centers(self) -> Array:
        """Current serving centers, refreshing first if stale."""
        if self._stale():
            self.refresh()
        return self._centers

    # -- ingestion + queries -------------------------------------------------

    def push(self, batch) -> None:
        """Ingest through the service (keeps the staleness clock honest)."""
        self.stream.push(batch)

    def _as_batch(self, points) -> Array:
        """Normalize query input to (n, d), n >= 0, with clear errors: a
        single d-vector becomes one row; an empty input (``[]`` or
        ``(0, d)``) becomes the canonical (0, d) batch instead of reaching
        the kernels as a zero-dim point."""
        d = self.stream.config.d
        q = jnp.asarray(points, jnp.float32)
        if q.ndim <= 1 and q.size == 0:      # [] / shape-(0,) ragged empty
            return jnp.zeros((0, d), jnp.float32)
        if q.ndim == 1:
            q = q[None, :]
        # a (0, d) batch falls through unchanged; (0, d') and (n, 0) are
        # malformed and must raise like any other wrong-width batch
        if q.ndim != 2 or q.shape[1] != d:
            raise ValueError(f"expected (n, {d}) query points, got shape "
                             f"{tuple(q.shape)}")
        return q

    def query(self, points) -> Tuple[Array, Array]:
        """Batched nearest-center query: (n, d) -> (assign (n,) i32,
        dist (n,) f32; squared for k-means, euclidean for k-median).
        An empty batch returns empty arrays (and costs no solve/refresh)."""
        q = self._as_batch(points)
        if q.shape[0] == 0:
            return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32))
        centers = self.centers()
        qp, n = pad_queries(q)
        assign, dist = backend_mod.query_assignments(
            qp, centers, objective=self.stream.config.objective,
            backend=self.backend)
        self.stats.n_queries += n
        self.stats.n_batches += 1
        return assign[:n], dist[:n]

    def query_load(self, points, weights: Optional[Array] = None) -> Array:
        """Per-center (optionally weighted) query-load histogram (k,) for
        one batch -- a single fused ``lloyd_stats`` pass (counts output),
        useful for shard/center load monitoring. Batches are bucket-padded
        like :meth:`query` (weight-0 padding keeps counts exact); an empty
        batch is an all-zero histogram."""
        q = self._as_batch(points)
        if q.shape[0] == 0:
            return jnp.zeros((self.k,), jnp.float32)
        w = (jnp.ones((q.shape[0],), jnp.float32) if weights is None
             else jnp.asarray(weights, jnp.float32))
        qp, n = pad_queries(q)
        wp = jnp.pad(w, (0, qp.shape[0] - n))
        _, counts, _ = backend_mod.get_backend(self.backend).lloyd_stats(
            qp, self.centers(), wp)
        return counts
