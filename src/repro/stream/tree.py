"""Merge-and-reduce coreset tree (Bentley-Saxe over Algorithm 1's summary).

Har-Peled & Mazumdar's composability facts make coresets streamable:

* **merge**: the union of eps-coresets of two disjoint sets is an
  eps-coreset of the union (weight-preserving, free);
* **reduce**: an eps'-coreset of an eps-coreset is an
  ((1+eps)(1+eps')-1)-coreset of the original.

:class:`CoresetTree` keeps one fixed-size :class:`~repro.core.coreset.Coreset`
slot per level; level ``i`` summarizes ``2^i`` ingested batches. Pushing a
batch builds its leaf summary and carries it up binary-counter style: two
occupied summaries at a level merge (``Coreset.concat``) and reduce
(``build_coreset`` re-runs sensitivity sampling on the union, through the
clustering-backend registry), vacating the level. After ``n`` batches at
most ``ceil(log2(n)) + 1`` levels are occupied, so the whole summary is
``O((t + k) log n)`` points with eps-coreset error ``O(eps log n)`` --
tighten per-level ``t`` by ``log^2 n`` to recover a clean eps overall.

Static-shape discipline (DESIGN.md Sec. 7/9): bucket storage is two stacked
arrays ``(levels, slot, d)`` / ``(levels, slot)`` whose vacant levels carry
weight exactly 0, so :meth:`summary` is a constant-shape reshape -- every
downstream jit (refresh solves, query kernels) compiles once per tree
config. The carry cascade is host-side control flow driven only by the
deterministic push counter (never by data), so each push costs amortized
O(1) jitted reduce calls.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import objective as objective_mod
from repro.core.backend import BackendLike
from repro.core.coreset import Coreset, build_coreset, merge_coresets

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static shape/solver parameters of one tree (the jit cache key)."""

    k: int                     # centers per local solve
    t: int                     # samples per bucket coreset
    d: int                     # point dimensionality
    batch_size: int            # points per ingested batch (fixed shape)
    levels: int = 24           # >= log2(#batches); 24 ~ 16M batches
    objective: str = "kmeans"  # any registered objective name
    lloyd_iters: int = 5
    backend: Optional[str] = None   # resolved at tree construction

    @property
    def slot(self) -> int:
        """Points per bucket: t samples + k solution centers."""
        return self.t + self.k


class CoresetTree:
    """Any-time bounded-memory coreset of everything pushed so far."""

    def __init__(self, config: TreeConfig, key: Optional[Array] = None):
        if config.levels < 1:
            raise ValueError("need at least one level")
        # resolve both registries once: unknown names fail loudly here, and
        # every jitted stage below sees the canonical static strings
        self.config = dataclasses.replace(
            config, backend=backend_mod.resolve_name(config.backend),
            objective=objective_mod.resolve_name(config.objective))
        s = config.slot
        self._points = jnp.zeros((config.levels, s, config.d), jnp.float32)
        self._weights = jnp.zeros((config.levels, s), jnp.float32)
        self._occupied = np.zeros((config.levels,), dtype=bool)
        self._key = jax.random.PRNGKey(0) if key is None else key
        self.n_batches = 0
        self.total_weight = 0.0    # exact mass pushed (host-side float)

    # -- internals -----------------------------------------------------------

    def _next_key(self) -> Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _leaf(self, batch: Array, weights: Array) -> Coreset:
        """Level-0 summary of one batch. Batches no larger than a slot are
        stored raw (zero-padded, exact); larger batches are reduced by one
        sensitivity-sampling pass."""
        cfg = self.config
        if cfg.batch_size <= cfg.slot:
            pad = cfg.slot - cfg.batch_size
            return Coreset(points=jnp.pad(batch, ((0, pad), (0, 0))),
                           weights=jnp.pad(weights, (0, pad)))
        return build_coreset(self._next_key(), batch, cfg.k, cfg.t,
                             weights=weights, objective=cfg.objective,
                             lloyd_iters=cfg.lloyd_iters, backend=cfg.backend)

    def _reduce(self, a: Coreset, b: Coreset) -> Coreset:
        cfg = self.config
        return merge_coresets(self._next_key(), a, b, cfg.k, cfg.t,
                              objective=cfg.objective,
                              lloyd_iters=cfg.lloyd_iters,
                              backend=cfg.backend)

    def _bucket(self, level: int) -> Coreset:
        return Coreset(points=self._points[level],
                       weights=self._weights[level])

    def _set_bucket(self, level: int, cs: Optional[Coreset]) -> None:
        if cs is None:
            # vacate: weights must go to exactly 0 so summary() stays a
            # plain reshape (vacant levels are inert by the mask discipline)
            self._weights = self._weights.at[level].set(0.0)
            self._occupied[level] = False
        else:
            self._points = self._points.at[level].set(cs.points)
            self._weights = self._weights.at[level].set(cs.weights)
            self._occupied[level] = True

    # -- public API ----------------------------------------------------------

    def push(self, batch: Array, weights: Optional[Array] = None) -> None:
        """Ingest one fixed-size batch ``(batch_size, d)`` (optionally
        weighted). Amortized O(1) reduce calls per push."""
        cfg = self.config
        batch = jnp.asarray(batch, jnp.float32)
        if batch.shape != (cfg.batch_size, cfg.d):
            raise ValueError(f"batch shape {batch.shape} != "
                             f"{(cfg.batch_size, cfg.d)}; pad with weight-0 "
                             f"slots for partial batches")
        # track mass from host-side values: a device sum here would block
        # async dispatch on every push
        if weights is None:
            w = jnp.ones((cfg.batch_size,), jnp.float32)
            self.total_weight += float(cfg.batch_size)
        else:
            self.total_weight += float(np.sum(np.asarray(weights,
                                                         np.float64)))
            w = jnp.asarray(weights, jnp.float32)

        carry = self._leaf(batch, w)
        level = 0
        # binary-counter carry: occupancy after n pushes == bits of n
        while level < cfg.levels and self._occupied[level]:
            carry = self._reduce(self._bucket(level), carry)
            self._set_bucket(level, None)
            level += 1
        if level == cfg.levels:
            # overflow: fold into the top bucket in place (memory stays
            # bounded; error grows only if levels was undersized for n)
            top = cfg.levels - 1
            self._set_bucket(top, carry)
        else:
            self._set_bucket(level, carry)
        self.n_batches += 1

    def occupied_levels(self) -> int:
        return int(self._occupied.sum())

    @property
    def size(self) -> int:
        """Static summary capacity in points (levels * slot)."""
        return self.config.levels * self.config.slot

    def max_summary_points(self) -> int:
        """Occupied capacity: the ``(t + k) * O(log n)`` bound."""
        return self.occupied_levels() * self.config.slot

    def summary(self) -> Coreset:
        """Any-time eps-coreset of everything pushed so far, as one
        constant-shape ``(levels * slot,)`` weighted point set (vacant
        levels carry weight exactly 0)."""
        cfg = self.config
        return Coreset(points=self._points.reshape(-1, cfg.d),
                       weights=self._weights.reshape(-1))

    def compact_summary(self) -> Coreset:
        """Summary with weight-carrying slots packed to the front and
        truncated to the occupied capacity (smaller downstream solves; shape
        changes as levels fill, so prefer :meth:`summary` under jit)."""
        cap = max(self.max_summary_points(), 1)
        return self.summary().compact(cap)

    def bucket_sizes(self) -> List[int]:
        """Nonzero-weight slot count per level (diagnostics)."""
        counts = np.asarray(jnp.sum(self._weights != 0.0, axis=1))
        return [int(c) for c in counts]
