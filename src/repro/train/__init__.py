from repro.train import loss, pipeline, train_step
from repro.train.loss import lm_loss
from repro.train.train_step import TrainConfig, init_state, make_train_step

__all__ = ["loss", "pipeline", "train_step", "lm_loss", "TrainConfig",
           "init_state", "make_train_step"]
