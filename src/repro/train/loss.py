"""Cross-entropy loss with padded-vocab masking, z-loss and MoE aux loss.

Two evaluation paths: :func:`lm_loss` over full logits, and
:func:`chunked_lm_loss` which applies the LM head + CE one sequence chunk
at a time under remat -- the (B, L, vocab) f32 logits tensor (2-34 GB for
the assigned configs) never materializes, in forward OR backward."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import lm_head_apply

Array = jax.Array


def lm_loss(
    logits: Array,            # (B, L, vocab_padded) f32
    labels: Array,            # (B, L) i32
    cfg: ModelConfig,
    mask: Optional[Array] = None,
    aux: Optional[Array] = None,
    z_coef: float = 1e-4,
) -> Tuple[Array, Dict[str, Array]]:
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        pad = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)                     # (B, L)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    m = jnp.ones_like(nll) if mask is None else mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m), 1.0)
    ce = jnp.sum(nll * m) / denom
    zl = jnp.sum(jnp.square(lse) * m) / denom
    total = ce + z_coef * zl
    metrics = {"ce": ce, "z_loss": zl,
               "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}
    if aux is not None:
        total = total + cfg.router_aux_coef * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = total
    return total, metrics


def chunked_lm_loss(
    head_params: Dict[str, Array],
    hidden: Array,            # (B, L, d) -- final-norm output
    labels: Array,            # (B, L) i32
    cfg: ModelConfig,
    chunk: int = 512,
    aux: Optional[Array] = None,
    z_coef: float = 1e-4,
) -> Tuple[Array, Dict[str, Array]]:
    """CE computed scanning over sequence chunks; the per-chunk logits are
    recomputed in the backward pass (jax.checkpoint), so peak memory holds
    one (B, chunk, vocab) block instead of (B, L, vocab)."""
    B, L, d = hidden.shape
    chunk = min(chunk, L)
    while L % chunk:
        chunk -= 1
    nc = L // chunk
    xs = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        nll_sum, z_sum = carry
        xc, lc = inp
        logits = lm_head_apply(head_params, xc, cfg).astype(jnp.float32)
        if cfg.vocab_padded != cfg.vocab_size:
            pad = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
            logits = jnp.where(pad[None, None, :], -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (nll_sum + jnp.sum(lse - ll),
                z_sum + jnp.sum(jnp.square(lse))), None

    wrapped = jax.checkpoint(body, prevent_cse=False)
    (nll, zl), _ = jax.lax.scan(
        wrapped, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    denom = float(B * L)
    ce = nll / denom
    zl = zl / denom
    total = ce + z_coef * zl
    metrics = {"ce": ce, "z_loss": zl,
               "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}
    if aux is not None:
        total = total + cfg.router_aux_coef * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = total
    return total, metrics
