"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Provided as the PP option for depth-dominated configs (88-layer granite-34b
at small per-pod HBM); the default production configs use FSDPxTP because
every assigned cell fits without PP (DESIGN.md Sec. 6).

Implementation: ``shard_map`` over the stage axis; each stage holds
``n_layers / S`` layers' params; microbatches flow stage-to-stage via
``ppermute`` (fill + steady-state + drain = M + S - 1 ticks). The returned
schedule cost model (bubble fraction (S-1)/(M+S-1)) is unit-tested against
the simulated tick count.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    n_stages: int
    n_microbatches: int

    @property
    def ticks(self) -> int:
        return self.n_microbatches + self.n_stages - 1

    @property
    def bubble_fraction(self) -> float:
        return (self.n_stages - 1) / self.ticks


def pipeline_forward(
    stage_fn: Callable[[PyTree, Array], Array],
    stage_params: PyTree,          # per-device (this stage's) params
    microbatches: Array,           # (M, mb, ...) input microbatches
    axis_name: str,
    n_stages: int,
) -> Array:
    """Run inside shard_map over ``axis_name``. Every device applies its
    stage to the stream; results of the last stage are returned (other
    devices return zeros of the same shape).

    GPipe forward schedule: at tick t, stage s processes microbatch t - s.
    """
    M = microbatches.shape[0]
    stage = jax.lax.axis_index(axis_name)
    ticks = M + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    mb_shape = microbatches.shape[1:]
    out = jnp.zeros((M,) + mb_shape, microbatches.dtype)

    def tick(carry, t):
        inflight, out = carry
        # stage 0 ingests microbatch t (if any)
        mb_idx = jnp.clip(t, 0, M - 1)
        fresh = microbatches[mb_idx]
        x = jnp.where(stage == 0, fresh, inflight)
        y = stage_fn(stage_params, x)
        # last stage writes result for microbatch t - (S-1)
        out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
        write = (stage == n_stages - 1) & (t >= n_stages - 1)
        out = jax.lax.cond(
            write,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
            lambda o: o, out)
        # pass activations downstream
        nxt = jax.lax.ppermute(y, axis_name, perm)
        return (nxt, out), None

    init = jnp.zeros(mb_shape, microbatches.dtype)
    (_, out), _ = jax.lax.scan(tick, (init, out), jnp.arange(ticks))
    return out
