"""Training step factory: microbatched grad accumulation (lax.scan), remat,
global-norm clip, AdamW, schedule -- all jit-compatible and GSPMD-shardable.

``make_train_step(cfg, tc)`` returns a pure ``(params, opt_state, batch,
step) -> (params, opt_state, metrics)`` suitable for ``jax.jit`` with
NamedShardings (the dry run lowers exactly this function).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import forward, make_positions
from repro.models.config import ModelConfig
from repro.optim import adamw, schedule
from repro.train.loss import lm_loss

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1            # grad accumulation steps
    remat: str = "full"              # "none" | "full"
    z_coef: float = 1e-4
    bf16_params: bool = False        # bf16 compute params + f32 master in
                                     # the optimizer (halves FSDP gather and
                                     # grad-reduce bytes)
    loss_chunk: int = 0              # >0: chunked CE (never materializes
                                     # the (B, L, vocab) logits)
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()


def loss_fn(params: PyTree, tokens: Array, labels: Array,
            cfg: ModelConfig, tc: TrainConfig
            ) -> Tuple[Array, Dict[str, Array]]:
    pos = make_positions(tokens, cfg)
    if tc.loss_chunk > 0:
        from repro.train.loss import chunked_lm_loss
        hidden, _, aux = forward(params, tokens, pos, cfg, remat=tc.remat,
                                 head=False)
        head_p = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        return chunked_lm_loss(head_p, hidden, labels, cfg,
                               chunk=tc.loss_chunk, aux=aux,
                               z_coef=tc.z_coef)
    logits, _, aux = forward(params, tokens, pos, cfg, remat=tc.remat)
    return lm_loss(logits, labels, cfg, aux=aux, z_coef=tc.z_coef)


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params: PyTree, opt_state: PyTree,
                   batch: Dict[str, Array], step: Array
                   ) -> Tuple[PyTree, PyTree, Dict[str, Array]]:
        tokens, labels = batch["tokens"], batch["labels"]
        B = tokens.shape[0]
        n_mb = tc.microbatches
        assert B % n_mb == 0, (B, n_mb)

        if n_mb == 1:
            (loss, metrics), grads = grad_fn(params, tokens, labels, cfg, tc)
        else:
            mb_tok = tokens.reshape(n_mb, B // n_mb, -1)
            mb_lab = labels.reshape(n_mb, B // n_mb, -1)

            def accum(carry, mb):
                g_acc, m_acc = carry
                (l, m), g = grad_fn(params, mb[0], mb[1], cfg, tc)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            acc_dtype = jnp.bfloat16 if tc.bf16_params else jnp.float32
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              params)
            m0 = {"ce": 0.0, "z_loss": 0.0, "ppl_proxy": 0.0, "loss": 0.0,
                  "moe_aux": 0.0}
            m0 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), m0)
            (grads, metrics), _ = jax.lax.scan(accum, (g0, m0),
                                               (mb_tok, mb_lab))
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            metrics = jax.tree.map(lambda m: m / n_mb, metrics)

        lr = schedule.warmup_cosine(step, tc.peak_lr, tc.warmup_steps,
                                    tc.total_steps)
        params, opt_state, opt_metrics = adamw.update(
            grads, opt_state, params, lr, tc.adamw)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def init_state(key: Array, cfg: ModelConfig,
               tc: Optional[TrainConfig] = None) -> Tuple[PyTree, PyTree]:
    from repro.models import init_params
    params = init_params(key, cfg)
    if tc is not None and tc.bf16_params:
        opt = adamw.init(params, keep_master=True)
        params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        return params, opt
    return params, adamw.init(params)
