"""Asynchronous, failure-prone WAN execution runtime (DESIGN.md Sec. 14).

Layers an asynchronous message-passing runtime over the synchronous
topology execution engine of :mod:`repro.core.message_passing`:

* :mod:`repro.wan.faults` -- :class:`FaultPlan`, the deterministic,
  seed-replayable fault model (dropped links, duplicated deliveries, node
  churn with rejoin) and its surviving-graph algebra.
* :mod:`repro.wan.schedules` -- per-round activation masks: randomized
  gossip (seeded random edge subsets) and per-edge clocks (heterogeneous
  periods derived from ``edge_costs``), composed with the fault masks.
  Everything is precomputed host-side into dense boolean arrays; the scan
  body never mutates Python state.
* :mod:`repro.wan.runtime` -- the jitted send-once relay scan
  (:func:`wan_flood_exec`), the measured per-round ledgers with the
  ``staleness`` axis, and the faulty Algorithm-1 rounds
  (:func:`async_algorithm1_rounds`) plus the restricted sim oracle.
* :mod:`repro.wan.quiesce` -- quiescence certification: flooding
  completes within the surviving subgraph's diameter after the churn
  horizon, duplicated deliveries leave relay tables bit-unchanged, and
  executed centers under faults equal the oracle's bit-for-bit.
"""
from repro.wan.faults import FaultPlan, random_fault_plan
from repro.wan.runtime import (WanExecResult, async_algorithm1_rounds,
                               restricted_sim_coreset, wan_flood_exec)
from repro.wan.quiesce import QuiescenceCertificate, certify_quiescence

__all__ = [
    "FaultPlan", "random_fault_plan", "WanExecResult", "wan_flood_exec",
    "async_algorithm1_rounds", "restricted_sim_coreset",
    "QuiescenceCertificate", "certify_quiescence",
]
