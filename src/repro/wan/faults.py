"""Deterministic, seed-replayable fault model for the WAN runtime.

A :class:`FaultPlan` is a frozen value object describing three failure
modes (DESIGN.md Sec. 14):

* **dropped links** -- edges that never carry a message (permanent);
* **node churn** -- a node goes down at a round boundary and rejoins at a
  later one (or never: ``rejoin < 0`` means permanently dead). A down
  node neither sends nor receives but keeps its local state; the fault
  model is crash-*pause*, not amnesia;
* **duplicated deliveries** -- with per-slot probability ``dup_rate`` a
  live link re-transmits payloads it has already delivered. Duplicates
  are metered as real traffic but must leave relay tables bit-unchanged
  (the idempotent-relay discipline the quiescence checker certifies).

Everything randomized is drawn from ``np.random.default_rng`` seeded by
``(seed, round, salt)``, so any round prefix replays identically however
many rounds the runtime ends up executing -- the property that lets the
random-gossip mode double its round budget until quiescence without
perturbing history. Plans are applied as precomputed boolean masks inside
the jitted scan, never as Python-side mutation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.topology import Graph, drop_edges, induced_subgraph

_DUP_SALT = 0xD0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One deterministic failure scenario.

    ``drop``: edges (endpoint pairs, either orientation on undirected
    graphs) that are down for the whole run. ``churn``: ``(node, down,
    rejoin)`` triples -- the node is offline during rounds ``[down,
    rejoin)``; ``rejoin < 0`` marks it permanently dead (a non-survivor).
    Round indices are per executed flood: each flood the plan is applied
    to counts its own rounds from 0. ``dup_rate`` is the per-(slot,
    round) duplicate-delivery probability, drawn from ``seed``."""

    drop: Tuple[Tuple[int, int], ...] = ()
    churn: Tuple[Tuple[int, int, int], ...] = ()
    dup_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "drop",
                           tuple((int(i), int(j)) for i, j in self.drop))
        object.__setattr__(self, "churn",
                           tuple((int(v), int(a), int(b))
                                 for v, a, b in self.churn))
        seen = set()
        for v, down, rejoin in self.churn:
            if v in seen:
                raise ValueError(f"node {v} appears twice in churn")
            seen.add(v)
            if down < 0:
                raise ValueError(f"churn down round must be >= 0, got "
                                 f"{down} for node {v}")
            if 0 <= rejoin <= down:
                raise ValueError(f"churn rejoin {rejoin} must exceed down "
                                 f"{down} for node {v} (or be < 0: dead)")
        if not (0.0 <= float(self.dup_rate) < 1.0):
            raise ValueError(f"dup_rate must be in [0, 1), got "
                             f"{self.dup_rate}")

    @property
    def is_trivial(self) -> bool:
        return not self.drop and not self.churn and self.dup_rate == 0.0

    def dead_nodes(self) -> Tuple[int, ...]:
        """Nodes that never rejoin (excluded from every survivor set)."""
        return tuple(sorted(v for v, _, r in self.churn if r < 0))

    def surviving_nodes(self, n: int) -> np.ndarray:
        """Ascending original ids of nodes alive at the end of time."""
        dead = set(self.dead_nodes())
        surv = np.asarray([v for v in range(n) if v not in dead], np.int64)
        if surv.size == 0:
            raise ValueError("fault plan kills every node")
        return surv

    def horizon(self) -> int:
        """First round from which every surviving node is up for good.
        Dead-forever nodes do not extend it (they never come back); a
        plan with no rejoining churn has horizon 0."""
        return max((r for _, _, r in self.churn if r >= 0), default=0)

    def node_up(self, n: int, n_rounds: int) -> np.ndarray:
        """(n_rounds, n) bool: is node v up during round r."""
        up = np.ones((n_rounds, n), bool)
        for v, down, rejoin in self.churn:
            if not 0 <= v < n:
                raise ValueError(f"churn node {v} out of range for n={n}")
            end = n_rounds if rejoin < 0 else min(rejoin, n_rounds)
            up[down:end, v] = False
        return up

    def surviving_graph(self, g: Graph) -> Tuple[Graph, np.ndarray]:
        """The steady-state topology: ``g`` minus dropped links, induced
        on the surviving nodes. Returns ``(sub, index)`` (compact
        relabeling, ``index`` maps sub node -> original id). May be
        disconnected -- the quiescence checker treats that as
        uncertifiable rather than papering over it."""
        return induced_subgraph(drop_edges(g, self.drop),
                                self.surviving_nodes(g.n))

    def dup_masks(self, n: int, max_deg: int, n_rounds: int) -> np.ndarray:
        """(n_rounds, n, max_deg) bool: duplicate-delivery draws per
        out-slot per round, prefix-stable in ``n_rounds``."""
        if self.dup_rate == 0.0:
            return np.zeros((n_rounds, n, max_deg), bool)
        out = np.empty((n_rounds, n, max_deg), bool)
        for r in range(n_rounds):
            rng = np.random.default_rng((self.seed, r, _DUP_SALT))
            out[r] = rng.random((n, max_deg)) < self.dup_rate
        return out


def random_fault_plan(g: Graph, seed: int = 0, drop_frac: float = 0.0,
                      n_churn: int = 0, churn_window: Tuple[int, int] = (1, 4),
                      dead_frac: float = 0.0, dup_rate: float = 0.0,
                      max_tries: int = 64) -> FaultPlan:
    """Sample a :class:`FaultPlan` whose surviving subgraph is connected.

    ``drop_frac`` of the edges are dropped and ``n_churn`` nodes churn
    (each down from a random round in ``churn_window`` for a short
    outage; a ``dead_frac`` fraction of the churned nodes never rejoin).
    Rejection-samples up to ``max_tries`` seeds; if every candidate
    disconnects the survivors, the drop fraction is halved and sampling
    restarts -- the benchmark sweep needs *certifiable* plans, and a plan
    that partitions the graph has no quiescence bound to certify."""
    frac = float(drop_frac)
    for attempt in range(max_tries):
        rng = np.random.default_rng((seed, attempt))
        n_drop = int(round(frac * g.m))
        drop_idx = rng.choice(g.m, size=min(n_drop, g.m), replace=False)
        drops = tuple(g.edges[int(i)] for i in sorted(drop_idx))
        nodes = rng.choice(g.n, size=min(n_churn, g.n), replace=False)
        churn = []
        for c, v in enumerate(sorted(int(x) for x in nodes)):
            down = int(rng.integers(churn_window[0], churn_window[1] + 1))
            if rng.random() < dead_frac:
                churn.append((v, down, -1))
            else:
                churn.append((v, down, down + int(rng.integers(1, 4))))
        plan = FaultPlan(drop=drops, churn=tuple(churn),
                         dup_rate=dup_rate, seed=seed)
        try:
            sub, _ = plan.surviving_graph(g)
            if sub.distances().min() >= 0:
                return plan
        except ValueError:
            pass
        if attempt == max_tries // 2:
            frac /= 2.0
    raise RuntimeError(f"could not sample a connected-survivor fault plan "
                       f"for drop_frac={drop_frac} on a {g.n}-node graph")
