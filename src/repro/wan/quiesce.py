"""Quiescence certification for the WAN runtime (DESIGN.md Sec. 14).

Three properties, each checked by *running* the runtime, never by
trusting the formulas that motivated it:

1. **Completion within the surviving diameter** -- flooding unique
   payloads under the fault plan, every surviving node learns every
   surviving origin no later than round ``H + P * D'`` (churn horizon
   ``H``, surviving-subgraph diameter ``D'``, max edge period ``P``; ``P
   = 1`` for mode ``"full"``), and the flood *quiesces*: the outstanding
   send-once obligations hit zero, after which the measured traffic is
   zero forever. Why the bound holds: from round ``H`` every surviving
   node is permanently up, so any payload held by some survivor crosses
   each remaining hop of the surviving subgraph within one activation
   period -- after ``H`` the schedule degenerates to a (period-dilated)
   synchronous flood on the surviving subgraph. Mode ``"random"`` has no
   deterministic bound and is certified for quiescence only.

2. **Duplicate idempotence** -- re-running the identical plan with a
   positive ``dup_rate`` must deliver strictly more messages yet leave
   every relay table bit-unchanged (relay state is overwrite/max, never
   sum).

3. **Engine-vs-oracle bit-identity** --
   ``graph_distributed_kmeans(engine="exec", faults=plan)`` must return
   centers (and the assembled coreset) bit-identical to the host sim
   oracle restricted to the surviving sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.topology import Graph, diameter
from repro.wan.faults import FaultPlan
from repro.wan.runtime import wan_flood_exec

_DUP_PROBE = 0.35


@dataclasses.dataclass
class QuiescenceCertificate:
    """Evidence record of one certification run. ``ok`` only if every
    checked property held; ``centers_match`` is None when the clustering
    check was skipped (``check_clustering=False``)."""

    mode: str
    horizon: int
    surviving_diameter: int
    max_period: int
    rounds_to_complete: int
    rounds_to_quiesce: int
    bound: Optional[int]          # None for mode="random" (no determinism)
    completed_within_bound: bool
    quiesced: bool
    duplicates_idempotent: bool
    duplicate_messages_extra: float
    centers_match: Optional[bool]
    staleness_mean: float

    @property
    def ok(self) -> bool:
        return (self.completed_within_bound and self.quiesced
                and self.duplicates_idempotent
                and self.centers_match is not False)


def certify_quiescence(g: Graph, plan: FaultPlan, mode: str = "full",
                       seed: int = 0, p: float = 0.5,
                       check_clustering: bool = False,
                       key=None, site_points=None, site_mask=None,
                       k: int = 3, t: int = 24,
                       backend: Optional[str] = None
                       ) -> QuiescenceCertificate:
    """Certify the three WAN-runtime properties for one (graph, plan).

    Raises ``ValueError`` (via the runtime) if the plan disconnects the
    surviving subgraph -- a partitioned deployment has no quiescence
    bound, and the checker refuses to pretend otherwise. With
    ``check_clustering=True`` (needs ``key``/``site_points``/
    ``site_mask``) it additionally runs property 3 end to end, with the
    local solves dispatched through ``backend`` on both sides (the CI
    fault smoke passes ``"pallas"``, interpret mode on CPU)."""
    from repro.wan.schedules import wan_schedule

    sub, _ = plan.surviving_graph(g)
    d_surv = diameter(sub)
    h = plan.horizon()
    ws = wan_schedule(g)
    period = ws.max_period if mode == "clock" else 1

    # distinct per-origin scalars so any mis-relay shows up as a bit diff
    payload = jnp.arange(g.n, dtype=jnp.float32)[:, None] * 1000.0 + 7.0
    base_plan = dataclasses.replace(plan, dup_rate=0.0)
    table, res = wan_flood_exec(g, payload, mode=mode, faults=base_plan,
                                unit_scalars=1.0, seed=seed, p=p)

    bound = None if mode == "random" else h + period * d_surv
    within = True if bound is None else res.rounds_to_complete <= bound
    quiesced = res.rounds_to_quiesce <= res.rounds

    # duplicates: same masks + forced dup draws; tables must not move
    dup_plan = dataclasses.replace(plan, dup_rate=max(plan.dup_rate,
                                                      _DUP_PROBE))
    dtable, dres = wan_flood_exec(g, payload, mode=mode, faults=dup_plan,
                                  unit_scalars=1.0, seed=seed, p=p)
    surv = plan.surviving_nodes(g.n)
    same = bool(np.array_equal(np.asarray(table)[surv][:, surv],
                               np.asarray(dtable)[surv][:, surv]))
    extra = float(dres.ledger.messages - res.ledger.messages)
    idempotent = same and (extra >= 0.0)

    centers_match: Optional[bool] = None
    if check_clustering:
        from repro.core import backend as backend_mod
        from repro.core.distributed import (_solve_on_coreset,
                                            graph_distributed_kmeans)
        from repro.core.coreset import Coreset
        from repro.wan.runtime import restricted_sim_coreset
        import jax

        backend = backend_mod.resolve_name(backend)
        result = graph_distributed_kmeans(
            key, site_points, site_mask, k, t, g, engine="exec",
            faults=plan, wan_mode=mode, wan_seed=seed, wan_p=p,
            backend=backend)
        k1, k2 = jax.random.split(key)
        pts, w, _, _ = restricted_sim_coreset(
            k1, site_points, site_mask, k, t, t_buffer=t,
            objective="kmeans", lloyd_iters=8, clip_negative=False,
            backend=backend, surviving=surv)
        oracle_centers = _solve_on_coreset(k2, Coreset(pts, w), k,
                                           "kmeans", 8, backend)
        centers_match = (
            bool(np.array_equal(np.asarray(result.coreset.points),
                                np.asarray(pts)))
            and bool(np.array_equal(np.asarray(result.coreset.weights),
                                    np.asarray(w)))
            and bool(np.array_equal(np.asarray(result.centers),
                                    np.asarray(oracle_centers))))

    return QuiescenceCertificate(
        mode=mode, horizon=h, surviving_diameter=d_surv, max_period=period,
        rounds_to_complete=res.rounds_to_complete,
        rounds_to_quiesce=res.rounds_to_quiesce, bound=bound,
        completed_within_bound=within, quiesced=quiesced,
        duplicates_idempotent=idempotent, duplicate_messages_extra=extra,
        centers_match=centers_match,
        staleness_mean=res.ledger.staleness)
