"""The asynchronous WAN flood runtime and the faulty Algorithm-1 rounds.

:func:`wan_flood_exec` executes Algorithm 3 under an asynchronous
activation schedule and a :class:`~repro.wan.faults.FaultPlan` as one
jitted ``lax.scan``. The protocol is **send-once relay**: each directed
out-slot ``(v, i)`` keeps per-origin state ``sent[v, i, o]`` and
transmits origin ``o``'s payload at the first live round after ``v``
learns it; receivers overwrite-on-first-receipt, never sum, so every
copy anywhere is a bit-exact relay of the origin's payload and duplicate
deliveries are idempotent by construction (the quiescence checker still
verifies it empirically). Fault and activation masks are dense per-round
boolean inputs -- the scan body contains no Python-side mutation, so a
faulty run is jittable and bit-reproducible from ``(plan, mode, seed)``.

The measured :class:`~repro.core.comm.CommLedger` gains the
``staleness`` axis here: node ``v``'s *completion round* is the first
round after which it knows every tracked (surviving) origin, its sync
baseline is its eccentricity in the lossless timetable
``Graph.distances()``, and ``staleness_v`` is the excess. The ledger
records the mean over surviving nodes; per-round sub-ledgers are filed
as ``wan_round_###`` phases.

Quiescence bounds (proved in DESIGN.md Sec. 14, certified in
:mod:`repro.wan.quiesce`): with a connected surviving subgraph of
diameter ``D'`` and churn horizon ``H``, mode ``"full"`` completes by
round ``H + D'`` and quiesces (no send-once obligation outstanding) one
round later; mode ``"clock"`` multiplies the per-hop latency by the
maximum edge period; mode ``"random"`` has no deterministic bound and
doubles its (prefix-stable) round budget until the pending count hits
zero.

:func:`async_algorithm1_rounds` runs the paper's Algorithm 1 with both
communication rounds under this runtime, restricting the allocation and
the assembled coreset to *surviving* origins -- which is exactly what
makes the result bit-identical to :func:`restricted_sim_coreset`, the
host oracle run on the surviving sites alone.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import strategy as strategy_mod
from repro.core.comm import CommLedger, link_cost_of
from repro.core.coreset import (proportional_allocation, round1_local_solves,
                                round2_local_samples)
from repro.core.message_passing import (Units, _units_ledger, pack_payload,
                                        unpack_payload)
from repro.core.strategy import Round1State, StrategyLike
from repro.core.topology import Graph, diameter
from repro.wan.faults import FaultPlan
from repro.wan.schedules import WanSchedule, liveness_masks, wan_schedule

Array = jax.Array

_MAX_ROUNDS = 4096


@dataclasses.dataclass
class WanExecResult:
    """Outcome of one asynchronous flood.

    ``rounds`` is the executed round count; ``rounds_to_complete`` the
    first round after which every surviving node knew every tracked
    origin; ``rounds_to_quiesce`` the first round after which no
    send-once obligation remained on any usable slot (all traffic ever
    after is zero). ``completion``/``staleness`` are per-node (staleness
    is 0 for non-surviving nodes); ``ledger.staleness`` is the surviving
    mean. ``known`` is the final (node, origin) knowledge table."""

    rounds: int
    rounds_to_complete: int
    rounds_to_quiesce: int
    ledger: CommLedger
    per_round_transmissions: List[int]
    completion: np.ndarray
    staleness: np.ndarray
    known: np.ndarray
    mode: str
    wall_s: float = 0.0


@jax.jit
def _wan_flood_rounds(in_neighbors, in_neighbor_mask, in_slot, payload,
                      live, dup, track, usable):
    """Scan ``live.shape[0]`` asynchronous rounds of send-once relay.

    State: ``known`` (n, n) node-x-origin knowledge, ``sent``
    (n, max_deg, n) per-out-slot send-once flags, ``table`` (n, n, F)
    relayed payload copies. Each round a slot transmits every known,
    not-yet-sent origin if ``live``; ``dup`` forces re-transmission of
    already-sent origins (metered, delivered, idempotent). The receive
    side gathers the *sender's* transmit decisions through ``in_slot``
    (the sender-side slot index of each in-edge), so directed graphs
    relay strictly along link orientation. Emits per-round transmit
    cubes (for host-side float64 ledger pricing), per-node tracked-
    completion flags, and the outstanding send-once count over ``usable``
    steady-state slots (zero == quiesced)."""
    n, f = payload.shape
    eye = jnp.eye(n, dtype=bool)
    table = jnp.where(eye[:, :, None], payload[None, :, :],
                      jnp.zeros((), payload.dtype))
    sent0 = jnp.zeros((n, live.shape[2], n), bool)

    def body(carry, masks):
        known, sent, table = carry
        live_r, dup_r = masks
        want = known[:, None, :] & ~sent & live_r[:, :, None]
        extra = sent & live_r[:, :, None] & dup_r[:, :, None]
        xmit = want | extra
        deliv = xmit[in_neighbors, in_slot] & in_neighbor_mask[:, :, None]
        incoming = jnp.any(deliv, axis=1)                     # (n, n)
        src = jnp.argmax(deliv, axis=1)                       # (n, n)
        recv = jnp.take_along_axis(table[in_neighbors],
                                   src[:, None, :, None], axis=1)[:, 0]
        new = incoming & ~known
        table = jnp.where(new[:, :, None], recv, table)
        known = known | new
        sent = sent | want
        pending = jnp.sum(known[:, None, :] & ~sent & usable[:, :, None])
        done = jnp.all(known | ~track[None, :], axis=1)       # (n,)
        return (known, sent, table), (xmit, done, pending)

    (known, _, table), (xmits, done, pending) = jax.lax.scan(
        body, (eye, sent0, table), (live, dup))
    return table, known, xmits, done, pending


def _round_budget(ws: WanSchedule, mode: str, plan: FaultPlan,
                  d_surv: int) -> int:
    """Deterministic round bound (+1 flush slack) for full/clock modes;
    the starting guess for random mode."""
    h = plan.horizon()
    if mode == "clock":
        return h + ws.max_period * (d_surv + 2)
    return h + d_surv + 2


def wan_flood_exec(graph: Graph, payload: Array, mode: str = "full",
                   faults: Optional[FaultPlan] = None,
                   unit_scalars: Units = 0.0, unit_points: Units = 0.0,
                   dim: int = 0, seed: int = 0, p: float = 0.5,
                   max_rounds: int = _MAX_ROUNDS
                   ) -> Tuple[Array, WanExecResult]:
    """Execute Algorithm 3 asynchronously under faults.

    Same payload/units contract as
    :func:`~repro.core.message_passing.flood_exec`; tracked origins are
    the plan's survivors (all nodes on a trivial plan), and the run
    raises if the surviving subgraph is disconnected or the tracked
    flood fails to complete within the round budget (random mode doubles
    its prefix-stable budget up to ``max_rounds`` first). Returns the
    relay table over *all* nodes -- restrict to surviving rows/origins
    before consuming it; dead origins' columns are whatever partially
    spread before death."""
    plan = faults if faults is not None else FaultPlan()
    ws = wan_schedule(graph)
    t0 = time.perf_counter()
    payload = jnp.asarray(payload)
    if payload.shape[0] != graph.n:
        raise ValueError(f"payload must be origin-indexed: got leading dim "
                         f"{payload.shape[0]} for a {graph.n}-node graph")
    surv = plan.surviving_nodes(graph.n)
    sub, _ = plan.surviving_graph(graph)
    try:
        d_surv = diameter(sub)
    except ValueError as e:
        raise ValueError(f"fault plan disconnects the surviving subgraph "
                         f"({e}); no quiescence bound exists") from e
    track = np.zeros(graph.n, bool)
    track[surv] = True

    trailing = payload.shape[1:]
    flat = payload.reshape(graph.n, -1)
    n_rounds = max(1, _round_budget(ws, mode, plan, d_surv))
    while True:
        live, dup, usable = liveness_masks(ws, mode, n_rounds, plan,
                                           seed=seed, p=p)
        table, known, xmits, done, pending = _wan_flood_rounds(
            jnp.asarray(ws.base.in_neighbors),
            jnp.asarray(ws.base.in_neighbor_mask),
            jnp.asarray(ws.in_slot), flat,
            jnp.asarray(live), jnp.asarray(dup), jnp.asarray(track),
            jnp.asarray(usable))
        pending_np = np.asarray(pending)
        done_np = np.asarray(done)
        quiesced = bool(pending_np[-1] == 0)
        complete = bool(done_np[-1][surv].all())   # the dead owe nothing
        if complete and quiesced:
            break
        if mode == "random" and n_rounds < max_rounds:
            n_rounds = min(2 * n_rounds, max_rounds)   # prefix-stable
            continue
        raise RuntimeError(
            f"wan flood did not {'complete' if not complete else 'quiesce'} "
            f"in {n_rounds} rounds (mode={mode!r}, horizon="
            f"{plan.horizon()}, surviving diameter={d_surv})")

    known_np = np.asarray(known)
    xmits_np = np.asarray(xmits)                 # (rounds, n, deg, n) bool

    # per-node completion round (0 if a node starts complete, e.g. n == 1)
    init_done = (np.eye(graph.n, dtype=bool) | ~track[None, :]).all(axis=1)
    completion = np.empty(graph.n, np.int64)
    for v in range(graph.n):
        if init_done[v]:
            completion[v] = 0
        else:
            hits = np.nonzero(done_np[:, v])[0]
            completion[v] = int(hits[0]) + 1 if hits.size else n_rounds + 1
    rounds_to_complete = int(completion[surv].max()) if surv.size else 0
    q_hits = np.nonzero(pending_np == 0)[0]
    rounds_to_quiesce = int(q_hits[0]) + 1 if q_hits.size else n_rounds

    # staleness vs the synchronous lossless timetable on the full graph
    dist = graph.distances()
    ecc = np.zeros(graph.n, np.int64)
    for v in range(graph.n):
        dv = dist[surv, v]
        ecc[v] = int(dv.max()) if (dv >= 0).all() else 0
    staleness = np.where(track, np.maximum(0, completion - ecc), 0)

    # ledger: totals from the summed counts (canonical float64 pricing),
    # per-round sub-ledgers filed as phases up to quiescence
    nc = np.asarray(ws.base.neighbor_costs, np.float64)
    counts = xmits_np.astype(np.int64)
    total = counts.sum(axis=0)                   # (n, deg, n)
    per_origin = total.sum(axis=(0, 1)).astype(np.float64)
    per_origin_link = (total.astype(np.float64)
                       * nc[:, :, None]).sum(axis=(0, 1))
    ledger = _units_ledger(per_origin, unit_scalars, unit_points, dim,
                           count_all_messages=True,
                           per_origin_link=per_origin_link)
    phases: Dict[str, CommLedger] = {}
    per_round_tx = []
    for r in range(n_rounds):
        cr = counts[r]
        tx = int(cr.sum())
        per_round_tx.append(tx)
        if r < rounds_to_quiesce:
            po = cr.sum(axis=(0, 1)).astype(np.float64)
            pl = (cr.astype(np.float64) * nc[:, :, None]).sum(axis=(0, 1))
            phases[f"wan_round_{r:03d}"] = _units_ledger(
                po, unit_scalars, unit_points, dim,
                count_all_messages=True, per_origin_link=pl)
    mean_stale = float(staleness[surv].mean()) if surv.size else 0.0
    ledger = dataclasses.replace(ledger, staleness=mean_stale,
                                 phases=phases)

    res = WanExecResult(rounds=n_rounds,
                        rounds_to_complete=rounds_to_complete,
                        rounds_to_quiesce=rounds_to_quiesce,
                        ledger=ledger, per_round_transmissions=per_round_tx,
                        completion=completion, staleness=staleness,
                        known=known_np, mode=mode,
                        wall_s=time.perf_counter() - t0)
    return table.reshape((graph.n, graph.n) + trailing), res


# ---------------------------------------------------------------------------
# Algorithm 1 under faults + the restricted sim oracle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AsyncDetail:
    """Per-node state after the faulty executed rounds, restricted to
    surviving origins: the async counterpart of
    :class:`~repro.core.distributed.ExecDetail`. ``surviving`` maps the
    compact survivor axis back to original node ids; ``node_points`` /
    ``node_weights`` are each *surviving* node's assembled coreset over
    surviving origins (rows bit-identical across survivors)."""

    surviving: np.ndarray
    node_points: Array
    node_weights: Array
    node_alloc: Array
    node_totals: Array
    rounds: Dict[str, WanExecResult]


def async_algorithm1_rounds(
    graph: Graph,
    key: Array,
    site_points: Array,
    w_site: Array,
    k: int,
    t: int,
    t_buffer: int,
    objective: str,
    lloyd_iters: int,
    clip_negative: bool,
    backend: str,
    mode: str = "clock",
    faults: Optional[FaultPlan] = None,
    seed: int = 0,
    p: float = 0.5,
    strategy: StrategyLike = None,
) -> Tuple[AsyncDetail, Array]:
    """A strategy's two rounds executed on the WAN runtime. Identical key
    derivation and descriptor hooks as the synchronous exec path (the
    strategy's all-site key table spans *every* site, dead or not --
    per-site stages are independent, which is what keeps survivor-site
    values bit-identical however many peers die); the allocation and the
    assembled coreset are restricted to surviving origins in ascending id
    order, matching :func:`restricted_sim_coreset` bit-for-bit.
    Single-shuffle strategies skip the Round-1 scalar flood entirely:
    survivors each derive the identical uniform split over the survivor
    set locally and normalize by their own scalar, so the only WAN
    traffic is the portions flood. Returns ``(detail, local_costs)``."""
    plan = faults if faults is not None else FaultPlan()
    strat = strategy_mod.get_strategy(strategy)
    n_sites, _, d = site_points.shape
    if graph.n != n_sites:
        raise ValueError(f"graph has {graph.n} nodes for {n_sites} sites")
    surv = plan.surviving_nodes(n_sites)
    keys = strat.keys(key, n_sites)

    r1 = strat.summary(keys[:, 0], site_points, w_site, k=k,
                       objective=objective, lloyd_iters=lloyd_iters,
                       backend=backend)
    local_costs = r1.local_costs

    if strat.needs_exchange:
        # -- Round 1: flood the exchange scalars under faults ----------------
        spec = strat.exchange_spec()
        cost_tables, r1x = wan_flood_exec(graph, local_costs[:, None],
                                          mode=mode, faults=plan,
                                          unit_scalars=spec.unit_scalars,
                                          seed=seed, p=p)
        # every surviving node holds bit-identical copies of every surviving
        # origin's scalar; each replays the strategy's exact allocation over
        # the survivor set (dead origins' partial payloads are discarded)
        costs_at = cost_tables[surv][:, surv, 0]         # (n', n')
        node_alloc = jax.vmap(lambda c: strat.allocate(c, t))(costs_at)
        t_i = jnp.diagonal(node_alloc)                   # own share, (n',)
        node_totals = jax.vmap(jnp.sum)(costs_at)
        rounds = {"round1": r1x}
    else:
        # no scalar flood: every survivor derives the identical uniform
        # split over the survivor set from (n', t) alone
        t_i = strat.allocate(local_costs[surv], t)
        node_alloc = jnp.tile(t_i[None, :], (surv.size, 1))
        node_totals = strat.local_totals(local_costs[surv])
        rounds = {}

    sub = Round1State(r1.centers[surv], r1.m[surv], r1.assign[surv],
                      local_costs[surv], r1.w_eff[surv])
    portions = strat.contribute(
        keys[surv, 1], site_points[surv], sub, t_i, node_totals, k=k, t=t,
        t_buffer=t_buffer, clip_negative=clip_negative)

    # -- Round 2: flood the portions (dead origin slots carry zeros; they
    # are never assembled) ---------------------------------------------------
    slots = portions.points.shape[1]
    payload = jnp.zeros((n_sites, slots, d + 1), portions.points.dtype)
    payload = payload.at[surv].set(pack_payload(portions.points,
                                                portions.weights))
    unit_pts = np.zeros(n_sites, np.float64)
    unit_pts[surv] = np.asarray(t_i, np.float64) + k
    port_tables, r2 = wan_flood_exec(graph, payload, mode=mode, faults=plan,
                                     unit_points=unit_pts, dim=d,
                                     seed=seed + 1, p=p)
    node_pts, node_w = unpack_payload(port_tables[surv][:, surv])
    n_surv = int(surv.size)
    rounds["round2"] = r2
    detail = AsyncDetail(
        surviving=surv,
        node_points=node_pts.reshape(n_surv, n_surv * slots, d),
        node_weights=node_w.reshape(n_surv, n_surv * slots),
        node_alloc=node_alloc, node_totals=node_totals,
        rounds=rounds)
    return detail, local_costs


def restricted_sim_coreset(
    key: Array,
    site_points: Array,
    site_mask: Array,
    k: int,
    t: int,
    t_buffer: int,
    objective: str,
    lloyd_iters: int,
    clip_negative: bool,
    backend: str,
    surviving: np.ndarray,
    strategy: StrategyLike = None,
) -> Tuple[Array, Array, Array, Array]:
    """The host oracle the faulty exec path must reproduce bit-for-bit:
    the strategy's rounds computed globally, with allocation and coreset
    assembly restricted to the ``surviving`` sites (ascending original
    ids). Key derivation spans *all* sites -- survivors must use the same
    per-site keys they would in a fault-free run. Returns ``(points,
    weights, t_i, local_costs)`` with the coreset as the survivors'
    portions concatenated in ascending id order."""
    strat = strategy_mod.get_strategy(strategy)
    n_sites, _, d = site_points.shape
    surviving = np.asarray(surviving, np.int64)
    keys = strat.keys(key, n_sites)
    w_site = site_mask.astype(site_points.dtype)

    r1 = strat.summary(keys[:, 0], site_points, w_site, k=k,
                       objective=objective, lloyd_iters=lloyd_iters,
                       backend=backend)

    costs = r1.local_costs[surviving]
    t_i = strat.allocate(costs, t)
    if strat.needs_exchange:
        total = jnp.sum(costs)
        totals = jnp.full(surviving.size, 1.0, costs.dtype) * total
    else:
        totals = strat.local_totals(costs)

    sub = Round1State(r1.centers[surviving], r1.m[surviving],
                      r1.assign[surviving], costs, r1.w_eff[surviving])
    portions = strat.contribute(
        keys[surviving, 1], site_points[surviving], sub, t_i, totals,
        k=k, t=t, t_buffer=t_buffer, clip_negative=clip_negative)
    pts = portions.points.reshape(-1, d)
    w = portions.weights.reshape(-1)
    return pts, w, t_i, r1.local_costs
