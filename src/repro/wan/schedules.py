"""Asynchronous activation schedules over the padded-neighbor tables.

The synchronous engine fires every edge every round. The WAN runtime
instead precomputes an ``(n_rounds, n, max_deg)`` boolean *liveness* cube
over the PR-4 out-slot layout -- slot ``(v, i)`` is the directed
transmission opportunity ``v -> neighbors[v, i]`` -- as the AND of

* an **activation** pattern (``mode``): ``"full"`` (every edge, every
  round -- the synchronous engine under faults), ``"random"`` (each round
  activates a seeded Bernoulli(p) subset of the *edges*; both directions
  of an undirected edge fire together), or ``"clock"`` (each edge fires
  on its own deterministic clock with period derived from its cost:
  ``period_e = max(1, round(cost_e / min_cost))``, phase seeded per edge
  -- expensive WAN links fire rarely, cheap rack links every round, which
  is what produces the staleness-vs-link-cost tradeoff);
* the **fault masks** of a :class:`~repro.wan.faults.FaultPlan`: dropped
  edges never fire, and a slot is live only while *both* endpoints are
  up (a down node neither sends nor receives).

Every random draw is seeded ``(seed, round, salt)``, so the cube for
``2R`` rounds extends the cube for ``R`` rounds exactly -- the runtime's
double-until-quiescent loop replays history bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.message_passing import GossipSchedule, gossip_schedule
from repro.core.topology import Graph
from repro.wan.faults import FaultPlan

_RANDOM_SALT = 0xA5
_PHASE_SALT = 0xC1


@dataclasses.dataclass(frozen=True, eq=False)
class WanSchedule:
    """The gossip schedule plus the slot algebra the async scan needs.

    ``slot_edge[v, i]`` maps out-slot ``(v, i)`` to its index in
    ``graph.edges`` (-1 on padding); ``in_slot[u, j]`` is the *sender's*
    out-slot index ``i`` with ``neighbors[in_neighbors[u, j], i] == u``,
    which is what lets the receive gather read the sender-side send-once
    state directly. ``periods`` are the per-edge clock periods."""

    graph: Graph
    base: GossipSchedule
    slot_edge: np.ndarray   # (n, max_deg) int32, -1 pad
    in_slot: np.ndarray     # (n, max_in) int32, 0 pad
    periods: np.ndarray     # (m,) int64

    @property
    def max_period(self) -> int:
        return int(self.periods.max()) if self.periods.size else 1


@functools.lru_cache(maxsize=128)
def wan_schedule(g: Graph) -> WanSchedule:
    base = gossip_schedule(g)
    edge_index = {}
    for idx, (i, j) in enumerate(g.edges):
        edge_index[(i, j)] = idx
        if not g.directed:
            edge_index[(j, i)] = idx
    slot_edge = np.full(base.neighbors.shape, -1, np.int32)
    for v in range(base.n):
        for i in range(base.neighbors.shape[1]):
            if base.neighbor_mask[v, i]:
                slot_edge[v, i] = edge_index[(v, int(base.neighbors[v, i]))]
    in_slot = np.zeros(base.in_neighbors.shape, np.int32)
    for u in range(base.n):
        for j in range(base.in_neighbors.shape[1]):
            if base.in_neighbor_mask[u, j]:
                s = int(base.in_neighbors[u, j])
                hits = np.nonzero((base.neighbors[s] == u)
                                  & base.neighbor_mask[s])[0]
                in_slot[u, j] = int(hits[0])   # an in-edge is some out-slot
    costs = np.asarray(g.costs, np.float64)
    pos = costs[costs > 0]
    if pos.size:
        periods = np.maximum(1, np.round(costs / pos.min())).astype(np.int64)
    else:
        periods = np.ones(max(g.m, 0), np.int64)
    return WanSchedule(graph=g, base=base, slot_edge=slot_edge,
                       in_slot=in_slot, periods=periods)


def _edge_to_slots(ws: WanSchedule, edge_mask: np.ndarray) -> np.ndarray:
    """Expand per-edge booleans (..., m) to per-out-slot (..., n, max_deg);
    padding slots come out False."""
    padded = np.concatenate([edge_mask,
                             np.zeros(edge_mask.shape[:-1] + (1,), bool)],
                            axis=-1)
    return padded[..., ws.slot_edge]


def activation_masks(ws: WanSchedule, mode: str, n_rounds: int,
                     seed: int = 0, p: float = 0.5) -> np.ndarray:
    """(n_rounds, n, max_deg) bool activation cube for ``mode`` (faults
    not yet applied). Prefix-stable in ``n_rounds`` for every mode."""
    m = ws.graph.m
    if mode == "full":
        edge = np.ones((n_rounds, m), bool)
    elif mode == "random":
        if not 0.0 < p <= 1.0:
            raise ValueError(f"random gossip needs 0 < p <= 1, got {p}")
        edge = np.empty((n_rounds, m), bool)
        for r in range(n_rounds):
            rng = np.random.default_rng((seed, r, _RANDOM_SALT))
            edge[r] = rng.random(m) < p
    elif mode == "clock":
        phase = np.random.default_rng((seed, _PHASE_SALT)).integers(
            0, ws.periods, size=m) if m else np.zeros(0, np.int64)
        r = np.arange(n_rounds)[:, None]
        edge = (r + phase[None, :]) % ws.periods[None, :] == 0
    else:
        raise ValueError(f"unknown wan mode {mode!r}: expected "
                         f"'full'|'random'|'clock'")
    return _edge_to_slots(ws, edge)


def liveness_masks(ws: WanSchedule, mode: str, n_rounds: int,
                   plan: FaultPlan, seed: int = 0, p: float = 0.5
                   ) -> tuple:
    """Compose activation with the fault plan.

    Returns ``(live, dup, usable)``: ``live`` and ``dup`` are
    ``(n_rounds, n, max_deg)`` per-round send / duplicate masks, and
    ``usable`` is the static ``(n, max_deg)`` steady-state slot mask
    (edge not dropped, both endpoints surviving) -- the slots over which
    send-once obligations must drain for the flood to quiesce."""
    base = ws.base
    n, max_deg = base.neighbors.shape
    alive_edges = np.ones(ws.graph.m, bool)
    if plan.drop:
        edge_set = set(ws.graph.edges)
        norm = set()
        for i, j in plan.drop:
            e = (i, j) if ws.graph.directed else (min(i, j), max(i, j))
            if e not in edge_set:
                raise ValueError(f"fault plan drops {(i, j)}, which is not "
                                 f"an edge of the graph")
            norm.add(e)
        for idx, e in enumerate(ws.graph.edges):
            if e in norm:
                alive_edges[idx] = False
    slot_alive = _edge_to_slots(ws, alive_edges) & base.neighbor_mask

    up = plan.node_up(n, n_rounds)                       # (rounds, n)
    peer_up = up[:, base.neighbors] & base.neighbor_mask[None]
    endpoints_up = up[:, :, None] & peer_up              # (rounds, n, deg)

    active = activation_masks(ws, mode, n_rounds, seed=seed, p=p)
    live = active & slot_alive[None] & endpoints_up
    dup = plan.dup_masks(n, max_deg, n_rounds) & live

    surv = np.zeros(n, bool)
    surv[plan.surviving_nodes(n)] = True
    usable = slot_alive & surv[:, None] & surv[base.neighbors]
    return live, dup, usable
