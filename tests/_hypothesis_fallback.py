"""Deterministic fallback for the ``hypothesis`` property-testing API.

The test suite uses a small slice of hypothesis (``given``, ``settings``,
``strategies.integers`` / ``floats`` / ``sampled_from`` / ``booleans``).
Some execution environments cannot install the real package; this module
provides a drop-in subset so the property tests still *collect and run*
everywhere -- as seeded random sweeps rather than shrinking searches.

``tests/conftest.py`` installs it into ``sys.modules["hypothesis"]`` only
when the real package is missing; with hypothesis installed this module is
inert. The examples are derived from a CRC of the test's qualified name, so
runs are reproducible.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: bool(rnd.getrandbits(1)))


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    return SearchStrategy(lambda rnd: seq[rnd.randrange(len(seq))])


def given(**kw_strategies):
    def decorate(test_fn):
        @functools.wraps(test_fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(
                zlib.crc32(test_fn.__qualname__.encode("utf-8")))
            for _ in range(n):
                drawn = {name: s.example_from(rnd)
                         for name, s in kw_strategies.items()}
                test_fn(*args, **kwargs, **drawn)

        # pytest must not see the strategy-bound params (it would try to
        # resolve them as fixtures): report the signature without them and
        # drop the __wrapped__ shortcut functools.wraps installed.
        sig = inspect.signature(test_fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in kw_strategies]
        del wrapper.__wrapped__
        wrapper.__signature__ = sig.replace(parameters=kept)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=test_fn)
        return wrapper

    return decorate


class settings:
    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, wrapped):
        wrapped._fallback_max_examples = self.max_examples
        return wrapped


def build_module() -> types.ModuleType:
    """Assemble importable ``hypothesis`` + ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.SearchStrategy = SearchStrategy
    hyp.strategies = st
    return hyp
