import os
import sys

# Tests run on the single real CPU device; SPMD tests spawn subprocesses with
# their own XLA_FLAGS (the 512-device dry run must NOT leak in here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests prefer real hypothesis; fall back to the deterministic
# seeded-sweep subset when it is not installed (see _hypothesis_fallback).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import build_module

    _hyp = build_module()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies

import gc

import numpy as np
import pytest


def _vm_map_count() -> int:
    try:
        with open("/proc/self/maps", "rb") as f:
            return sum(1 for _ in f)
    except OSError:  # non-Linux: no map-count limit to guard against
        return 0


@pytest.fixture(scope="module", autouse=True)
def _bounded_jit_code_maps():
    """XLA's CPU JIT mmaps code pages per compiled executable and never
    consolidates them; a full-suite run accumulates enough live
    executables to exhaust ``vm.max_map_count`` (65530 default), at which
    point the next compile segfaults inside LLVM. Dropping
    compiled-executable references between modules once the process nears
    the limit keeps the suite bounded without recompiling on every module
    boundary."""
    yield
    if _vm_map_count() > 40_000:
        import jax

        jax.clear_caches()
        gc.collect()


@pytest.fixture(scope="session")
def gaussian_mixture():
    """Well-separated 5-cluster mixture in R^10 (paper's synthetic setup,
    scaled down)."""
    rng = np.random.default_rng(0)
    k, d, per = 5, 10, 800
    centers = 4.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.1 * rng.standard_normal((per, d)) for i in range(k)]
    ).astype(np.float32)
    return pts, centers
