import os
import sys

# Tests run on the single real CPU device; SPMD tests spawn subprocesses with
# their own XLA_FLAGS (the 512-device dry run must NOT leak in here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests prefer real hypothesis; fall back to the deterministic
# seeded-sweep subset when it is not installed (see _hypothesis_fallback).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import build_module

    _hyp = build_module()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies

import numpy as np
import pytest


@pytest.fixture(scope="session")
def gaussian_mixture():
    """Well-separated 5-cluster mixture in R^10 (paper's synthetic setup,
    scaled down)."""
    rng = np.random.default_rng(0)
    k, d, per = 5, 10, 800
    centers = 4.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.1 * rng.standard_normal((per, d)) for i in range(k)]
    ).astype(np.float32)
    return pts, centers
