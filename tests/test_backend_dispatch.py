"""Unified backend dispatch layer: registry semantics + numerical parity of
the jnp / jnp_chunked / pallas backends across the full pipeline (the
acceptance bar: pallas in interpret mode matches jnp on final coreset
weights and clustering cost within float32 tolerance on a weighted
instance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core.backend import (JnpChunkedBackend, available_backends,
                                get_backend, use_backend)
from repro.core.coreset import build_coreset, distributed_coreset
from repro.core.partition import pad_partition, partition_indices

KEY = jax.random.PRNGKey(0)
BACKENDS = ["jnp", "jnp_chunked", "pallas"]


def _weighted_instance(seed=0, n_per=250, k=4, d=8):
    rng = np.random.default_rng(seed)
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.15 * rng.standard_normal((n_per, d))
         for i in range(k)]).astype(np.float32)
    w = np.abs(rng.standard_normal(len(pts))).astype(np.float32) + 0.1
    return jnp.asarray(pts), jnp.asarray(w), k


# -- registry semantics ------------------------------------------------------

def test_registry_exposes_all_three_backends():
    assert set(BACKENDS) <= set(available_backends())
    for name in BACKENDS:
        assert get_backend(name).name == name


def test_unknown_backend_raises():
    with pytest.raises(KeyError, match="unknown clustering backend"):
        get_backend("triton")


def test_use_backend_context_sets_and_restores_default():
    base = backend_mod.default_backend_name()
    with use_backend("jnp_chunked") as b:
        assert b.name == "jnp_chunked"
        assert backend_mod.default_backend_name() == "jnp_chunked"
        with use_backend("jnp"):
            assert backend_mod.default_backend_name() == "jnp"
        assert backend_mod.default_backend_name() == "jnp_chunked"
    assert backend_mod.default_backend_name() == base


def test_use_backend_plain_call_is_sticky():
    prev = getattr(backend_mod._local, "default", None)
    try:
        use_backend("jnp_chunked")
        assert backend_mod.default_backend_name() == "jnp_chunked"
        use_backend("jnp")
        assert backend_mod.default_backend_name() == "jnp"
    finally:
        backend_mod._local.default = prev


def test_use_backend_stored_instance_reentry_restores_entry_default():
    """Re-entering a stored instance must restore the default *at entry
    time*, not a stale snapshot from construction time."""
    prev = getattr(backend_mod._local, "default", None)
    try:
        ctx = use_backend("jnp_chunked")      # sticky set; snapshot taken now
        backend_mod._local.default = "jnp"    # ambient moves on afterwards
        with use_backend("pallas"):
            with ctx:                          # entered with "pallas" ambient
                assert backend_mod.default_backend_name() == "jnp_chunked"
            # must restore "pallas" (the at-entry default), not the stale
            # construction-time snapshot
            assert backend_mod.default_backend_name() == "pallas"
        assert backend_mod.default_backend_name() == "jnp"
        # reuse the same instance a second time
        with ctx:
            assert backend_mod.default_backend_name() == "jnp_chunked"
        assert backend_mod.default_backend_name() == "jnp"
    finally:
        backend_mod._local.default = prev


def test_use_backend_exception_in_body_still_restores():
    base = backend_mod.default_backend_name()
    with pytest.raises(RuntimeError):
        with use_backend("jnp_chunked"):
            raise RuntimeError("boom")
    assert backend_mod.default_backend_name() == base


def test_use_backend_exit_without_enter_is_noop():
    """A constructed-but-never-entered instance whose __exit__ fires (e.g.
    contextlib.ExitStack unwinding) must not clobber the default."""
    prev = getattr(backend_mod._local, "default", None)
    try:
        backend_mod._local.default = "jnp"
        ctx = use_backend("jnp_chunked")       # sticky set
        ctx.__exit__(None, None, None)         # never entered: no-op
        assert backend_mod.default_backend_name() == "jnp_chunked"
    finally:
        backend_mod._local.default = prev


def test_conflicting_instance_under_registered_name_raises():
    """jit caches key on the backend *name*; a second instance under an
    existing name must fail loudly instead of silently hitting the first
    instance's compiled traces."""
    imposter = JnpChunkedBackend(chunk=7, name="jnp")
    with pytest.raises(ValueError, match="already registered"):
        backend_mod.resolve_name(imposter)


def test_chunk_arg_upgrades_dense_jnp_but_respects_other_backends():
    """chunk bounds the dense jnp path's memory (explicit or ambient); it
    must not override an explicitly or ambiently selected non-jnp backend."""
    pts, w, k = _weighted_instance(n_per=100)
    ctr = pts[:4]
    ref_md, ref_am = clustering.min_dist_argmin(pts, ctr, backend="jnp")
    # explicit jnp + chunk: chunked semantics, same numbers
    md, am = clustering.min_dist_argmin(pts, ctr, chunk=64, backend="jnp")
    np.testing.assert_allclose(np.asarray(md), np.asarray(ref_md),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(ref_am))
    # ambient non-jnp default + chunk: the ambient choice wins
    calls = []
    orig = backend_mod.PallasBackend.min_dist_argmin
    backend_mod.PallasBackend.min_dist_argmin = (
        lambda self, p, c: calls.append(1) or orig(self, p, c))
    try:
        with use_backend("pallas"):
            clustering.min_dist_argmin(pts, ctr, chunk=64)
    finally:
        backend_mod.PallasBackend.min_dist_argmin = orig
    assert calls, "chunk= must not override the ambient pallas backend"


def test_custom_backend_instance_is_registered_and_dispatchable():
    b = JnpChunkedBackend(chunk=64, name="jnp_chunked_64")
    pts, w, k = _weighted_instance()
    ctr = pts[:k]
    md_c, am_c = clustering.min_dist_argmin(pts, ctr, backend=b)
    md_d, am_d = clustering.min_dist_argmin(pts, ctr, backend="jnp")
    np.testing.assert_allclose(np.asarray(md_c), np.asarray(md_d), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(am_c), np.asarray(am_d))
    assert "jnp_chunked_64" in available_backends()


# -- primitive-op parity -----------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_min_dist_argmin_parity(backend):
    pts, w, k = _weighted_instance()
    ctr = pts[: k + 3]
    md, am = clustering.min_dist_argmin(pts, ctr, backend=backend)
    md_ref, am_ref = clustering.min_dist_argmin(pts, ctr, backend="jnp")
    np.testing.assert_allclose(np.asarray(md), np.asarray(md_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(am_ref))


@pytest.mark.parametrize("backend", BACKENDS)
def test_lloyd_stats_parity_weighted(backend):
    pts, w, k = _weighted_instance(seed=1)
    ctr = pts[:6]
    sums, counts, cost = clustering.lloyd_stats(pts, ctr, w, backend=backend)
    sums_r, counts_r, cost_r = clustering.lloyd_stats(pts, ctr, w,
                                                      backend="jnp")
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_r),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_weiszfeld_stats_parity_weighted(backend):
    pts, w, k = _weighted_instance(seed=1)
    ctr = pts[:6] + 0.3  # generic positions
    nums, denoms, cost = clustering.weiszfeld_stats(pts, ctr, w,
                                                    backend=backend)
    nums_r, denoms_r, cost_r = clustering.weiszfeld_stats(pts, ctr, w,
                                                          backend="jnp")
    np.testing.assert_allclose(np.asarray(denoms), np.asarray(denoms_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(nums), np.asarray(nums_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_weiszfeld_stats_parity_coincident_centers(backend):
    """The hard case: centers that are bit-exact copies of data points
    (k-means++ seeds are data points). The exact-form distance + eta
    smoothing must keep every backend's inverse-distance pull identical --
    the matmul-trick distance is pure cancellation noise here and an
    unsmoothed inverse amplifies it by orders of magnitude."""
    pts, w, k = _weighted_instance(seed=2)
    ctr = pts[:6]  # exact coincidences
    nums, denoms, cost = clustering.weiszfeld_stats(pts, ctr, w,
                                                    backend=backend)
    nums_r, denoms_r, cost_r = clustering.weiszfeld_stats(pts, ctr, w,
                                                          backend="jnp")
    np.testing.assert_allclose(np.asarray(denoms), np.asarray(denoms_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(nums), np.asarray(nums_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=1e-4,
                               atol=1e-4)


def test_weiszfeld_signed_weights_discipline():
    """Negative weights contribute their sign to the cost but exert zero
    pull on the median statistics (max(w, 0) membership)."""
    pts, w, k = _weighted_instance(seed=3, n_per=50)
    ctr = pts[:4] + 0.5
    w_signed = w.at[::3].set(-w[::3])
    nums_s, denoms_s, cost_s = clustering.weiszfeld_stats(
        pts, ctr, w_signed, backend="jnp")
    w_clip = jnp.maximum(w_signed, 0.0)
    nums_c, denoms_c, _ = clustering.weiszfeld_stats(
        pts, ctr, w_clip, backend="jnp")
    np.testing.assert_allclose(np.asarray(nums_s), np.asarray(nums_c),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(denoms_s), np.asarray(denoms_c),
                               rtol=1e-6)
    # the signed cost really is signed
    per_pt = clustering.point_costs(pts, ctr, objective="kmedian")[0]
    np.testing.assert_allclose(float(cost_s),
                               float(jnp.sum(w_signed * per_pt)),
                               rtol=1e-3, atol=1e-2)


def test_chunked_backend_actually_chunks_and_matches():
    pts, w, k = _weighted_instance(n_per=300)
    ctr = pts[:5]
    small = JnpChunkedBackend(chunk=128, name="_tmp_chunk128")
    sums, counts, cost = small.lloyd_stats(pts, ctr, w)
    sums_r, counts_r, cost_r = get_backend("jnp").lloyd_stats(pts, ctr, w)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_r),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=1e-5)


# -- end-to-end pipeline parity (acceptance criterion) -----------------------

@pytest.mark.parametrize("objective", ["kmeans", "kmedian"])
@pytest.mark.parametrize("backend", ["jnp_chunked", "pallas"])
def test_lloyd_end_to_end_parity(backend, objective):
    pts, w, k = _weighted_instance(seed=2)
    c0 = clustering.kmeans_pp_init(KEY, pts, k, weights=w,
                                   objective=objective, backend="jnp")
    ref, hist_ref = clustering.lloyd(pts, c0, weights=w, iters=5,
                                     objective=objective, backend="jnp")
    got, hist = clustering.lloyd(pts, c0, weights=w, iters=5,
                                 objective=objective, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hist), np.asarray(hist_ref),
                               rtol=1e-4)


@pytest.mark.parametrize("objective", ["kmeans", "kmedian"])
@pytest.mark.parametrize("backend", ["jnp_chunked", "pallas"])
def test_build_coreset_weight_and_cost_parity(backend, objective):
    """Same key => same draws; the coreset weights and the cost of a probe
    center set must agree with the jnp backend within f32 tolerance.

    k-median runs weiszfeld_iters fused reassignment passes per Lloyd step,
    so backend trajectories accumulate more f32 noise than k-means; a
    boundary-straddling inverse-CDF draw may flip on a non-jnp backend. The
    k-median check therefore tolerates a couple of flipped slots (each flip
    moves one sample's mass between two center-weight slots) while keeping
    the aggregate identities strict."""
    pts, w, k = _weighted_instance(seed=3)
    cs_ref = build_coreset(KEY, pts, k, 100, weights=w, objective=objective,
                           backend="jnp")
    cs = build_coreset(KEY, pts, k, 100, weights=w, objective=objective,
                       backend=backend)
    dw = np.abs(np.asarray(cs.weights) - np.asarray(cs_ref.weights))
    tol = 5e-2 + 1e-3 * np.abs(np.asarray(cs_ref.weights))
    if objective == "kmeans":
        assert np.all(dw <= tol), dw[dw > tol]
    else:
        assert np.sum(dw > tol) <= 4, dw[dw > tol]
    # total signed mass is an exact identity regardless of which slots flip
    np.testing.assert_allclose(float(jnp.sum(cs.weights)),
                               float(jnp.sum(cs_ref.weights)), rtol=1e-4)
    probe = jax.random.normal(jax.random.PRNGKey(7), (k, pts.shape[1]))
    c_ref = float(clustering.cost(cs_ref.points, probe, objective=objective,
                                  weights=cs_ref.weights, backend="jnp"))
    c_got = float(clustering.cost(cs.points, probe, objective=objective,
                                  weights=cs.weights, backend=backend))
    np.testing.assert_allclose(c_got, c_ref,
                               rtol=1e-3 if objective == "kmeans" else 1e-2)


@pytest.mark.parametrize("backend", ["jnp_chunked", "pallas"])
def test_distributed_coreset_weight_and_cost_parity(backend):
    pts, w, k = _weighted_instance(seed=4)
    pts_np = np.asarray(pts)
    idx = partition_indices(pts_np, 5, "weighted", seed=1)
    sp, sm = pad_partition(pts_np, idx)
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    dc_ref = distributed_coreset(KEY, sp, sm, k, 128, backend="jnp")
    dc = distributed_coreset(KEY, sp, sm, k, 128, backend=backend)
    np.testing.assert_array_equal(np.asarray(dc.t_i), np.asarray(dc_ref.t_i))
    np.testing.assert_allclose(np.asarray(dc.weights),
                               np.asarray(dc_ref.weights),
                               rtol=1e-3, atol=5e-2)
    # the final clustering cost on the full data must agree too
    cs_ref, cs = dc_ref.flatten(), dc.flatten()
    c_ref = clustering.kmeans_pp_init(KEY, cs_ref.points, k,
                                      weights=jnp.maximum(cs_ref.weights, 0),
                                      backend="jnp")
    c_ref, _ = clustering.lloyd(cs_ref.points, c_ref,
                                weights=cs_ref.weights, iters=8,
                                backend="jnp")
    c_got = clustering.kmeans_pp_init(KEY, cs.points, k,
                                      weights=jnp.maximum(cs.weights, 0),
                                      backend=backend)
    c_got, _ = clustering.lloyd(cs.points, c_got, weights=cs.weights,
                                iters=8, backend=backend)
    cost_ref = float(clustering.cost(pts, c_ref))
    cost_got = float(clustering.cost(pts, c_got))
    np.testing.assert_allclose(cost_got, cost_ref, rtol=5e-3)


def test_kmedian_chunked_never_materializes_n_k():
    """Peak-shape proof for the acceptance criterion: the full k-median
    Lloyd loop on the chunked backend must not create any intermediate of
    shape (..., n, k) -- the fused weiszfeld_stats path bounds every
    distance/one-hot block at (chunk, k)."""
    n, k, chunk, d = 512, 7, 128, 16
    rng = np.random.default_rng(11)
    pts = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    ctr = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    b = backend_mod.JnpChunkedBackend(chunk, name="_wz_peak_chunk")

    closed = jax.make_jaxpr(
        lambda p, c: clustering.lloyd(p, c, iters=2, objective="kmedian",
                                      backend=b))(pts, ctr)

    def sub_jaxprs(v):
        if hasattr(v, "jaxpr"):          # ClosedJaxpr
            yield v.jaxpr
        elif hasattr(v, "eqns"):         # Jaxpr
            yield v
        elif isinstance(v, (list, tuple)):
            for item in v:
                yield from sub_jaxprs(item)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for var in eqn.outvars:
                shape = tuple(getattr(var.aval, "shape", ()))
                assert shape[-2:] != (n, k), (
                    f"(n, k) intermediate {shape} from {eqn.primitive}")
            for param in eqn.params.values():
                for sub in sub_jaxprs(param):
                    walk(sub)

    walk(closed.jaxpr)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 200), k=st.integers(2, 6),
       seed=st.integers(0, 2**31 - 1))
def test_property_fused_weiszfeld_never_increases_cost(n, k, seed):
    """Each fused pass = reassign (cost down) + one Weiszfeld MM step on
    the new assignment (cost down): the composition must be monotone in
    k-median cost from any seeding, including data-point seeds."""
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((n, 5)).astype(np.float32))
    centers = pts[:k]  # data-point seeds: the Weiszfeld-degenerate case
    prev = float(clustering.cost(pts, centers, objective="kmedian"))
    for _ in range(3):
        centers, _ = clustering.lloyd(pts, centers, iters=1,
                                      objective="kmedian", backend="jnp")
        cur = float(clustering.cost(pts, centers, objective="kmedian"))
        assert cur <= prev * (1.0 + 1e-3) + 1e-4, (cur, prev)
        prev = cur


def test_negative_weight_coreset_solve_all_backends():
    """The final coreset solve runs on a signed measure; every backend must
    keep it finite and consistent."""
    pts, w, k = _weighted_instance(seed=5)
    cs = build_coreset(KEY, pts, k, 80, weights=w, backend="jnp")
    assert float(jnp.min(cs.weights)) < 0.0  # signed measure actually occurs
    c0 = clustering.kmeans_pp_init(KEY, cs.points, k,
                                   weights=jnp.maximum(cs.weights, 0))
    outs = {}
    for b in BACKENDS:
        c, hist = clustering.lloyd(cs.points, c0, weights=cs.weights,
                                   iters=4, backend=b)
        assert np.isfinite(np.asarray(c)).all()
        outs[b] = np.asarray(c)
    np.testing.assert_allclose(outs["jnp_chunked"], outs["jnp"],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs["pallas"], outs["jnp"],
                               rtol=1e-3, atol=1e-3)
