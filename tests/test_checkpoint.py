"""Checkpoint manager: atomic saves, restore, async writer, retention GC,
and elastic resharding via a subprocess with a different device count."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, gc, latest_step, restore,
                              save, steps)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32)),
            "nested": {"b": jnp.arange(10), "c": jnp.asarray(1.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    got, step = restore(str(tmp_path), target=t)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 5, 9, 13):
        save(str(tmp_path), s, t)
    assert latest_step(str(tmp_path)) == 13
    removed = gc(str(tmp_path), keep_last=2)
    assert removed == [1, 5]
    assert steps(str(tmp_path)) == [9, 13]


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    # simulate a crashed write: step dir without COMMIT
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 3
    got, step = restore(str(tmp_path), target=t)
    assert step == 3


def test_async_checkpointer(tmp_path):
    t = _tree()
    ck = AsyncCheckpointer(str(tmp_path), keep_last=2)
    for s in range(1, 6):
        ck.save(s, jax.tree.map(lambda x: x + s, t))
    ck.wait()
    assert steps(str(tmp_path)) == [4, 5]
    got, _ = restore(str(tmp_path), target=t)
    np.testing.assert_allclose(np.asarray(got["a"]),
                               np.asarray(t["a"]) + 5)
    ck.close()


ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save, restore
    root = sys.argv[1]
    mesh = jax.make_mesh((%d,), ("data",))
    sh = NamedSharding(mesh, P("data"))
    t = {"w": jnp.arange(32.0)}
    if "%s" == "save":
        t = jax.device_put(t, {"w": sh})
        save(root, 1, t)
        print("SAVED", len(jax.devices()))
    else:
        got, _ = restore(root, target=t, shardings={"w": sh})
        assert got["w"].sharding.num_devices == %d, got["w"].sharding
        np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(32.0))
        print("RESTORED", len(jax.devices()))
""")


def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Save on a 4-device mesh, restore onto an 8-device mesh (elastic
    scale-up) -- the checkpoint is mesh-agnostic."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    cwd = os.path.dirname(os.path.dirname(__file__))
    r1 = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (4, 4, "save", 4),
         str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=300, cwd=cwd)
    assert "SAVED 4" in r1.stdout, r1.stdout + r1.stderr
    r2 = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (8, 8, "restore", 8),
         str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=300, cwd=cwd)
    assert "RESTORED 8" in r2.stdout, r2.stdout + r2.stderr
