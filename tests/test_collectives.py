"""2-D torus collective schedule, staged-overlap coreset engine, and
per-phase roofline attribution tests (DESIGN.md Sec. 17).

The SPMD parity checks run in subprocesses with forced host devices (the
same idiom as test_core_distributed: jax is already imported in-process,
so device count must be set in a fresh interpreter). Host-side tests cover
the staged engine's strict bit-parity contract, the relaxed-mode
invariants, and the HLO phase parser on a synthetic module.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering, topology
from repro.core.coreset import distributed_coreset, staged_distributed_coreset
from repro.core.message_passing import collective_hops, torus_mesh_shape
from repro.core.partition import pad_partition, partition_indices
from repro.kernels.ops import site_bucket_lengths
from repro.roofline.hlo import collective_phase_analysis

KEY = jax.random.PRNGKey(0)


def _run_spmd_script(script: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "TORUS_OK" in out.stdout, out.stdout + out.stderr


# -- analytic hop model --------------------------------------------------------

def test_torus_mesh_shape_most_square():
    assert torus_mesh_shape(16) == (4, 4)
    assert torus_mesh_shape(8) == (2, 4)
    assert torus_mesh_shape(12) == (3, 4)
    assert torus_mesh_shape(6) == (2, 3)
    assert torus_mesh_shape(7) == (1, 7)       # prime degenerates to the ring
    assert torus_mesh_shape(1) == (1, 1)
    with pytest.raises(ValueError):
        torus_mesh_shape(0)


def test_collective_hops():
    # ring depth for the flat-axis schedules; (R-1)+(C-1) for the folding
    assert collective_hops("all_gather", 16) == 15
    assert collective_hops("neighbor_rounds", 16) == 15
    assert collective_hops("torus_2d", 16) == 6            # (4,4) default
    assert collective_hops("torus_2d", 16, (2, 8)) == 8
    assert collective_hops("torus_2d", 7) == 6             # ring fallback
    # every proper 2-D folding beats the ring once R*C >= 16
    for n in (16, 20, 24, 32, 64):
        assert collective_hops("torus_2d", n) < collective_hops(
            "all_gather", n)
    with pytest.raises(ValueError, match="does not tile"):
        collective_hops("torus_2d", 16, (3, 2))
    with pytest.raises(ValueError, match="unknown collectives"):
        collective_hops("warp", 8)


# -- SPMD parity: torus vs the all_gather oracle (acceptance criterion) -------

TORUS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core import spmd_distributed_kmeans
    from repro.core.distributed import spmd_distributed_kmeans_fn
    from repro.core.message_passing import (collective_hops,
                                            neighbor_rounds_sum,
                                            torus_rounds_gather,
                                            torus_rounds_sum)
    from repro.core.partition import partition_indices, pad_partition
    from repro.roofline.hlo import collective_phase_analysis

    rng = np.random.default_rng(0)
    k, d = 4, 8
    c0 = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate([c0[i] + 0.15 * rng.standard_normal((400, d))
                          for i in range(k)]).astype(np.float32)
    idx = partition_indices(pts, 8, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    mesh = jax.make_mesh((8,), ("sites",))
    t = 256
    key = jax.random.PRNGKey(0)

    # centers/local_costs/t_i bit-identical to the all_gather oracle for
    # BOTH objectives, under the default (2,4) and the transposed (4,2)
    # foldings of the same flat axis
    for objective in ("kmeans", "kmedian"):
        c, lc, t_i = spmd_distributed_kmeans(
            mesh, "sites", key, sp, sm, k, t=t, t_buffer=t,
            objective=objective)
        for mesh_shape in (None, (4, 2)):
            c2, lc2, t_i2 = spmd_distributed_kmeans(
                mesh, "sites", key, sp, sm, k, t=t, t_buffer=t,
                objective=objective, collectives="torus_2d",
                mesh_shape=mesh_shape)
            tag = (objective, mesh_shape)
            assert (np.asarray(c2) == np.asarray(c)).all(), tag
            assert (np.asarray(lc2) == np.asarray(lc)).all(), tag
            assert (np.asarray(t_i2) == np.asarray(t_i)).all(), tag

    # knob validation: a non-tiling folding and a folding without the
    # torus mode both fail loudly
    try:
        spmd_distributed_kmeans(mesh, "sites", key, sp, sm, k, t=t,
                                collectives="torus_2d", mesh_shape=(3, 2))
        raise SystemExit("expected ValueError: mesh_shape does not tile")
    except ValueError as e:
        assert "does not tile" in str(e), e
    try:
        spmd_distributed_kmeans(mesh, "sites", key, sp, sm, k, t=t,
                                mesh_shape=(2, 4))
        raise SystemExit("expected ValueError: mesh_shape without torus")
    except ValueError as e:
        assert "torus" in str(e), e
    try:
        spmd_distributed_kmeans(mesh, "sites", key, sp, sm, k, t=t,
                                collectives="warp")
        raise SystemExit("expected ValueError: unknown collectives")
    except ValueError as e:
        assert "unknown collectives" in str(e), e

    # torus primitives: gather is an exact relay; both explicit sums agree
    # with psum within the documented float tolerance (rtol 1e-6 -- the
    # hop-by-hop association order differs from XLA's reduction) and are
    # bit-exact with themselves across repeated runs (fixed schedule =>
    # deterministic reduction order)
    x = jnp.arange(8, dtype=jnp.float32) * 1.7 + 0.3
    prim = jax.jit(shard_map(
        lambda v: (torus_rounds_gather(v[0], "sites", (2, 4))[None],
                   torus_rounds_sum(v[0], "sites", (2, 4))[None],
                   neighbor_rounds_sum(v[0], "sites", 8)[None],
                   jax.lax.psum(v[0], "sites")[None]),
        mesh=mesh, in_specs=P("sites"), out_specs=P("sites")))
    g1, ts1, ns1, ps = prim(x)
    assert (np.asarray(g1) == np.asarray(x)[None].repeat(8, 0)).all()
    np.testing.assert_allclose(np.asarray(ts1), np.asarray(ps), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ns1), np.asarray(ps), rtol=1e-6)
    g2, ts2, ns2, _ = prim(x)
    assert (np.asarray(g1) == np.asarray(g2)).all()
    assert (np.asarray(ts1) == np.asarray(ts2)).all()
    assert (np.asarray(ns1) == np.asarray(ns2)).all()

    # axis-size / folding validation raises at trace time, not silently
    # wrong answers (the schedule is built from the *claimed* size)
    try:
        jax.jit(shard_map(
            lambda v: neighbor_rounds_sum(v[0], "sites", 4)[None],
            mesh=mesh, in_specs=P("sites"), out_specs=P("sites")))(x)
        raise SystemExit("expected ValueError: axis_size mismatch")
    except ValueError as e:
        assert "disagrees" in str(e), e
    try:
        jax.jit(shard_map(
            lambda v: torus_rounds_sum(v[0], "sites", (2, 2))[None],
            mesh=mesh, in_specs=P("sites"), out_specs=P("sites")))(x)
        raise SystemExit("expected ValueError: folding mismatch")
    except ValueError as e:
        pass

    # compiled-HLO cross-check: the torus program's Round-1 gather issues
    # exactly its analytic hop depth in sequential ppermutes, and Round 2
    # (two gathers) exactly twice that
    fn = spmd_distributed_kmeans_fn("sites", 8, k, t, t,
                                    collectives="torus_2d")
    def device_fn(key, p, m):
        return fn(key, p.reshape(-1, p.shape[-1]), m.reshape(-1))
    hlo = jax.jit(shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(), P("sites"), P("sites")),
        out_specs=(P(), P("sites"), P("sites")),
    )).lower(key, sp, sm).compile().as_text()
    ph = collective_phase_analysis(hlo)
    hops = collective_hops("torus_2d", 8)
    pp1 = int(ph["round1"].collective_counts.get("collective-permute", 0))
    pp2 = int(ph["round2"].collective_counts.get("collective-permute", 0))
    assert pp1 == hops, (pp1, hops)
    assert pp2 == 2 * hops, (pp2, hops)
    print("TORUS_OK")
""")


def test_spmd_torus_parity_8dev():
    _run_spmd_script(TORUS_SCRIPT)


# Non-power-of-two regression: the ring/torus ppermute schedules make no
# power-of-two assumption (unlike recursive-doubling lowerings), so a
# 6-device axis must give the same exact relays and end-to-end parity.
NONPOW2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core import spmd_distributed_kmeans
    from repro.core.message_passing import (neighbor_rounds_gather,
                                            neighbor_rounds_sum,
                                            torus_mesh_shape,
                                            torus_rounds_gather,
                                            torus_rounds_sum)
    from repro.core.partition import partition_indices, pad_partition

    assert torus_mesh_shape(6) == (2, 3)
    mesh = jax.make_mesh((6,), ("sites",))
    x = jnp.arange(6, dtype=jnp.float32) * 0.9 - 1.1
    g_ring, g_torus, s_ring, s_torus, ps = jax.jit(shard_map(
        lambda v: (neighbor_rounds_gather(v[0], "sites", 6)[None],
                   torus_rounds_gather(v[0], "sites", (2, 3))[None],
                   neighbor_rounds_sum(v[0], "sites", 6)[None],
                   torus_rounds_sum(v[0], "sites", (2, 3))[None],
                   jax.lax.psum(v[0], "sites")[None]),
        mesh=mesh, in_specs=P("sites"), out_specs=P("sites")))(x)
    ref = np.asarray(x)[None].repeat(6, 0)
    assert (np.asarray(g_ring) == ref).all()
    assert (np.asarray(g_torus) == ref).all()
    np.testing.assert_allclose(np.asarray(s_ring), np.asarray(ps),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_torus), np.asarray(ps),
                               rtol=1e-6)

    rng = np.random.default_rng(0)
    k, d = 4, 8
    c0 = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate([c0[i] + 0.15 * rng.standard_normal((300, d))
                          for i in range(k)]).astype(np.float32)
    idx = partition_indices(pts, 6, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    sp, sm = jnp.asarray(sp), jnp.asarray(sm)
    key = jax.random.PRNGKey(0)
    t = 192
    c, lc, t_i = spmd_distributed_kmeans(mesh, "sites", key, sp, sm, k,
                                         t=t, t_buffer=t)
    for mode in ("neighbor_rounds", "torus_2d"):
        c2, lc2, t_i2 = spmd_distributed_kmeans(
            mesh, "sites", key, sp, sm, k, t=t, t_buffer=t,
            collectives=mode)
        assert (np.asarray(c2) == np.asarray(c)).all(), mode
        assert (np.asarray(lc2) == np.asarray(lc)).all(), mode
        assert (np.asarray(t_i2) == np.asarray(t_i)).all(), mode
    print("TORUS_OK")
""")


def test_spmd_collectives_nonpow2_6dev():
    _run_spmd_script(NONPOW2_SCRIPT)


# -- staged-overlap coreset engine --------------------------------------------

def _sites(n_sites=6, seed=0, per=150):
    rng = np.random.default_rng(seed)
    k, d = 4, 8
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.15 * rng.standard_normal((per, d)) for i in range(k)]
    ).astype(np.float32)
    idx = partition_indices(pts, n_sites, "weighted", seed=seed + 1)
    sp, sm = pad_partition(pts, idx)
    return pts, jnp.asarray(sp), jnp.asarray(sm), k


_FIELDS = ("points", "weights", "t_i", "local_costs")


@pytest.mark.parametrize("strategy", ["algorithm1", "cohen_addad",
                                      "mapreduce"])
@pytest.mark.parametrize("objective", ["kmeans", "kmedian"])
def test_staged_strict_bit_parity(strategy, objective):
    """With tol=0 and no buckets, every output field of the staged engine
    is bit-identical to the lockstep vmap for every registered strategy --
    the frozen algorithm1 key-derivation/digest contract survives."""
    _, sp, sm, k = _sites()
    t = 200
    base = distributed_coreset(KEY, sp, sm, k, t=t, objective=objective,
                               strategy=strategy)
    staged, detail = staged_distributed_coreset(
        KEY, sp, sm, k, t=t, objective=objective, strategy=strategy)
    for f in _FIELDS:
        a, b = np.asarray(getattr(base, f)), np.asarray(getattr(staged, f))
        assert (a == b).all(), f"{strategy}/{objective}: {f} differs"
    assert detail.site_lengths == (sp.shape[1],) * sp.shape[0]
    assert (np.asarray(detail.iters_run) == 5).all()  # lockstep iter count
    assert detail.wall_round1_s > 0 and detail.wall_round2_s > 0


def test_staged_overlap_mode_deterministic_and_valid():
    """tol>0 + site_buckets trades bit-parity for wall-clock but keeps the
    hard invariants: deterministic across runs, sum(t_i) == t exactly,
    total weight == |P|, per-site lengths power-of-two <= the lockstep pad,
    and coreset quality stays competitive."""
    pts, sp, sm, k = _sites()
    t = 200
    run = lambda: staged_distributed_coreset(
        KEY, sp, sm, k, t=t, tol=1e-3, site_buckets=True)
    cs1, d1 = run()
    cs2, _ = run()
    for f in _FIELDS:
        a, b = np.asarray(getattr(cs1, f)), np.asarray(getattr(cs2, f))
        assert (a == b).all(), f"nondeterministic field {f}"
    assert int(np.asarray(cs1.t_i).sum()) == t
    np.testing.assert_allclose(float(jnp.sum(cs1.weights)), len(pts),
                               rtol=1e-3)
    M = sp.shape[1]
    for ln in d1.site_lengths:
        # each length is a power-of-two bucket, or the lockstep pad M when
        # the bucket would overshoot it (the clamp)
        assert ln <= M and ((ln & (ln - 1)) == 0 or ln == M)
    assert (np.asarray(d1.iters_run) <= 5).all()
    flat = cs1.flatten()
    c, _ = clustering.solve(KEY, flat.points, k,
                            weights=jnp.maximum(flat.weights, 0.0),
                            restarts=3)
    _, full = clustering.solve(KEY, jnp.asarray(pts), k, restarts=4)
    assert float(clustering.cost(jnp.asarray(pts), c) / full) < 1.3


def test_lloyd_converged_strict_matches_lloyd():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.standard_normal((300, 5)).astype(np.float32))
    init = clustering.kmeans_pp_init(KEY, pts, 4)
    ref, _ = clustering.lloyd(pts, init, iters=6)
    out, iters_run = clustering.lloyd_converged(pts, init, iters=6, tol=0.0)
    assert (np.asarray(out) == np.asarray(ref)).all()
    assert int(iters_run) == 6


def test_lloyd_converged_early_exit():
    # well-separated blobs converge in a couple of passes; the while_loop
    # must stop long before the iteration cap, at ~the fixed-point quality
    rng = np.random.default_rng(1)
    blobs = np.concatenate([c + 0.05 * rng.standard_normal((100, 3))
                            for c in (np.zeros(3), 10 * np.ones(3),
                                      -10 * np.ones(3))]).astype(np.float32)
    pts = jnp.asarray(blobs)
    init = clustering.kmeans_pp_init(KEY, pts, 3)
    ref, _ = clustering.lloyd(pts, init, iters=50)
    out, iters_run = clustering.lloyd_converged(pts, init, iters=50,
                                                tol=1e-3)
    assert int(iters_run) < 50
    np.testing.assert_allclose(float(clustering.cost(pts, out)),
                               float(clustering.cost(pts, ref)), rtol=1e-2)


def test_site_bucket_lengths():
    assert site_bucket_lengths((3, 70, 500), 512) == (64, 128, 512)
    # clamped at the lockstep pad even when the bucket would overshoot
    assert site_bucket_lengths((400,), 300) == (300,)
    assert site_bucket_lengths((1,), 512, min_bucket=16) == (16,)


# -- per-phase HLO attribution -------------------------------------------------

_PHASED_HLO = textwrap.dedent("""
    HloModule phases

    %wcond (p.0: (s32[], f32[4])) -> pred[] {
      %p.0 = (s32[], f32[4]) parameter(0)
      %i.0 = s32[] get-tuple-element((s32[], f32[4]) %p.0), index=0
      %t.0 = s32[] constant(3)
      ROOT %lt.0 = pred[] compare(s32[] %i.0, s32[] %t.0), direction=LT
    }

    %wbody (p.1: (s32[], f32[4])) -> (s32[], f32[4]) {
      %p.1 = (s32[], f32[4]) parameter(0)
      %i.1 = s32[] get-tuple-element((s32[], f32[4]) %p.1), index=0
      %b.1 = f32[4] get-tuple-element((s32[], f32[4]) %p.1), index=1
      %cp.1 = f32[4] collective-permute(f32[4] %b.1), source_target_pairs={{0,1},{1,0}}, metadata={op_name="jit(fn)/round1/ppermute"}
      %one.1 = s32[] constant(1)
      %ip.1 = s32[] add(s32[] %i.1, s32[] %one.1)
      ROOT %tup.1 = (s32[], f32[4]) tuple(s32[] %ip.1, f32[4] %cp.1)
    }

    ENTRY %main (x.2: f32[4]) -> f32[32] {
      %x.2 = f32[4] parameter(0)
      %c0.2 = s32[] constant(0)
      %tup.2 = (s32[], f32[4]) tuple(s32[] %c0.2, f32[4] %x.2)
      %w.2 = (s32[], f32[4]) while((s32[], f32[4]) %tup.2), condition=%wcond, body=%wbody
      %g.2 = f32[4] get-tuple-element((s32[], f32[4]) %w.2), index=1
      %ag.2 = f32[32] all-gather(f32[4] %g.2), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}, metadata={op_name="jit(fn)/round2/all_gather"}
      ROOT %un.2 = f32[32] all-gather(f32[4] %g.2), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
    }
""")


def test_collective_phase_analysis_loops_and_scopes():
    """A ppermute inside a 3-trip while body counts 3 sequential issues
    under its named_scope phase; collectives without a phase scope land in
    'other'; non-collective ops contribute nothing."""
    ph = collective_phase_analysis(_PHASED_HLO)
    r1, r2, other = ph["round1"], ph["round2"], ph["other"]
    assert r1.collective_counts == {"collective-permute": 3.0}
    assert r1.ici_collective_bytes > 0
    assert r2.collective_counts == {"all-gather": 1.0}
    assert other.collective_counts == {"all-gather": 1.0}
    # phase matching is by exact path segment: "round1" must not bleed
    # into a custom phase list that doesn't contain it
    ph2 = collective_phase_analysis(_PHASED_HLO, phases=("round2",))
    assert ph2["round2"].collective_counts == {"all-gather": 1.0}
    assert ph2["other"].collective_counts.get("collective-permute") == 3.0
