"""CommLedger unit tests: totals arithmetic and the per-phase breakdown
(`as_dict(by_phase=True)`) used by streaming aggregation rounds."""
import numpy as np

from repro.core.comm import CommLedger, flood_cost, tree_broadcast_cost
from repro.core.topology import bfs_spanning_tree, grid


def test_add_and_bytes_totals():
    a = CommLedger(scalars=3.0, points=10.0, messages=5.0, dim=4)
    b = CommLedger(scalars=1.0, points=2.0, messages=1.0, dim=8)
    c = a.add(b)
    assert c.scalars == 4.0 and c.points == 12.0 and c.messages == 6.0
    assert c.dim == 8
    assert c.bytes == 4.0 * 4.0 + 4.0 * (8 + 1) * 12.0


def test_tag_files_totals_under_phase():
    led = CommLedger(scalars=2.0, points=7.0, messages=3.0, dim=2)
    tagged = led.tag("round_0")
    d = tagged.as_dict(by_phase=True)
    assert d["points"] == 7.0
    assert d["phases"]["round_0"]["points"] == 7.0
    assert d["phases"]["round_0"]["scalars"] == 2.0
    assert d["phases"]["round_0"]["bytes"] == tagged.bytes
    # untagged as_dict has no phases key (backwards compatible)
    assert "phases" not in led.as_dict()
    assert "phases" not in tagged.as_dict()


def test_add_merges_phases_labelwise():
    r0 = CommLedger(points=5.0, dim=3).tag("round_0")
    r1 = CommLedger(points=7.0, scalars=2.0, dim=3).tag("round_1")
    r0b = CommLedger(points=11.0, dim=3).tag("round_0")
    total = r0.add(r1).add(r0b)
    d = total.as_dict(by_phase=True)
    assert d["points"] == 23.0
    assert d["phases"]["round_0"]["points"] == 16.0
    assert d["phases"]["round_1"]["points"] == 7.0
    assert d["phases"]["round_1"]["scalars"] == 2.0
    # phase totals decompose the grand total exactly
    np.testing.assert_allclose(
        sum(p["points"] for p in d["phases"].values()), d["points"])
    np.testing.assert_allclose(
        sum(p["bytes"] for p in d["phases"].values()), d["bytes"])


def test_add_does_not_alias_phase_subledgers():
    r0 = CommLedger(points=5.0, dim=3).tag("round_0")
    other = CommLedger(points=1.0, dim=3).tag("round_0")
    merged = r0.add(other)
    assert merged.phases["round_0"].points == 6.0
    # the inputs' breakdowns are unchanged (add returns fresh copies)
    assert r0.phases["round_0"].points == 5.0
    assert other.phases["round_0"].points == 1.0


def test_tag_collapses_existing_breakdown():
    inner = CommLedger(points=4.0, dim=2).tag("a").add(
        CommLedger(points=6.0, dim=2).tag("b"))
    re = inner.tag("outer")
    d = re.as_dict(by_phase=True)
    assert set(d["phases"]) == {"outer"}
    assert d["phases"]["outer"]["points"] == 10.0


def test_phase_tagging_composes_with_cost_helpers():
    g = grid(3, 3)
    tree = bfs_spanning_tree(g)
    led = (flood_cost(g, n_messages=g.n, unit_scalars=1.0).tag("round1")
           .add(tree_broadcast_cost(tree, unit_points=5.0, dim=4)
                .tag("broadcast")))
    d = led.as_dict(by_phase=True)
    assert d["phases"]["round1"]["scalars"] == 2.0 * g.m * g.n
    assert d["phases"]["broadcast"]["points"] == 5.0 * (tree.n - 1)


def test_link_cost_sums_and_tags():
    a = CommLedger(scalars=3.0, points=10.0, messages=5.0, dim=4,
                   link_cost=100.0)
    b = CommLedger(points=2.0, messages=1.0, dim=4, link_cost=7.0)
    c = a.add(b)
    assert c.link_cost == 107.0
    t = c.tag("phase")
    assert t.link_cost == 107.0
    d = t.as_dict(by_phase=True)
    assert d["link_cost"] == 107.0
    assert d["phases"]["phase"]["link_cost"] == 107.0


def test_link_cost_equals_bytes_on_uniform_costs():
    g = grid(3, 3)
    led = flood_cost(g, n_messages=g.n, unit_scalars=1.0)
    assert led.link_cost == led.bytes
    led2 = flood_cost(g, n_messages=2, unit_points=5.0, dim=7)
    assert led2.link_cost == led2.bytes
    tree = bfs_spanning_tree(g)
    up = tree_broadcast_cost(tree, unit_points=3.0, dim=2)
    assert up.link_cost == up.bytes


def test_link_cost_prices_heterogeneous_links():
    from repro.core.comm import link_cost_of, tree_gather_cost
    from repro.core.topology import heterogeneous
    g = heterogeneous(grid(3, 3), lambda i, j: 4.0)
    led = flood_cost(g, n_messages=g.n, unit_scalars=1.0)
    assert led.link_cost == 4.0 * led.bytes     # every link 4x pricier
    assert led.scalars == 2.0 * g.m * g.n       # unit axes unchanged
    tree = bfs_spanning_tree(g)
    gl = tree_gather_cost(tree, unit_scalars_per_node=1.0)
    assert gl.link_cost == 4.0 * gl.bytes
    # link_cost_of: per-origin weights times per-origin byte sizes
    assert link_cost_of([2.0, 3.0], unit_scalars=[1.0, 10.0]) \
        == 2.0 * 4.0 + 3.0 * 40.0
