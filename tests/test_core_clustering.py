"""Unit tests for the weighted clustering primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import clustering

KEY = jax.random.PRNGKey(0)


def test_pairwise_sq_dists_matches_numpy():
    rng = np.random.default_rng(1)
    p = rng.standard_normal((40, 7)).astype(np.float32)
    c = rng.standard_normal((6, 7)).astype(np.float32)
    got = np.asarray(clustering.pairwise_sq_dists(jnp.asarray(p), jnp.asarray(c)))
    want = ((p[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_min_dist_argmin_chunked_equals_dense():
    rng = np.random.default_rng(2)
    p = jnp.asarray(rng.standard_normal((100, 5)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((8, 5)).astype(np.float32))
    md0, am0 = clustering.min_dist_argmin(p, c)
    md1, am1 = clustering.min_dist_argmin(p, c, chunk=32)
    np.testing.assert_allclose(np.asarray(md0), np.asarray(md1), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(am0), np.asarray(am1))


def test_kmeans_pp_never_selects_zero_weight_points():
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.standard_normal((50, 3)).astype(np.float32))
    w = jnp.concatenate([jnp.ones(25), jnp.zeros(25)])
    for seed in range(5):
        centers = clustering.kmeans_pp_init(jax.random.PRNGKey(seed), pts, 4,
                                            weights=w)
        # every chosen center must be one of the first 25 points
        d2 = clustering.pairwise_sq_dists(centers, pts[:25])
        assert float(jnp.max(jnp.min(d2, axis=1))) < 1e-5


def test_kmeans_pp_all_zero_weights_is_deterministic():
    """Degenerate fully masked instance (an empty site under vmap in
    distributed_coreset): with every logit equal, categorical would seed
    uniformly from padding rows depending on the key; the guard must pin
    every chosen center to row 0 for any key."""
    rng = np.random.default_rng(7)
    pts = jnp.asarray(rng.standard_normal((40, 5)).astype(np.float32))
    w = jnp.zeros((40,))
    for seed in range(5):
        centers = clustering.kmeans_pp_init(jax.random.PRNGKey(seed), pts, 3,
                                            weights=w)
        np.testing.assert_array_equal(np.asarray(centers),
                                      np.tile(np.asarray(pts[0]), (3, 1)))


def test_kmeans_pp_single_positive_weight_point():
    """All remaining mass at distance 0 after the first pick: subsequent
    draws are degenerate too and must stay deterministic and in-range."""
    rng = np.random.default_rng(8)
    pts = jnp.asarray(rng.standard_normal((30, 4)).astype(np.float32))
    w = jnp.zeros((30,)).at[17].set(2.0)
    centers = clustering.kmeans_pp_init(KEY, pts, 4, weights=w)
    # first center is the only weighted point; the rest collapse to row 0
    np.testing.assert_array_equal(np.asarray(centers[0]),
                                  np.asarray(pts[17]))
    assert np.isfinite(np.asarray(centers)).all()


def test_lloyd_cost_nonincreasing(gaussian_mixture):
    pts, _ = gaussian_mixture
    pts = jnp.asarray(pts)
    centers = clustering.kmeans_pp_init(KEY, pts, 5)
    _, hist = clustering.lloyd(pts, centers, iters=8)
    h = np.asarray(hist)
    assert np.all(h[1:] <= h[:-1] + 1e-3 * h[0])


def test_solve_recovers_separated_clusters(gaussian_mixture):
    pts, true_centers = gaussian_mixture
    centers, c = clustering.solve(KEY, jnp.asarray(pts), 5, restarts=4)
    # each true center has a solution center within a small distance
    d2 = clustering.pairwise_sq_dists(jnp.asarray(true_centers.astype(np.float32)),
                                      centers)
    assert float(jnp.max(jnp.min(d2, axis=1))) < 0.1
    # cost close to the generative optimum n*d*sigma^2
    n, d = pts.shape
    assert float(c) < 1.5 * n * d * 0.01


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(20, 60),
    k=st.integers(2, 5),
    mult=st.integers(2, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_weighted_lloyd_equals_replicated_points(n, k, mult, seed):
    """Integer weight w == w replicated copies: the weighted k-means update
    must produce identical centers (invariance of the weighted instance)."""
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((n, 3)).astype(np.float32)
    w = rng.integers(1, mult + 1, size=n)
    rep = np.repeat(pts, w, axis=0)
    centers0 = pts[:k].copy()
    cw, _ = clustering.lloyd(jnp.asarray(pts), jnp.asarray(centers0),
                             weights=jnp.asarray(w.astype(np.float32)), iters=3)
    cr, _ = clustering.lloyd(jnp.asarray(rep), jnp.asarray(centers0), iters=3)
    np.testing.assert_allclose(np.asarray(cw), np.asarray(cr), rtol=2e-3,
                               atol=2e-3)


def test_kmedian_weiszfeld_decreases_cost(gaussian_mixture):
    pts, _ = gaussian_mixture
    pts = jnp.asarray(pts)
    centers = clustering.kmeans_pp_init(KEY, pts, 5, objective="kmedian")
    c0 = clustering.cost(pts, centers, objective="kmedian")
    centers1, _ = clustering.lloyd(pts, centers, iters=5, objective="kmedian")
    c1 = clustering.cost(pts, centers1, objective="kmedian")
    assert float(c1) <= float(c0) * 1.001


def test_negative_weights_do_not_nan():
    rng = np.random.default_rng(5)
    pts = jnp.asarray(rng.standard_normal((30, 4)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(30).astype(np.float32))  # signed
    centers0 = pts[:3]
    centers, hist = clustering.lloyd(pts, centers0, weights=w, iters=4)
    assert np.isfinite(np.asarray(centers)).all()


def test_empty_cluster_keeps_previous_center():
    pts = jnp.asarray(np.array([[0.0, 0], [0, 0.1], [10, 10], [10, 10.1]],
                               dtype=np.float32))
    far = jnp.asarray(np.array([[0, 0], [10, 10], [100, 100]],
                               dtype=np.float32))
    centers, _ = clustering.lloyd(pts, far, iters=2)
    c = np.asarray(centers)
    np.testing.assert_allclose(c[2], [100, 100], atol=1e-6)  # untouched
