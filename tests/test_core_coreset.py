"""Coreset construction tests: exact algebraic identities of Algorithm 1 plus
statistical epsilon-coreset quality (Definition 1 / Theorem 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import clustering
from repro.core.coreset import (build_coreset, distributed_coreset,
                                proportional_allocation, weighted_choice)
from repro.core.partition import pad_partition, partition_indices

KEY = jax.random.PRNGKey(0)


def _mixture(seed=0, n_per=400, k=4, d=6, sigma=0.15):
    rng = np.random.default_rng(seed)
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + sigma * rng.standard_normal((n_per, d)) for i in range(k)]
    ).astype(np.float32)
    return pts


def _sites(pts, n_sites=6, method="weighted", seed=1):
    idx = partition_indices(pts, n_sites, method, seed=seed)
    sp, sm = pad_partition(pts, idx)
    return jnp.asarray(sp), jnp.asarray(sm)


def test_total_weight_preserved_exactly():
    """sum of coreset weights == |P|: the signed center weights are built to
    cancel the sampled mass exactly (Eq. (1) in the paper)."""
    pts = _mixture()
    sp, sm = _sites(pts)
    dc = distributed_coreset(KEY, sp, sm, k=4, t=150)
    total = float(jnp.sum(dc.weights))
    assert abs(total - len(pts)) < 1e-2 * len(pts) * 1e-3 + 0.5


def test_unbiasedness_identity():
    """sum_q w_q m_q == sum_p m_p (each sampled slot contributes exactly
    total_m / t): holds deterministically, not just in expectation."""
    pts = _mixture(seed=2)
    sp, sm = _sites(pts, method="uniform", seed=3)
    dc = distributed_coreset(KEY, sp, sm, k=4, t=128)
    # recompute m for the *sampled* points against their local solutions is
    # awkward post-hoc; instead verify the per-slot invariant: every valid
    # sampled slot has weight w_q = total_m / (t * m_q) => w_q > 0 and the
    # number of valid slots == t.
    n_sites, M, d = sp.shape
    sampled_w = np.asarray(dc.weights[:, :-4])  # t_buffer slots (k=4 centers at end)
    assert int(np.sum(sampled_w > 0)) == int(np.sum(np.asarray(dc.t_i)))
    assert int(np.sum(np.asarray(dc.t_i))) == 128


def test_proportional_allocation_sums_to_t():
    for seed in range(10):
        rng = np.random.default_rng(seed)
        costs = jnp.asarray(np.abs(rng.standard_normal(7)).astype(np.float32))
        t_i = proportional_allocation(costs, 100)
        assert int(jnp.sum(t_i)) == 100
        frac = np.asarray(100 * costs / jnp.sum(costs))
        assert np.all(np.abs(np.asarray(t_i) - frac) <= 1.0 + 1e-5)


def test_proportional_allocation_all_zero_costs_sums_to_t():
    """Degenerate Round 1: every site solves its data exactly (cost 0).
    The allocation must fall back to uniform and still sum exactly to t."""
    for n_sites, t in [(7, 100), (4, 3), (8, 8), (3, 1000)]:
        costs = jnp.zeros((n_sites,), jnp.float32)
        t_i = proportional_allocation(costs, t)
        assert int(jnp.sum(t_i)) == t, (n_sites, t)
        assert int(jnp.min(t_i)) >= 0
        # uniform fallback: no site deviates from t/n by more than 1
        assert np.all(np.abs(np.asarray(t_i) - t / n_sites) <= 1.0)


def test_proportional_allocation_exact_ties_sum_to_t():
    """All sites tie on cost and on fractional part; the largest-remainder
    bonus must hand out exactly the remainder, never more or fewer."""
    for n_sites in (3, 6, 7):
        for t in (10, 99, 100, 101):
            costs = jnp.full((n_sites,), 2.5, jnp.float32)
            t_i = proportional_allocation(costs, t)
            assert int(jnp.sum(t_i)) == t, (n_sites, t)
            assert np.all(np.abs(np.asarray(t_i) - t / n_sites) <= 1.0)


def test_proportional_allocation_single_nonzero_site():
    costs = jnp.asarray([0.0, 0.0, 5.0, 0.0], jnp.float32)
    t_i = np.asarray(proportional_allocation(costs, 64))
    assert t_i.sum() == 64
    assert t_i[2] == 64  # all samples go to the only costly site


@settings(max_examples=80, deadline=None)
@given(n_sites=st.integers(2, 16), t=st.integers(1, 512),
       log_scale=st.integers(-30, 38), seed=st.integers(0, 10_000))
def test_proportional_allocation_sign_safe_across_scales(n_sites, t,
                                                         log_scale, seed):
    """sum(t_i) == t and t_i >= 0 for cost scales 1e-30..1e38: float error
    in the fractions can drive the remainder ``t - sum(floor(frac))``
    out of [0, n): ``t * costs`` overflows to inf around 1e36 (an inf
    fraction floors to garbage, driving the remainder arbitrarily
    *negative*, which the one-sided bonus correction silently turned into
    sum(t_i) != t -- breaking the exact-draw invariant Round 2 depends
    on), and an overflowed total zeroes every fraction (remainder == t >
    n_sites, more than the old single-round bonus could hand out). The
    correction must be sign-safe and capped-take-back / cycling-award
    robust, and never drive an allocation negative."""
    rng = np.random.default_rng(seed)
    costs = (rng.random(n_sites) * (10.0 ** log_scale)).astype(np.float32)
    t_i = np.asarray(proportional_allocation(jnp.asarray(costs), t))
    assert t_i.sum() == t, (costs, t, t_i)
    assert (t_i >= 0).all(), (costs, t, t_i)


def test_proportional_allocation_overflow_regressions():
    """Deterministic extreme-scale cases: near-f32-max costs (the old
    ``t * costs / total`` form made every fraction inf -> sum(t_i) far
    from t) and an inf total (every fraction 0 -> remainder t, which must
    be awarded cyclically, not capped at one per site)."""
    # t * costs overflows, costs/total does not
    costs = jnp.asarray([3e37, 2e37, 1e37], jnp.float32)
    t_i = np.asarray(proportional_allocation(costs, 300))
    assert t_i.sum() == 300 and (t_i >= 0).all(), t_i
    np.testing.assert_allclose(t_i, [150, 100, 50], atol=1)
    # total overflows to inf: fractions all 0, remainder == t > n_sites
    costs = jnp.full((4,), 3.0e38, jnp.float32)
    t_i = np.asarray(proportional_allocation(costs, 10))
    assert t_i.sum() == 10 and (t_i >= 0).all(), t_i


def test_weighted_choice_zero_total_mass_yields_valid_indices():
    """Degenerate single-cluster site: every point sits on its center, all
    sampling masses are exactly 0. Draws must still be in-range indices
    (their weights are zeroed downstream by the total_m > tiny guard)."""
    masses = jnp.zeros((33,), jnp.float32)
    idx = np.asarray(weighted_choice(jax.random.PRNGKey(3), masses, 50))
    assert idx.dtype == np.int32
    assert np.all((idx >= 0) & (idx < 33))


def test_weighted_choice_near_zero_total_mass_no_nan_weights():
    """Masses at the edge of f32 underflow: indices stay valid and the
    downstream sample-weight formula stays finite."""
    masses = jnp.full((16,), 1e-38, jnp.float32)
    idx = weighted_choice(jax.random.PRNGKey(4), masses, 40)
    assert np.all((np.asarray(idx) >= 0) & (np.asarray(idx) < 16))
    # single-site distributed construction over a degenerate instance:
    # all points identical => local cost 0 => no NaN anywhere in the output
    pts = np.zeros((1, 32, 3), dtype=np.float32)
    mask = np.ones((1, 32), dtype=bool)
    dc = distributed_coreset(KEY, jnp.asarray(pts), jnp.asarray(mask),
                             k=2, t=16)
    assert np.isfinite(np.asarray(dc.weights)).all()
    assert np.isfinite(np.asarray(dc.points)).all()


def test_weighted_choice_never_draws_zero_mass_entries():
    masses = jnp.asarray([0.0, 1.0, 0.0, 2.0, 0.0], jnp.float32)
    idx = np.asarray(weighted_choice(jax.random.PRNGKey(5), masses, 500))
    assert set(np.unique(idx)) <= {1, 3}


@pytest.mark.parametrize("objective", ["kmeans", "kmedian"])
def test_empty_site_portion_stays_all_zero_weight(objective):
    """A fully masked site (zero points land on it) must contribute an
    all-zero-weight portion: its local solve runs on an all-zero-weight
    instance under vmap (deterministically seeded from row 0 by the
    kmeans_pp_init degenerate guard), its sensitivities are zero, and no
    sample or center weight may leak out of it."""
    pts = _mixture(seed=12, n_per=200)
    sp, sm = _sites(pts, n_sites=5, method="weighted", seed=13)
    # append a sixth, fully masked site
    sp = jnp.concatenate([sp, jnp.zeros_like(sp[:1])], axis=0)
    sm = jnp.concatenate([sm, jnp.zeros_like(sm[:1])], axis=0)
    dc = distributed_coreset(KEY, sp, sm, k=4, t=128, objective=objective)
    w_empty = np.asarray(dc.weights[-1])
    assert np.all(w_empty == 0.0), w_empty[w_empty != 0.0]
    assert int(dc.t_i[-1]) == 0
    assert float(dc.local_costs[-1]) == 0.0
    assert np.isfinite(np.asarray(dc.points)).all()
    # the other sites are unaffected: total mass and budget still exact
    assert int(jnp.sum(dc.t_i)) == 128
    np.testing.assert_allclose(float(jnp.sum(dc.weights)), len(pts),
                               rtol=1e-4)


@pytest.mark.parametrize("objective", ["kmeans", "kmedian"])
def test_coreset_approximates_cost_on_random_centers(objective):
    """Definition 1: coreset cost within eps of true cost for arbitrary
    center sets (statistical; generous t and tolerance)."""
    pts = _mixture(seed=4)
    sp, sm = _sites(pts, method="weighted", seed=5)
    dc = distributed_coreset(KEY, sp, sm, k=4, t=600, objective=objective)
    cs = dc.flatten()
    pts_j = jnp.asarray(pts)
    max_err = 0.0
    for trial in range(8):
        x = jax.random.normal(jax.random.PRNGKey(100 + trial), (4, pts.shape[1]))
        true_c = float(clustering.cost(pts_j, x, objective=objective))
        cs_c = float(cs.cost(x, objective=objective))
        max_err = max(max_err, abs(cs_c / true_c - 1.0))
    assert max_err < 0.15, f"coreset rel err {max_err}"


def test_coreset_supports_good_solutions():
    """Solving on the coreset gives a solution whose *true* cost is close to
    solving on the full data (Theorem 2's (1+eps)alpha chain)."""
    pts = _mixture(seed=6)
    pts_j = jnp.asarray(pts)
    sp, sm = _sites(pts, method="weighted", seed=7)
    dc = distributed_coreset(KEY, sp, sm, k=4, t=400)
    cs = dc.flatten()
    c_cs = clustering.kmeans_pp_init(KEY, cs.points, 4,
                                     weights=jnp.maximum(cs.weights, 0))
    c_cs, _ = clustering.lloyd(cs.points, c_cs, weights=cs.weights, iters=10)
    _, full_cost = clustering.solve(KEY, pts_j, 4, restarts=4)
    coreset_sol_cost = float(clustering.cost(pts_j, c_cs))
    assert coreset_sol_cost < 1.3 * float(full_cost)


def test_centralized_build_coreset_weight_identities():
    pts = jnp.asarray(_mixture(seed=8))
    cs = build_coreset(KEY, pts, k=4, t=200)
    assert cs.points.shape == (204, pts.shape[1])
    np.testing.assert_allclose(float(jnp.sum(cs.weights)), pts.shape[0],
                               rtol=1e-5)


def test_clip_negative_option():
    pts = jnp.asarray(_mixture(seed=9))
    cs = build_coreset(KEY, pts, k=4, t=200, clip_negative=True)
    assert float(jnp.min(cs.weights)) >= 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_sites=st.integers(2, 8),
       t=st.sampled_from([64, 128, 256]))
def test_property_weight_preservation_any_partition(seed, n_sites, t):
    """Property: for any partition skew and sample budget, the distributed
    coreset preserves total mass and allocates exactly t samples."""
    pts = _mixture(seed=seed, n_per=150, k=3, d=4)
    idx = partition_indices(pts, n_sites, "weighted", seed=seed)
    sp, sm = pad_partition(pts, idx)
    dc = distributed_coreset(jax.random.PRNGKey(seed), jnp.asarray(sp),
                             jnp.asarray(sm), k=3, t=t)
    assert int(jnp.sum(dc.t_i)) == t
    np.testing.assert_allclose(float(jnp.sum(dc.weights)), len(pts),
                               rtol=1e-4)
