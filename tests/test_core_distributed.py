"""End-to-end Algorithm 2 tests (simulation + baselines + SPMD subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (baselines, bfs_spanning_tree, clustering,
                        distributed_kmeans, distributed_kmeans_tree,
                        erdos_renyi, grid)
from repro.core.partition import pad_partition, partition_indices

KEY = jax.random.PRNGKey(0)


def _setup(n_sites=9, method="weighted", seed=0):
    rng = np.random.default_rng(seed)
    k, d = 4, 8
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.15 * rng.standard_normal((500, d)) for i in range(k)]
    ).astype(np.float32)
    idx = partition_indices(pts, n_sites, method, seed=seed + 1)
    sp, sm = pad_partition(pts, idx)
    return pts, jnp.asarray(sp), jnp.asarray(sm), k


def test_distributed_kmeans_quality_and_ledger():
    pts, sp, sm, k = _setup()
    g = erdos_renyi(9, 0.3, seed=3)
    res = distributed_kmeans(KEY, sp, sm, k, t=300, graph=g)
    _, full = clustering.solve(KEY, jnp.asarray(pts), k, restarts=4)
    ratio = float(clustering.cost(jnp.asarray(pts), res.centers) / full)
    assert ratio < 1.25, f"cost ratio {ratio}"
    # Theorem 2 ledger structure: scalars = 2mn, points = 2m * sum|D_i|
    assert res.ledger.scalars == 2 * g.m * g.n
    assert res.ledger.points == 2 * g.m * (300 + g.n * k)


def test_distributed_kmeans_tree_ledger_uses_depths():
    pts, sp, sm, k = _setup()
    g = grid(3, 3)
    tree = bfs_spanning_tree(g, root=0)
    res = distributed_kmeans_tree(KEY, sp, sm, k, t=300, tree=tree)
    # up-pass point traffic bounded by h * sum|D_i|; exact value uses depths
    assert res.ledger.points <= tree.height * (300 + g.n * k) + k * (g.n - 1)
    _, full = clustering.solve(KEY, jnp.asarray(pts), k, restarts=4)
    ratio = float(clustering.cost(jnp.asarray(pts), res.centers) / full)
    assert ratio < 1.25


def test_combine_baseline_quality():
    pts, sp, sm, k = _setup(method="uniform")
    cs = baselines.combine(KEY, sp, sm, k, t_total=300)
    c = clustering.kmeans_pp_init(KEY, cs.points, k,
                                  weights=jnp.maximum(cs.weights, 0))
    c, _ = clustering.lloyd(cs.points, c, weights=cs.weights, iters=10)
    _, full = clustering.solve(KEY, jnp.asarray(pts), k, restarts=4)
    assert float(clustering.cost(jnp.asarray(pts), c) / full) < 1.3


def test_zhang_baseline_runs_and_ledger():
    pts, sp, sm, k = _setup(n_sites=9)
    g = grid(3, 3)
    tree = bfs_spanning_tree(g, root=0)
    cs, ledger = baselines.zhang_tree(KEY, np.asarray(sp), np.asarray(sm),
                                      tree, k, s=80)
    assert ledger.points == (g.n - 1) * (80 + k)
    np.testing.assert_allclose(float(jnp.sum(cs.weights)), len(pts), rtol=1e-3)
    # restarted solve: the assertion targets the coreset's quality, not the
    # luck of one k-means++ seeding on a highly concentrated weighted set
    c, _ = clustering.solve(KEY, cs.points, k, weights=cs.weights,
                            restarts=3)
    _, full = clustering.solve(KEY, jnp.asarray(pts), k, restarts=4)
    assert float(clustering.cost(jnp.asarray(pts), c) / full) < 1.5


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import spmd_distributed_kmeans, clustering
    from repro.core.coreset import proportional_allocation
    from repro.core.message_passing import (neighbor_rounds_gather,
                                            neighbor_rounds_sum)
    from repro.core.partition import partition_indices, pad_partition
    from repro.compat import shard_map

    rng = np.random.default_rng(0)
    k, d = 4, 8
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate([centers[i] + 0.15 * rng.standard_normal((400, d))
                          for i in range(k)]).astype(np.float32)
    idx = partition_indices(pts, 8, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    mesh = jax.make_mesh((8,), ("sites",))
    t = 256
    c, lc, t_i = spmd_distributed_kmeans(mesh, "sites", jax.random.PRNGKey(0),
                                         jnp.asarray(sp), jnp.asarray(sm), k,
                                         t=t, t_buffer=t)
    _, full = clustering.solve(jax.random.PRNGKey(0), jnp.asarray(pts), k,
                               restarts=4)
    ratio = float(clustering.cost(jnp.asarray(pts), c) / full)
    assert ratio < 1.3, f"spmd ratio {ratio}"
    assert np.asarray(lc).shape == (8,)

    # host-vs-SPMD t_i parity: given the same Round-1 scalars, the SPMD
    # allocation must be the host path's exact largest-remainder allocation
    # (sum-to-t invariant; a rounded share can over/under-draw collectively)
    t_i = np.asarray(t_i)
    t_host = np.asarray(proportional_allocation(jnp.asarray(lc), t))
    assert (t_i == t_host).all(), (t_i, t_host)
    assert t_i.sum() == t, t_i

    # Algorithm 3 on the physical ring: swapping the all_gathers for the
    # explicit ppermute neighbour rounds must be bit-for-bit identical
    c_nr, lc_nr, t_i_nr = spmd_distributed_kmeans(
        mesh, "sites", jax.random.PRNGKey(0), jnp.asarray(sp),
        jnp.asarray(sm), k, t=t, t_buffer=t,
        collectives="neighbor_rounds")
    assert (np.asarray(c_nr) == np.asarray(c)).all(), "centers differ"
    assert (np.asarray(lc_nr) == np.asarray(lc)).all()
    assert (np.asarray(t_i_nr) == t_i).all()

    # the ring primitives themselves vs the XLA collectives
    x = jnp.arange(8, dtype=jnp.float32) * 1.7
    gathered, summed = jax.jit(shard_map(
        lambda v: (neighbor_rounds_gather(v[0], "sites", 8)[None],
                   neighbor_rounds_sum(v[0], "sites", 8)[None]),
        mesh=mesh, in_specs=P("sites"), out_specs=P("sites")))(x)
    assert (np.asarray(gathered) == np.asarray(x)[None].repeat(8, 0)).all()
    np.testing.assert_allclose(np.asarray(summed), float(x.sum()), rtol=1e-6)

    # t_buffer regression: with n_sites = 2 * axis_size the device_fn
    # reshape-merge leaves axis_size participating sites, so the default
    # buffer must be sized off axis_size -- no allocation may exceed it
    # (sizing off n_sites made t_i ~ t/axis_size overflow ~ 4t/n_sites
    # and silently truncated draws)
    idx16 = partition_indices(pts, 16, "weighted", seed=2)
    sp16, sm16 = pad_partition(pts, idx16)
    c16, lc16, t_i16 = spmd_distributed_kmeans(
        mesh, "sites", jax.random.PRNGKey(0), jnp.asarray(sp16),
        jnp.asarray(sm16), k, t=t)
    t_buffer_default = max(4 * t // 8, 64)
    t_i16 = np.asarray(t_i16)
    assert t_i16.sum() == t, t_i16
    assert (t_i16 <= t_buffer_default).all(), (t_i16, t_buffer_default)
    t_host16 = np.asarray(proportional_allocation(jnp.asarray(lc16), t))
    assert (t_i16 == t_host16).all(), (t_i16, t_host16)
    ratio16 = float(clustering.cost(jnp.asarray(pts), c16) / full)
    assert ratio16 < 1.3, f"spmd merged-sites ratio {ratio16}"

    # strategy layer on the mesh path: a single-shuffle strategy skips the
    # Round-1 gather -- the budget splits uniformly (largest remainder over
    # equal shares, sum-to-t), and quality stays competitive
    c_mr, lc_mr, t_i_mr = spmd_distributed_kmeans(
        mesh, "sites", jax.random.PRNGKey(0), jnp.asarray(sp),
        jnp.asarray(sm), k, t=t, t_buffer=t, strategy="mapreduce")
    t_i_mr = np.asarray(t_i_mr)
    assert t_i_mr.sum() == t, t_i_mr
    t_uniform = np.asarray(proportional_allocation(jnp.ones(8), t))
    assert (t_i_mr == t_uniform).all(), (t_i_mr, t_uniform)
    ratio_mr = float(clustering.cost(jnp.asarray(pts), c_mr) / full)
    assert ratio_mr < 1.3, f"spmd mapreduce ratio {ratio_mr}"
    print("SPMD_OK", ratio)
""")


def test_spmd_distributed_kmeans_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SPMD_OK" in out.stdout, out.stdout + out.stderr
