"""Graph topology, message passing (Algorithm 3), and partition tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.core.comm import flood_cost, tree_broadcast_cost, tree_up_cost
from repro.core.message_passing import flood, flood_scalars
from repro.core.partition import pad_partition, partition_indices


@pytest.mark.parametrize("maker", [
    lambda s: topology.erdos_renyi(12, 0.3, seed=s),
    lambda s: topology.grid(3, 4),
    lambda s: topology.preferential(12, 2, seed=s),
])
def test_graphs_connected(maker):
    for seed in range(3):
        g = maker(seed)
        res = flood(g)
        assert all(len(r) == g.n for r in res.received), "graph not connected"


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 20), p=st.floats(0.1, 0.9),
       seed=st.integers(0, 10_000))
def test_flood_reaches_everyone_and_counts_2mn(n, p, seed):
    """Algorithm 3: every node ends with all n messages; each node forwards
    each message to all neighbours exactly once => 2*m*n transmissions."""
    g = topology.erdos_renyi(n, p, seed=seed)
    res = flood(g)
    assert all(r == set(range(n)) for r in res.received)
    assert res.transmissions == 2 * g.m * g.n
    assert res.rounds <= topology.diameter(g) + 1


def test_flood_scalars_tables():
    g = topology.grid(3, 3)
    vals = [float(i * i) for i in range(g.n)]
    tables, res = flood_scalars(g, vals)
    for v in range(g.n):
        assert tables[v] == {i: float(i * i) for i in range(g.n)}


def test_flood_scalars_rejects_wrong_length():
    """One scalar per node, validated up front: a short values list used to
    die with a cryptic IndexError mid-flood and a long one was silently
    truncated."""
    g = topology.grid(3, 3)
    with pytest.raises(ValueError, match="one value per node"):
        flood_scalars(g, [1.0] * (g.n - 1))
    with pytest.raises(ValueError, match="one value per node"):
        flood_scalars(g, [1.0] * (g.n + 2))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 25), seed=st.integers(0, 10_000))
def test_bfs_tree_height_vs_diameter(n, seed):
    g = topology.erdos_renyi(n, 0.3, seed=seed)
    diam = topology.diameter(g)
    tree = topology.bfs_spanning_tree(g, root=0)
    assert tree.height <= diam
    assert 2 * tree.height >= diam
    # parent pointers form a tree rooted at 0
    assert tree.parent[0] == -1
    for v in range(1, n):
        assert 0 <= tree.parent[v] < n
        assert tree.depth[v] == tree.depth[tree.parent[v]] + 1


def test_grid_diameter():
    g = topology.grid(4, 4)
    assert topology.diameter(g) == 6  # (rows-1)+(cols-1)


def test_flood_cost_ledger():
    g = topology.grid(3, 3)  # n=9, m=12
    led = flood_cost(g, n_messages=9, unit_scalars=1.0)
    assert led.scalars == 2 * 12 * 9
    led2 = flood_cost(g, n_messages=9, unit_points=10.0, dim=5)
    assert led2.points == 2 * 12 * 90
    assert led2.bytes == 4 * 6 * led2.points


def test_tree_costs():
    g = topology.grid(3, 3)
    tree = topology.bfs_spanning_tree(g, root=0)
    up = tree_up_cost(tree, 7.0, dim=3)
    assert up.points == 7.0 * sum(tree.depth)
    down = tree_broadcast_cost(tree, unit_points=5.0, dim=3)
    assert down.points == 5.0 * (g.n - 1)


@pytest.mark.parametrize("method", ["uniform", "similarity", "weighted"])
def test_partition_is_a_partition(method):
    rng = np.random.default_rng(0)
    data = rng.standard_normal((500, 8)).astype(np.float32)
    idx = partition_indices(data, 7, method, seed=1)
    allix = np.concatenate(idx)
    assert len(allix) == 500
    assert len(np.unique(allix)) == 500
    assert all(len(i) > 0 for i in idx)


def test_degree_partition_skews_to_high_degree():
    g = topology.preferential(10, 2, seed=0)
    deg = g.degrees()
    rng = np.random.default_rng(0)
    data = rng.standard_normal((5000, 4)).astype(np.float32)
    idx = partition_indices(data, g.n, "degree", seed=1, degrees=deg)
    sizes = np.array([len(i) for i in idx])
    # site sizes correlate with degree
    corr = np.corrcoef(sizes, deg)[0, 1]
    assert corr > 0.7


def test_pad_partition_masks():
    rng = np.random.default_rng(0)
    data = rng.standard_normal((100, 3)).astype(np.float32)
    idx = partition_indices(data, 4, "weighted", seed=0)
    sp, sm = pad_partition(data, idx)
    assert sp.shape[0] == 4 and sp.shape[2] == 3
    assert sm.sum() == 100
    # padded slots are zero
    assert np.all(sp[~sm] == 0)
