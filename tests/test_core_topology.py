"""Graph topology, message passing (Algorithm 3), and partition tests."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.core.comm import (flood_cost, tree_allocation_cost,
                             tree_broadcast_cost, tree_gather_cost,
                             tree_up_cost)
from repro.core.message_passing import flood, flood_scalars
from repro.core.partition import pad_partition, partition_indices


@pytest.mark.parametrize("maker", [
    lambda s: topology.erdos_renyi(12, 0.3, seed=s),
    lambda s: topology.grid(3, 4),
    lambda s: topology.preferential(12, 2, seed=s),
])
def test_graphs_connected(maker):
    for seed in range(3):
        g = maker(seed)
        res = flood(g)
        assert all(len(r) == g.n for r in res.received), "graph not connected"


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 20), p=st.floats(0.1, 0.9),
       seed=st.integers(0, 10_000))
def test_flood_reaches_everyone_and_counts_2mn(n, p, seed):
    """Algorithm 3: every node ends with all n messages; each node forwards
    each message to all neighbours exactly once => 2*m*n transmissions."""
    g = topology.erdos_renyi(n, p, seed=seed)
    res = flood(g)
    assert all(r == set(range(n)) for r in res.received)
    assert res.transmissions == 2 * g.m * g.n
    assert res.rounds <= topology.diameter(g) + 1


def test_flood_scalars_tables():
    g = topology.grid(3, 3)
    vals = [float(i * i) for i in range(g.n)]
    tables, res = flood_scalars(g, vals)
    for v in range(g.n):
        assert tables[v] == {i: float(i * i) for i in range(g.n)}


def test_flood_scalars_rejects_wrong_length():
    """One scalar per node, validated up front: a short values list used to
    die with a cryptic IndexError mid-flood and a long one was silently
    truncated."""
    g = topology.grid(3, 3)
    with pytest.raises(ValueError, match="one value per node"):
        flood_scalars(g, [1.0] * (g.n - 1))
    with pytest.raises(ValueError, match="one value per node"):
        flood_scalars(g, [1.0] * (g.n + 2))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 25), seed=st.integers(0, 10_000))
def test_bfs_tree_height_vs_diameter(n, seed):
    g = topology.erdos_renyi(n, 0.3, seed=seed)
    diam = topology.diameter(g)
    tree = topology.bfs_spanning_tree(g, root=0)
    assert tree.height <= diam
    assert 2 * tree.height >= diam
    # parent pointers form a tree rooted at 0
    assert tree.parent[0] == -1
    for v in range(1, n):
        assert 0 <= tree.parent[v] < n
        assert tree.depth[v] == tree.depth[tree.parent[v]] + 1


def test_grid_diameter():
    g = topology.grid(4, 4)
    assert topology.diameter(g) == 6  # (rows-1)+(cols-1)


def test_torus_structure_and_diameter():
    g = topology.torus(4, 4)
    assert g.n == 16 and g.m == 32                 # degree-4 regular
    assert set(g.degrees()) == {4}
    assert topology.diameter(g) == 4               # floor(R/2)+floor(C/2)
    # wraparound halves the grid's diameter ((R-1)+(C-1) -> the above)
    assert topology.diameter(g) < topology.diameter(topology.grid(4, 4))
    res = flood(g)
    assert all(r == set(range(g.n)) for r in res.received)


def test_torus_degenerate_dimensions():
    # a 1 x C (or R x 1) torus is exactly the C-cycle
    assert set(topology.torus(1, 6).edges) == set(topology.ring(6).edges)
    assert topology.torus(6, 1).m == 6
    # a dimension of 2 keeps its wrap edge single (as in ring(2))
    g = topology.torus(2, 3)
    assert g.n == 6 and g.m == 9
    assert max(g.degrees()) == 3
    with pytest.raises(ValueError, match="rows \\* cols"):
        topology.torus(1, 1)


def test_flood_cost_ledger():
    g = topology.grid(3, 3)  # n=9, m=12
    led = flood_cost(g, n_messages=9, unit_scalars=1.0)
    assert led.scalars == 2 * 12 * 9
    led2 = flood_cost(g, n_messages=9, unit_points=10.0, dim=5)
    assert led2.points == 2 * 12 * 90
    assert led2.bytes == 4 * 6 * led2.points


def test_tree_costs():
    g = topology.grid(3, 3)
    tree = topology.bfs_spanning_tree(g, root=0)
    up = tree_up_cost(tree, 7.0, dim=3)
    assert up.points == 7.0 * sum(tree.depth)
    down = tree_broadcast_cost(tree, unit_points=5.0, dim=3)
    assert down.points == 5.0 * (g.n - 1)


@pytest.mark.parametrize("method", ["uniform", "similarity", "weighted"])
def test_partition_is_a_partition(method):
    rng = np.random.default_rng(0)
    data = rng.standard_normal((500, 8)).astype(np.float32)
    idx = partition_indices(data, 7, method, seed=1)
    allix = np.concatenate(idx)
    assert len(allix) == 500
    assert len(np.unique(allix)) == 500
    assert all(len(i) > 0 for i in idx)


def test_degree_partition_skews_to_high_degree():
    g = topology.preferential(10, 2, seed=0)
    deg = g.degrees()
    rng = np.random.default_rng(0)
    data = rng.standard_normal((5000, 4)).astype(np.float32)
    idx = partition_indices(data, g.n, "degree", seed=1, degrees=deg)
    sizes = np.array([len(i) for i in idx])
    # site sizes correlate with degree
    corr = np.corrcoef(sizes, deg)[0, 1]
    assert corr > 0.7


def test_pad_partition_masks():
    rng = np.random.default_rng(0)
    data = rng.standard_normal((100, 3)).astype(np.float32)
    idx = partition_indices(data, 4, "weighted", seed=0)
    sp, sm = pad_partition(data, idx)
    assert sp.shape[0] == 4 and sp.shape[2] == 3
    assert sm.sum() == 100
    # padded slots are zero
    assert np.all(sp[~sm] == 0)


# -- Graph validation (a malformed edge list used to corrupt schedules
# silently; now it raises at construction) -----------------------------------

def test_graph_rejects_self_loop():
    with pytest.raises(ValueError, match="self-loop"):
        topology.Graph(3, ((0, 1), (2, 2)))


def test_graph_rejects_out_of_range_endpoints():
    with pytest.raises(ValueError, match="out of range"):
        topology.Graph(3, ((0, 1), (1, 3)))
    with pytest.raises(ValueError, match="out of range"):
        topology.Graph(3, ((-1, 1),))


def test_graph_rejects_unsorted_and_duplicate_edges():
    with pytest.raises(ValueError, match="unsorted"):
        topology.Graph(3, ((1, 2), (0, 1)))
    with pytest.raises(ValueError, match="duplicate"):
        topology.Graph(3, ((0, 1), (0, 1), (1, 2)))
    with pytest.raises(ValueError, match="min, max"):
        topology.Graph(3, ((1, 0), (1, 2)))


def test_graph_rejects_bad_costs():
    with pytest.raises(ValueError, match="invalid cost"):
        topology.Graph(3, ((0, 1), (1, 2)), edge_costs=(1.0, -2.0))
    with pytest.raises(ValueError, match="invalid cost"):
        topology.Graph(3, ((0, 1), (1, 2)), edge_costs=(float("nan"), 1.0))
    with pytest.raises(ValueError, match="invalid cost"):
        topology.Graph(3, ((0, 1), (1, 2)), edge_costs=(float("inf"), 1.0))
    with pytest.raises(ValueError, match="entries for"):
        topology.Graph(3, ((0, 1), (1, 2)), edge_costs=(1.0,))


def test_graph_directed_allows_both_orientations():
    g = topology.Graph(3, ((0, 1), (1, 2), (2, 0)), directed=True)
    assert g.adjacency() == ((1,), (2,), (0,))
    assert list(g.degrees()) == [1, 1, 1]
    assert topology.diameter(g) == 2
    res = flood(g)
    assert all(r == set(range(3)) for r in res.received)
    assert res.transmissions == g.m * g.n      # out-links only
    led = flood_cost(g, n_messages=g.n, unit_scalars=1.0)
    assert led.scalars == g.m * g.n
    with pytest.raises(ValueError, match="undirected"):
        topology.bfs_spanning_tree(g)


# -- adjacency/degree caching ------------------------------------------------

def test_adjacency_and_degrees_are_cached():
    g = topology.grid(3, 3)
    assert g.adjacency() is g.adjacency()
    assert g.degrees() is g.degrees()
    assert g.weighted_degrees() is g.weighted_degrees()
    assert g.adjacency_costs() is g.adjacency_costs()
    with pytest.raises(ValueError):
        g.degrees()[0] = 99                    # cache is read-only
    np.testing.assert_array_equal(g.weighted_degrees(),
                                  g.degrees().astype(np.float64))


# -- cost accessors and generators -------------------------------------------

def test_uniform_costs_default():
    g = topology.ring(5)
    assert g.is_uniform_cost
    assert g.costs == (1.0,) * g.m
    assert g.cost_of(0, 1) == 1.0 == g.cost_of(1, 0)


def test_heterogeneous_reprices_edges():
    g = topology.heterogeneous(topology.grid(2, 3),
                               lambda i, j: 8.0 if j - i > 1 else 1.0)
    assert not g.is_uniform_cost
    for (i, j), c in zip(g.edges, g.costs):
        assert c == (8.0 if j - i > 1 else 1.0)
        assert g.cost_of(i, j) == c
    # invalid cost functions are caught by Graph validation
    with pytest.raises(ValueError, match="invalid cost"):
        topology.heterogeneous(topology.ring(4), lambda i, j: -1.0)


def test_wan_clusters_structure():
    n_racks, rack_size, cross = 3, 4, 2
    g = topology.wan_clusters(n_racks, rack_size, intra_cost=1.0,
                              cross_cost=16.0, cross_links=cross, seed=0)
    assert g.n == n_racks * rack_size
    intra = [e for e, c in zip(g.edges, g.costs) if c == 1.0]
    wan = [(e, c) for e, c in zip(g.edges, g.costs) if c == 16.0]
    assert len(intra) == n_racks * rack_size * (rack_size - 1) // 2
    assert len(wan) == cross * n_racks * (n_racks - 1) // 2
    for (i, j), _ in wan:
        assert i // rack_size != j // rack_size     # cross links cross racks
    for i, j in intra:
        assert i // rack_size == j // rack_size
    res = flood(g)
    assert all(r == set(range(g.n)) for r in res.received)  # connected
    with pytest.raises(ValueError, match="cross_links"):
        topology.wan_clusters(2, 3, cross_links=0)


# -- spanning trees over costs -----------------------------------------------

def test_spanning_tree_dispatcher():
    g = topology.wan_clusters(2, 3, cross_links=2, seed=1)
    bfs = topology.spanning_tree(g, routing="bfs")
    mst = topology.spanning_tree(g, routing="min_cost")
    assert bfs.parent == topology.bfs_spanning_tree(g).parent
    assert mst.parent == topology.mst_spanning_tree(g).parent
    with pytest.raises(ValueError, match="unknown routing"):
        topology.spanning_tree(g, routing="warp")


def test_tree_parent_costs_track_graph_costs():
    g = topology.wan_clusters(2, 3, cross_links=2, seed=1)
    for tree in (topology.bfs_spanning_tree(g), topology.mst_spanning_tree(g)):
        pc = tree.parent_costs()
        assert pc[tree.root] == 0.0
        for v in range(g.n):
            if tree.parent[v] >= 0:
                assert pc[v] == g.cost_of(tree.parent[v], v)
        # path costs decompose into parent costs; uniform == depth analogue
        assert tree.path_costs()[tree.root] == 0.0
        assert tree.edge_cost_total() == pytest.approx(pc.sum())


def test_mst_min_cost_on_wan():
    """The MST of a wan_clusters graph pays for exactly one cross link per
    attached rack; BFS pays for every shallow entry point."""
    g = topology.wan_clusters(3, 3, cross_cost=16.0, cross_links=3, seed=0)
    bfs = topology.bfs_spanning_tree(g)
    mst = topology.mst_spanning_tree(g)
    n_cross = lambda t: sum(1 for v in range(g.n)
                            if t.parent[v] >= 0 and t.parent_costs()[v] > 1.0)
    assert n_cross(mst) == 2                   # n_racks - 1
    assert n_cross(bfs) > n_cross(mst)
    assert mst.edge_cost_total() < bfs.edge_cost_total()


def _brute_force_mst_cost(g: topology.Graph) -> float:
    best = None
    for combo in itertools.combinations(range(g.m), g.n - 1):
        parent = list(range(g.n))

        def find(a):
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        total, ok = 0.0, True
        for ei in combo:
            i, j = g.edges[ei]
            ri, rj = find(i), find(j)
            if ri == rj:            # cycle: n-1 acyclic edges span iff forest
                ok = False
                break
            parent[ri] = rj
            total += g.costs[ei]
        if ok and (best is None or total < best):
            best = total
    return best


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 7), p=st.floats(0.3, 0.9),
       seed=st.integers(0, 1000), cost_seed=st.integers(0, 1000))
def test_mst_total_cost_is_minimal(n, p, seed, cost_seed):
    """Prim's total equals the brute-force minimum over all spanning trees
    (integer costs, so float equality is exact)."""
    base = topology.erdos_renyi(n, p, seed=seed)
    rng = np.random.default_rng(cost_seed)
    costs = rng.integers(1, 17, size=base.m).astype(np.float64)
    g = topology.Graph(base.n, base.edges, edge_costs=tuple(costs))
    mst = topology.mst_spanning_tree(g)
    assert mst.edge_cost_total() == _brute_force_mst_cost(g)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 20), seed=st.integers(0, 10_000),
       root=st.integers(0, 3))
def test_uniform_cost_mst_is_the_bfs_tree(n, seed, root):
    """On uniform costs Prim's FIFO tie-breaking explores in BFS frontier
    order, so the min-cost tree *is* the BFS tree and every uniform-cost
    min-cost ledger matches the BFS ledger bit-for-bit."""
    g = topology.erdos_renyi(n, 0.3, seed=seed)
    root = root % n
    bfs = topology.bfs_spanning_tree(g, root=root)
    mst = topology.mst_spanning_tree(g, root=root)
    assert bfs.parent == mst.parent
    assert bfs.depth == mst.depth
    units = [float(i % 5) for i in range(n)]
    for lb, lm in [(tree_allocation_cost(bfs), tree_allocation_cost(mst)),
                   (tree_up_cost(bfs, units, dim=3),
                    tree_up_cost(mst, units, dim=3)),
                   (tree_broadcast_cost(bfs, unit_points=4.0, dim=3),
                    tree_broadcast_cost(mst, unit_points=4.0, dim=3))]:
        assert lb.as_dict() == lm.as_dict()
        assert lb.link_cost == lb.bytes        # uniform: weighted == plain


def test_gather_cost_prices_paths_broadcast_prices_edges():
    g = topology.wan_clusters(2, 2, intra_cost=1.0, cross_cost=10.0,
                              cross_links=1, seed=0)
    tree = topology.mst_spanning_tree(g)
    led = tree_gather_cost(tree, unit_scalars_per_node=1.0)
    pc = tree.path_costs()
    assert led.link_cost == 4.0 * pc.sum()
    down = tree_broadcast_cost(tree, unit_scalars=1.0)
    assert down.link_cost == 4.0 * tree.edge_cost_total()


# -- all-pairs distances + fault-plan surgery (WAN runtime groundwork) -------

def test_distances_cached_and_matches_bfs_floods():
    g = topology.wan_clusters(3, 3, cross_links=2, seed=0)
    dist = g.distances()
    assert dist is g.distances()          # cached, one BFS sweep per graph
    assert not dist.flags.writeable
    np.testing.assert_array_equal(np.diag(dist), np.zeros(g.n, np.int64))
    np.testing.assert_array_equal(dist, dist.T)   # undirected symmetry
    assert int(dist.max()) == topology.diameter(g)
    # spot-check one row against the flood round a payload arrives in
    res = flood(g)
    assert res.rounds == int(dist.max()) + 1


def test_distances_directed_are_asymmetric():
    g = topology.Graph(3, ((0, 1), (1, 2), (2, 0)), directed=True)
    dist = g.distances()
    assert dist[0, 2] == 2 and dist[2, 0] == 1    # one-way cycle
    assert topology.diameter(g) == 2
    # weakly- but not strongly-connected: unreachable pairs are -1
    path = topology.Graph(3, ((0, 1), (1, 2)), directed=True)
    d2 = path.distances()
    assert d2[0, 2] == 2 and d2[2, 0] == -1
    with pytest.raises(ValueError, match="strongly connected"):
        topology.diameter(path)


def test_drop_edges_preserves_costs_and_validates():
    g = topology.wan_clusters(2, 3, cross_links=2, seed=1)
    victim = g.edges[0]
    g2 = topology.drop_edges(g, [victim])
    assert g2.m == g.m - 1 and victim not in g2.edges
    for e, c in zip(g2.edges, g2.costs):
        assert c == g.cost_of(*e)
    # either orientation names an undirected edge; unknown edges raise
    g3 = topology.drop_edges(g, [victim[::-1]])
    assert g3.edges == g2.edges
    with pytest.raises(ValueError, match="not an edge"):
        topology.drop_edges(g, [(0, g.n - 1) if (0, g.n - 1) not in g.edges
                                else (1, 2)])


def test_induced_subgraph_relabels_and_keeps_costs():
    g = topology.wan_clusters(2, 3, cross_links=2, seed=1)
    keep = [0, 1, 2, 4, 5]
    sub, index = topology.induced_subgraph(g, keep)
    np.testing.assert_array_equal(index, np.asarray(keep))
    assert sub.n == len(keep)
    for (a, b), c in zip(sub.edges, sub.costs):
        assert c == g.cost_of(int(index[a]), int(index[b]))
    # every surviving edge of g appears exactly once, relabeled
    kept = {tuple(sorted((i, j))) for i, j in g.edges
            if i in set(keep) and j in set(keep)}
    relabeled = {tuple(sorted((int(index[a]), int(index[b]))))
                 for a, b in sub.edges}
    assert relabeled == kept
