"""Coreset-based data selection (the paper's technique in the data plane) +
synthetic data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (BigramLM, embed_examples, gather_selected,
                        paper_dataset, paper_dataset_names, select_coreset)


def test_bigram_batches_deterministic_and_learnable():
    gen = BigramLM(vocab_size=512, seed=0)
    b1 = gen.batch(3, 4, 16)
    b2 = gen.batch(3, 4, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are next tokens
    assert b1["tokens"].shape == (4, 16)
    assert int(jnp.max(b1["tokens"])) < 256  # active vocab slice


def test_paper_dataset_shapes():
    for name in paper_dataset_names():
        pts, k = paper_dataset(name, scale=0.02)
        assert pts.ndim == 2 and np.isfinite(pts).all()
        assert k >= 5


def test_select_coreset_preserves_mass_and_budget():
    rng = np.random.default_rng(0)
    n_sites, M, d = 4, 200, 16
    emb = jnp.asarray(rng.standard_normal((n_sites, M, d)).astype(np.float32))
    mask = jnp.ones((n_sites, M), bool)
    sel = select_coreset(jax.random.PRNGKey(0), emb, mask, k=5, t=100)
    assert int(jnp.sum(sel.t_i)) == 100
    total_w = float(jnp.sum(sel.weights))
    np.testing.assert_allclose(total_w, n_sites * M, rtol=1e-3)
    # indices in range
    assert int(jnp.max(sel.indices)) < M


def test_selection_weighted_cost_approximates_pool_cost():
    """The selected weighted subset approximates the k-means cost of the
    full pool on random centers (Definition 1 applied to embeddings)."""
    rng = np.random.default_rng(1)
    n_sites, M, d = 4, 300, 8
    emb_np = np.concatenate([
        c + 0.3 * rng.standard_normal((n_sites, M // 4, d))
        for c in 3.0 * rng.standard_normal((4, d))], axis=1
    ).astype(np.float32)
    emb = jnp.asarray(emb_np)
    mask = jnp.ones((n_sites, M), bool)
    sel = select_coreset(jax.random.PRNGKey(1), emb, mask, k=4, t=400)
    flat = emb.reshape(-1, d)
    sel_pts = jax.vmap(lambda e, i: e[i])(emb, sel.indices).reshape(-1, d)
    sel_w = sel.weights.reshape(-1)
    from repro.core import clustering
    errs = []
    for trial in range(5):
        x = jax.random.normal(jax.random.PRNGKey(10 + trial), (4, d))
        full = float(clustering.cost(flat, x))
        approx = float(clustering.cost(sel_pts, x, weights=sel_w))
        errs.append(abs(approx / full - 1))
    assert max(errs) < 0.2, errs


def test_gather_selected_layout():
    rng = np.random.default_rng(2)
    n_sites, M, L = 3, 50, 12
    toks = jnp.asarray(rng.integers(0, 100, size=(n_sites, M, L)),
                       jnp.int32)
    emb = jnp.asarray(rng.standard_normal((n_sites, M, 4)).astype(np.float32))
    mask = jnp.ones((n_sites, M), bool)
    sel = select_coreset(jax.random.PRNGKey(2), emb, mask, k=3, t=20)
    out = gather_selected(toks, sel)
    assert out["tokens"].shape == (n_sites * (20 + 3), L)
    assert out["weights"].shape == (n_sites * 23,)


def test_embed_examples_shape():
    table = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((64, 8)).astype(np.float32))
    toks = jnp.asarray(np.random.default_rng(1)
                       .integers(0, 64, size=(2, 5, 10)), jnp.int32)
    emb = embed_examples(table, toks)
    assert emb.shape == (2, 5, 8)
