"""CI-scale dry-run machinery test: the same build_cell/lower/compile/
roofline path as the production 512-device dry run, on an 8-device mesh with
reduced configs (subprocess so the device count doesn't leak)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    import dataclasses
    from repro import configs
    from repro.launch.mesh import make_mesh
    from repro.launch.shapes import ShapeSpec
    from repro.launch import specs as S
    from repro.roofline.report import build_report

    arch, kind = sys.argv[1], sys.argv[2]
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = configs.get_reduced(arch)
    shape = ShapeSpec("ci", kind, seq_len=64,
                      global_batch=8 if kind != "decode" else 8)
    S.SHAPES["ci"] = shape
    cell = S.build_cell(arch, "ci", mesh, cfg_override=cfg)
    compiled = cell.lower().compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()  # dict on new jax, [dict] on old
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    rep = build_report(arch, "ci", "small", cfg, kind, 64, 8, 8,
                       compiled.as_text(),
                       dict(ca or {}),
                       float(ma.temp_size_in_bytes), None)
    out = {"flops": rep.hlo_dot_flops, "ici": rep.ici_bytes,
           "bottleneck": rep.bottleneck,
           "counts": rep.collective_counts}
    print("CELL_OK " + json.dumps(out))
""")


@pytest.mark.parametrize("arch,kind", [
    ("llama3_8b", "train"),
    ("dbrx_132b", "train"),
    ("mamba2_370m", "train"),
    ("gemma3_27b", "prefill"),
    ("recurrentgemma_2b", "decode"),
    ("qwen2_vl_2b", "decode"),
])
def test_dryrun_cell_small_mesh(arch, kind):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    cwd = os.path.dirname(os.path.dirname(__file__))
    r = subprocess.run([sys.executable, "-c", SCRIPT, arch, kind], env=env,
                       capture_output=True, text=True, timeout=600, cwd=cwd)
    assert "CELL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
    payload = json.loads(r.stdout.split("CELL_OK ")[1])
    assert payload["flops"] > 0
    if kind == "train":
        # sharded training must communicate something
        assert payload["ici"] > 0


def test_hlo_parser_loop_awareness():
    """Unit check of the trip-count-aware parse on a hand-built module."""
    from repro.roofline import hlo
    txt = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i2, %d)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]{1,0}) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (x0: f32[8,8]) -> f32[8,8] {
  %x0 = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %x0)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    ana = hlo.analyze(txt)
    assert ana.dot_flops == 5 * 2 * 8 * 8 * 8, ana.dot_flops


def test_collective_factors():
    from repro.roofline import hlo
    txt = """
HloModule test

ENTRY %main (x0: f32[64,64]) -> f32[64,64] {
  %x0 = f32[64,64]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%x0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,64]{1,0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[64,64]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
}
"""
    ana = hlo.analyze(txt)
    b = 64 * 64 * 4
    assert abs(ana.collective_bytes_by_kind["all-reduce"]
               - 2 * 3 / 4 * b) < 1
    assert abs(ana.collective_bytes_by_kind["all-gather"] - 3 / 4 * b) < 1
    assert abs(ana.collective_bytes_by_kind["collective-permute"] - b) < 1
