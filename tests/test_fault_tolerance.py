"""Fault tolerance: injected crash -> supervisor restart -> resume from
checkpoint -> training completes. Plus straggler detection unit tests."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.ft import (Heartbeat, Supervisor, SupervisorConfig,
                             detect_straggler)


def test_heartbeat_roundtrip(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"))
    hb.beat(3, {"loss": 1.5})
    with open(tmp_path / "hb.json") as f:
        data = json.load(f)
    assert data["step"] == 3 and data["loss"] == 1.5


def test_detect_straggler():
    assert detect_straggler([1.0] * 10) is None
    times = [1.0] * 8 + [5.0] + [1.0]
    assert detect_straggler(times, factor=3.0) == 8
    assert detect_straggler([1.0, 1.2], factor=3.0) is None  # too few


@pytest.mark.slow
def test_crash_restart_resume_completes(tmp_path):
    """End-to-end: trainer crashes at step 12 (injected), supervisor
    restarts it, it resumes from the step-10 checkpoint and finishes all 20
    steps."""
    ckpt = str(tmp_path / "ckpt")
    hb = str(tmp_path / "hb.json")
    metrics = str(tmp_path / "metrics.json")
    argv = [sys.executable, "-m", "repro.launch.train",
            "--arch", "mamba2_370m", "--reduced",
            "--steps", "20", "--batch", "4", "--seq", "32",
            "--ckpt-dir", ckpt, "--ckpt-every", "5",
            "--heartbeat", hb, "--log-every", "5",
            "--metrics-out", metrics]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["REPRO_FAIL_AT_STEP"] = "12"
    env.pop("XLA_FLAGS", None)
    cwd = os.path.dirname(os.path.dirname(__file__))

    class TwoPhaseSupervisor(Supervisor):
        """Remove the failure injection after the first restart (the bug
        'goes away' once restarted -- models a node failure)."""

        def run(self):
            ret = None
            while True:
                proc = subprocess.Popen(self.argv, env=self.env, cwd=cwd)
                ret = proc.wait()
                if ret == 0:
                    return 0
                self.restarts += 1
                self.env.pop("REPRO_FAIL_AT_STEP", None)
                if self.restarts > self.cfg.max_restarts:
                    return ret

    sup = TwoPhaseSupervisor(argv, SupervisorConfig(heartbeat_path=hb),
                             env=env)
    ret = sup.run()
    assert ret == 0
    assert sup.restarts == 1
    with open(metrics) as f:
        log = json.load(f)
    steps_seen = [m["step"] for m in log]
    assert 19 in steps_seen           # training completed
    # resume happened from step 10 (the last checkpoint before the crash)
    with open(hb) as f:
        assert json.load(f)["step"] == 19
