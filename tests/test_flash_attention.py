"""Flash custom-VJP attention vs the naive reference: values and grads."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention


def _naive(q, k, v, q_pos, k_pos):
    """q (B, Lq, KV, G, hd); k, v (B, Lk, KV, hd)."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k) / math.sqrt(q.shape[-1])
    mask = k_pos[None, :] <= q_pos[:, None]
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v)


@pytest.mark.parametrize("B,L,KV,G,hd,qc,kc", [
    (2, 64, 2, 2, 16, 16, 16),
    (1, 96, 4, 1, 8, 32, 48),
    (2, 128, 1, 4, 16, 128, 64),   # single q chunk
])
def test_flash_matches_naive_fwd_and_bwd(B, L, KV, G, hd, qc, kc):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, L, KV, G, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, L, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, L, KV, hd)).astype(np.float32))
    pos = jnp.arange(L, dtype=jnp.int32)

    out_f = flash_attention(q, k, v, pos, pos, qc, kc)
    out_n = _naive(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_n),
                               rtol=2e-4, atol=2e-4)

    w = jnp.asarray(rng.standard_normal(out_n.shape).astype(np.float32))

    def loss_f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, pos, pos, qc, kc) * w)

    def loss_n(q, k, v):
        return jnp.sum(_naive(q, k, v, pos, pos) * w)

    gf = jax.grad(loss_f, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_n, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-3,
                                   err_msg=f"d{nm} mismatch")


def test_flash_bf16_inputs():
    rng = np.random.default_rng(1)
    B, L, KV, G, hd = 1, 32, 2, 2, 8
    q = jnp.asarray(rng.standard_normal((B, L, KV, G, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.bfloat16)
    pos = jnp.arange(L, dtype=jnp.int32)
    out = flash_attention(q, k, v, pos, pos, 16, 16)
    assert out.dtype == jnp.bfloat16
    ref = _naive(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32), pos, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)
