"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [
    (8, 4, 3),       # tiny, everything padded
    (100, 5, 10),    # paper's synthetic dims
    (256, 128, 128), # exactly tile-aligned
    (300, 17, 90),   # ragged everywhere (YearPredictionMSD dims)
    (1024, 50, 32),  # ColorHistogram-ish
    (513, 257, 129), # off-by-one on every axis
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(n, k, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((n, d)).astype(np.float32)
    ctr = rng.standard_normal((k, d)).astype(np.float32)
    w = np.abs(rng.standard_normal(n)).astype(np.float32)
    return (jnp.asarray(pts, dtype), jnp.asarray(ctr, dtype), jnp.asarray(w))


@pytest.mark.parametrize("n,k,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_distance_argmin_matches_ref(n, k, d, dtype):
    pts, ctr, _ = _data(n, k, d, dtype)
    md, am = ops.min_dist_argmin(pts, ctr)
    md_ref, am_ref = ref.min_dist_argmin_ref(pts, ctr)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(md), np.asarray(md_ref),
                               rtol=tol, atol=tol)
    # argmin may differ only where two centers are effectively tied
    diff = np.asarray(am) != np.asarray(am_ref)
    if diff.any():
        d_kernel = np.asarray(md)[diff]
        d_oracle = np.asarray(md_ref)[diff]
        np.testing.assert_allclose(d_kernel, d_oracle, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,k,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_lloyd_stats_matches_ref(n, k, d, dtype):
    pts, ctr, w = _data(n, k, d, dtype)
    sums, counts, cost = ops.lloyd_stats(pts, ctr, w)
    sums_r, counts_r, cost_r = ref.lloyd_stats_ref(pts, ctr, w)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_r),
                               rtol=tol, atol=1e-2)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=tol, atol=max(tol * 10, 1e-3))
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=5e-3)


@pytest.mark.parametrize("n,k,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_weiszfeld_stats_matches_ref(n, k, d, dtype):
    pts, ctr, w = _data(n, k, d, dtype)
    nums, denoms, cost = ops.weiszfeld_stats(pts, ctr, w)
    nums_r, denoms_r, cost_r = ref.weiszfeld_stats_ref(pts, ctr, w)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(denoms), np.asarray(denoms_r),
                               rtol=tol, atol=1e-2)
    np.testing.assert_allclose(np.asarray(nums), np.asarray(nums_r),
                               rtol=tol, atol=max(tol * 10, 1e-3))
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=5e-3)


def test_weiszfeld_stats_coincident_points_match_ref():
    """Centers that are bit-exact data points (k-means++ seeds): the
    exact-form distance must agree across kernel and oracle instead of
    amplifying matmul cancellation noise through the inverse."""
    pts, ctr, w = _data(300, 17, 90, jnp.float32)
    ctr = pts[:17]
    nums, denoms, cost = ops.weiszfeld_stats(pts, ctr, w)
    nums_r, denoms_r, cost_r = ref.weiszfeld_stats_ref(pts, ctr, w)
    np.testing.assert_allclose(np.asarray(denoms), np.asarray(denoms_r),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(nums), np.asarray(nums_r),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=1e-4,
                               atol=1e-3)


def test_weiszfeld_stats_large_k_fallback_path():
    """k*d beyond the VMEM-resident budget must route through the two-pass
    fallback and still match the oracle."""
    pts, ctr, w = _data(512, 1100, 1024, jnp.float32)
    nums, denoms, cost = ops.weiszfeld_stats(pts, ctr, w)
    nums_r, denoms_r, cost_r = ref.weiszfeld_stats_ref(pts, ctr, w)
    np.testing.assert_allclose(np.asarray(denoms), np.asarray(denoms_r),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(nums), np.asarray(nums_r),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=1e-3)


def test_weiszfeld_zero_weight_points_do_not_contribute():
    pts, ctr, w = _data(128, 4, 8, jnp.float32)
    w = w.at[64:].set(0.0)
    nums_a, denoms_a, cost_a = ops.weiszfeld_stats(pts, ctr, w)
    nums_b, denoms_b, cost_b = ops.weiszfeld_stats(pts[:64], ctr, w[:64])
    np.testing.assert_allclose(np.asarray(nums_a), np.asarray(nums_b),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(denoms_a), np.asarray(denoms_b),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(cost_a), float(cost_b), rtol=1e-5)


def test_lloyd_stats_large_k_fallback_path():
    """k*d beyond the VMEM-resident budget must route through the two-pass
    fallback and still match the oracle."""
    pts, ctr, w = _data(512, 1100, 1024, jnp.float32)
    sums, counts, cost = ops.lloyd_stats(pts, ctr, w)
    sums_r, counts_r, cost_r = ref.lloyd_stats_ref(pts, ctr, w)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_r),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(float(cost), float(cost_r), rtol=1e-3)


def test_lloyd_step_matches_clustering_update():
    from repro.core import backend, objective
    pts, ctr, w = _data(300, 8, 16, jnp.float32)
    new_k, cost_k = ops.lloyd_step(pts, ctr, w)
    # one reference weighted Lloyd step through the jnp dispatch backend
    new_r, cost_r = objective.KMEANS.update(backend.get_backend("jnp"),
                                            pts, w, ctr)
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(new_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(cost_k), float(cost_r), rtol=1e-4)


def test_zero_weight_points_do_not_contribute():
    pts, ctr, w = _data(128, 4, 8, jnp.float32)
    w = w.at[64:].set(0.0)
    sums_a, counts_a, cost_a = ops.lloyd_stats(pts, ctr, w)
    sums_b, counts_b, cost_b = ops.lloyd_stats(pts[:64], ctr, w[:64])
    np.testing.assert_allclose(np.asarray(sums_a), np.asarray(sums_b),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(cost_a), float(cost_b), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 400), k=st.integers(1, 70), d=st.integers(1, 150),
       seed=st.integers(0, 2**31 - 1))
def test_property_distance_argmin_any_shape(n, k, d, seed):
    pts, ctr, _ = _data(n, k, d, jnp.float32, seed=seed)
    md, am = ops.min_dist_argmin(pts, ctr)
    md_ref, am_ref = ref.min_dist_argmin_ref(pts, ctr)
    np.testing.assert_allclose(np.asarray(md), np.asarray(md_ref),
                               rtol=1e-4, atol=1e-4)
    assert md.shape == (n,) and am.shape == (n,)
    assert int(jnp.max(am)) < k


def test_block_size_sweep_invariance():
    pts, ctr, _ = _data(512, 64, 32, jnp.float32)
    md0, am0 = ops.min_dist_argmin(pts, ctr, block_n=64, block_k=16)
    md1, am1 = ops.min_dist_argmin(pts, ctr, block_n=256, block_k=64)
    np.testing.assert_allclose(np.asarray(md0), np.asarray(md1), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(am0), np.asarray(am1))
