"""Per-architecture smoke tests (reduced configs of the same family): one
forward + shapes + finiteness, decode==forward equivalence, analytic param
count == actual, and full-config advertised sizes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import forward, init_cache, init_params, make_positions

ARCHS = configs.ARCH_IDS


def _exactify(cfg):
    """f32 activations + drop-free MoE so prefill/decode are bit-comparable."""
    cf = cfg.capacity_factor
    if cfg.n_experts:
        cf = float(cfg.n_experts) / cfg.top_k
    return dataclasses.replace(cfg, dtype="float32", capacity_factor=cf)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                cfg.vocab_size)
    pos = make_positions(tokens, cfg)
    logits, cache, aux = forward(params, tokens, pos, cfg)
    assert logits.shape == (B, L, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    assert cache is None
    if cfg.n_experts:
        assert float(aux) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_runs(arch):
    """One SGD step on the reduced config: loss finite and decreasing-ish."""
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L + 1), 0,
                                cfg.vocab_size)

    def loss_fn(p):
        pos = make_positions(tokens[:, :-1], cfg)
        logits, _, aux = forward(p, tokens[:, :-1], pos, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)
        return -jnp.mean(ll) + 0.01 * aux

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, g: p - 0.3 * g / (gnorm + 1e-6),
                           params, grads)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0) + 1e-3


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = _exactify(configs.get_reduced(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L, Lp = 2, 32, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                cfg.vocab_size)
    logits_full, _, _ = forward(params, tokens, make_positions(tokens, cfg),
                                cfg)
    scale = float(jnp.max(jnp.abs(logits_full)))
    cache = init_cache(cfg, B, max_len=L)
    logits_p, cache, _ = forward(params, tokens[:, :Lp],
                                 make_positions(tokens[:, :Lp], cfg), cfg,
                                 cache=cache)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_full[:, :Lp]),
                               atol=5e-4 * scale)
    for t in range(Lp, L):
        logits_t, cache, _ = forward(
            params, tokens[:, t:t + 1],
            make_positions(tokens[:, t:t + 1], cfg, offset=t), cfg,
            cache=cache)
        np.testing.assert_allclose(np.asarray(logits_t[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=5e-4 * scale)


@pytest.mark.parametrize("arch", ARCHS)
def test_analytic_param_count_exact(arch):
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert cfg.param_count() == actual


ADVERTISED = {
    "dbrx_132b": 132e9,
    "granite_moe_3b_a800m": 3.3e9,
    "gemma3_27b": 27e9,
    "qwen2_72b": 72e9,
    "granite_34b": 34e9,
    "llama3_8b": 8e9,
    "qwen2_vl_2b": 1.5e9,       # backbone (vision tower stubbed)
    "mamba2_370m": 370e6,
    "musicgen_large": 2.4e9,    # decoder backbone (cross-attn/frontend stubbed)
    "recurrentgemma_2b": 2.7e9,
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_advertised_size(arch):
    cfg = configs.get(arch)
    n = cfg.param_count()
    assert abs(n - ADVERTISED[arch]) / ADVERTISED[arch] < 0.12, (arch, n)


def test_moe_active_params():
    cfg = configs.get("dbrx_132b")
    frac = cfg.active_param_count() / cfg.param_count()
    # 16 experts top-4 => roughly 1/4 of expert params active
    assert 0.2 < frac < 0.45


def test_remainder_layers_exercised():
    """gemma3 (62 = 6*10+2) and recurrentgemma (26 = 3*8+2) have remainder
    blocks; the reduced configs must too, and they must carry params."""
    for arch in ("gemma3_27b", "recurrentgemma_2b"):
        cfg = configs.get_reduced(arch)
        assert cfg.n_layers % cfg.period != 0
        params = init_params(jax.random.PRNGKey(0), cfg)
        assert len(params["layers"]["rem"]) == len(cfg.remainder_runs())
        n_rem_layers = sum(n for _, n in cfg.remainder_runs())
        assert n_rem_layers == len(cfg.remainder_kinds)


def test_runs_grouping():
    cfg = configs.get("gemma3_27b")
    assert cfg.runs() == (("local", 5), ("attn", 1))
    assert cfg.remainder_runs() == (("local", 2),)
    cfg2 = configs.get("recurrentgemma_2b")
    assert cfg2.runs() == (("rglru", 2), ("local", 1))
    assert cfg2.remainder_runs() == (("rglru", 2),)
    cfg3 = configs.get("llama3_8b")
    assert cfg3.runs() == (("attn", 1),)
    assert cfg3.remainder_runs() == ()


def test_mrope_differs_from_rope_on_spatial_ids():
    """qwen2-vl: giving patches distinct h/w position ids must change the
    logits vs collapsed text-only ids."""
    cfg = _exactify(configs.get_reduced("qwen2_vl_2b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                cfg.vocab_size)
    pos_text = make_positions(tokens, cfg)             # (B, 3, L) identical
    grid = jnp.stack([jnp.zeros((L,), jnp.int32),
                      jnp.arange(L, dtype=jnp.int32) // 4,
                      jnp.arange(L, dtype=jnp.int32) % 4])[None]
    l_text, _, _ = forward(params, tokens, pos_text, cfg)
    l_grid, _, _ = forward(params, tokens, grid, cfg)
    assert float(jnp.max(jnp.abs(l_text - l_grid))) > 1e-3
