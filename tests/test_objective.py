"""First-class objective layer (DESIGN.md Sec. 15).

Covers the registry boundary (unknown names raise with the known names
listed -- the legacy string branches silently mis-dispatched typos), the
bit-compat discipline (z=1/z=2 power objectives equal the legacy
kmedian/kmeans paths bit for bit across backends; trimmed at t=0 equals
untrimmed), the trimmed objective's semantics (monotone non-increasing in
t, outlier mass excluded from coresets), and the contamination acceptance
test: on PR 7's ``contaminated_stream`` the trimmed objective recovers the
clean-stream cost where plain k-means is destroyed, for both the sim and
exec aggregation engines and all three backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import backend as backend_mod
from repro.core import clustering, objective, topology
from repro.core.coreset import build_coreset, sensitivities
from repro.core.distributed import graph_distributed_kmeans
from repro.data.synthetic import contaminated_stream, drifting_mixture_stream
from repro.serve.cluster import ClusterServeEngine, StaticCenters
from repro.stream.ingest import DistributedStream
from repro.stream.tree import CoresetTree, TreeConfig

BACKENDS = ("jnp", "jnp_chunked", "pallas")


@pytest.fixture(scope="module")
def outlier_mixture():
    """3 tight clusters + 10 far-field outliers (n=160, d=2)."""
    rng = np.random.default_rng(0)
    centers = np.array([[0, 0], [10, 0], [0, 10]], np.float32)
    pts = np.concatenate(
        [centers[i] + 0.3 * rng.standard_normal((50, 2)) for i in range(3)]
        + [100.0 * rng.standard_normal((10, 2))]).astype(np.float32)
    return jnp.asarray(pts)


# ---------------------------------------------------------------------------
# registry boundary (satellite: unknown strings must raise, not mis-dispatch)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", ["kmeans ", "median", "kmens", "KMEANS",
                                 "kmeans_trimmed", "power(0)", "power(-1)",
                                 "kmeans_trimmed(-3)"])
def test_unknown_objective_raises_with_known_names(bad):
    with pytest.raises(ValueError, match="unknown objective"):
        objective.resolve_name(bad)
    with pytest.raises(ValueError, match="kmedian"):
        # the error must list the registered names
        objective.resolve_name(bad)


def test_unknown_objective_raises_at_every_public_boundary(outlier_mixture):
    pts = outlier_mixture
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="unknown objective"):
        clustering.solve(key, pts, 3, objective="kmeans ")
    with pytest.raises(ValueError, match="unknown objective"):
        clustering.cost(pts, pts[:3], objective="median")
    with pytest.raises(ValueError, match="unknown objective"):
        clustering.kmeans_pp_init(key, pts, 3, objective="kmens")
    with pytest.raises(ValueError, match="unknown objective"):
        build_coreset(key, pts, 3, 16, objective="kmeanss")
    with pytest.raises(ValueError, match="unknown objective"):
        backend_mod.query_assignments(pts, pts[:3], objective=" kmedian")
    with pytest.raises(ValueError, match="unknown objective"):
        CoresetTree(TreeConfig(k=3, t=8, d=2, batch_size=16,
                               objective="kmean"))
    with pytest.raises(ValueError, match="unknown objective"):
        ClusterServeEngine().add_tenant(StaticCenters(pts[:3]), k=3, d=2,
                                        objective="kmeans!")
    sp = pts[:160].reshape(4, 40, 2)
    with pytest.raises(ValueError, match="unknown objective"):
        graph_distributed_kmeans(key, sp, jnp.ones((4, 40), bool), 3, 16,
                                 topology.ring(4), objective="kmedian ")


def test_parametrized_names_round_trip():
    obj = objective.kmeans_trimmed(16)
    assert obj.name == "kmeans_trimmed(16)"
    assert objective.resolve_name("kmeans_trimmed(16)") == obj.name
    assert objective.get_objective("kmeans_trimmed(16)") is obj
    # float count folds to the int spelling; fractions keep theirs
    assert objective.kmeans_trimmed(16.0) is obj
    frac = objective.kmeans_trimmed(0.05)
    assert frac.name == "kmeans_trimmed(0.05)"
    assert objective.get_objective("kmeans_trimmed(0.05)") is frac
    pw = objective.power_objective(3)
    assert pw.name == "power(3)"
    assert objective.resolve_name(pw) == "power(3)"
    # instances are accepted anywhere a name is
    assert objective.resolve_name(objective.KMEANS) == "kmeans"


def test_register_conflicting_name_raises():
    other = objective.Objective(name="kmeans_conflict_probe", power_z=2.0)
    objective.register_objective(other)
    clone = objective.Objective(name="kmeans_conflict_probe", power_z=2.0)
    # equal instance: no-op re-register
    objective.register_objective(clone)
    different = objective.Objective(name="kmeans_conflict_probe",
                                    power_z=1.0)
    with pytest.raises(ValueError, match="already registered"):
        objective.register_objective(different)


def test_invalid_trim_parameters_raise():
    with pytest.raises(ValueError, match="t_outliers"):
        objective.kmeans_trimmed(-1)
    with pytest.raises(ValueError, match="t_outliers"):
        objective.kmeans_trimmed(2.5)
    with pytest.raises(ValueError):
        objective.power_objective(0.0)


# ---------------------------------------------------------------------------
# bit-compat discipline (satellite: hypothesis properties)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       z=st.sampled_from([1, 2]),
       backend=st.sampled_from(BACKENDS))
def test_power_z12_bit_identical_to_legacy(seed, z, backend):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((120, 5)).astype(np.float32))
    w = jnp.asarray(np.abs(rng.standard_normal(120)).astype(np.float32))
    key = jax.random.PRNGKey(seed)
    legacy = "kmeans" if z == 2 else "kmedian"
    c_p, cost_p = clustering.solve(key, pts, 4, weights=w, lloyd_iters=3,
                                   objective=f"power({z})", backend=backend)
    c_l, cost_l = clustering.solve(key, pts, 4, weights=w, lloyd_iters=3,
                                   objective=legacy, backend=backend)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_l))
    assert float(cost_p) == float(cost_l)
    cs_p = build_coreset(key, pts, 4, 16, weights=w,
                         objective=f"power({z})", backend=backend)
    cs_l = build_coreset(key, pts, 4, 16, weights=w, objective=legacy,
                         backend=backend)
    np.testing.assert_array_equal(np.asarray(cs_p.points),
                                  np.asarray(cs_l.points))
    np.testing.assert_array_equal(np.asarray(cs_p.weights),
                                  np.asarray(cs_l.weights))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_trimmed_t0_equals_untrimmed_bitwise(seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((100, 4)).astype(np.float32))
    key = jax.random.PRNGKey(seed)
    c_t, cost_t = clustering.solve(key, pts, 3, lloyd_iters=4,
                                   objective="kmeans_trimmed(0)")
    c_u, cost_u = clustering.solve(key, pts, 3, lloyd_iters=4,
                                   objective="kmeans")
    np.testing.assert_array_equal(np.asarray(c_t), np.asarray(c_u))
    assert float(cost_t) == float(cost_u)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       t_lo=st.integers(0, 10), t_delta=st.integers(1, 20))
def test_trimmed_cost_monotone_nonincreasing_in_t(seed, t_lo, t_delta):
    """At FIXED centers, trimming more points can only drop cost: the
    trimmed cost sums the n - t smallest residuals."""
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((80, 3)).astype(np.float32))
    centers = pts[:4]
    c_lo = float(clustering.cost(
        pts, centers, objective=f"kmeans_trimmed({t_lo})"))
    c_hi = float(clustering.cost(
        pts, centers, objective=f"kmeans_trimmed({t_lo + t_delta})"))
    c_un = float(clustering.cost(pts, centers, objective="kmeans"))
    assert c_hi <= c_lo <= c_un


def test_trimmed_cost_excludes_exactly_t_largest(outlier_mixture):
    """Trimmed per-point costs zero exactly the t largest residuals (ties
    broken deterministically), on every backend."""
    pts = outlier_mixture
    centers = pts[:3]
    for be in BACKENDS:
        full, _ = clustering.point_costs(pts, centers, objective="kmeans",
                                         backend=be)
        trimmed, _ = clustering.point_costs(
            pts, centers, objective="kmeans_trimmed(10)", backend=be)
        full = np.asarray(full)
        trimmed = np.asarray(trimmed)
        zeroed = np.flatnonzero((trimmed == 0.0) & (full > 0.0))
        assert zeroed.size == 10
        kept_max = full[trimmed > 0.0].max() if (trimmed > 0.0).any() else 0
        assert full[zeroed].min() >= kept_max
        assert trimmed.sum() <= full.sum()


def test_trimmed_fractional_t_counts_live_slots_only():
    """t as a fraction is taken of the *live* (weight != 0) slots, so
    padding never eats the trim budget."""
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.standard_normal((50, 3)).astype(np.float32))
    w = jnp.ones((50,), jnp.float32).at[40:].set(0.0)   # 40 live, 10 pad
    obj = objective.kmeans_trimmed(0.25)
    keep = objective.trim_mask(obj, jnp.arange(50, dtype=jnp.float32), w)
    keep = np.asarray(keep)
    # 25% of 40 live = 10 trimmed, all from the live largest residuals
    assert (~keep).sum() == 10
    assert np.array_equal(np.flatnonzero(~keep), np.arange(30, 40))
    del pts


def test_trimmed_sensitivities_zero_outlier_mass(outlier_mixture):
    pts = outlier_mixture
    w = jnp.ones((pts.shape[0],), jnp.float32)
    centers = pts[:3]
    m, _, w_eff = sensitivities(pts, centers, w,
                                objective="kmeans_trimmed(10)")
    assert int(jnp.sum(w_eff == 0.0)) == 10
    assert float(m[np.asarray(w_eff) == 0.0].sum()) == 0.0
    # plain objectives pass the weights through untouched (bit-identity)
    m2, _, w_eff2 = sensitivities(pts, centers, w, objective="kmeans")
    assert w_eff2 is w
    assert float(jnp.sum(m2 > 0.0)) > 0


def test_trimmed_coreset_drops_outlier_weight(outlier_mixture):
    """Total coreset weight equals the inlier count: the 10 outliers'
    mass is genuinely excluded, not folded into center weights."""
    pts = outlier_mixture
    cs = build_coreset(jax.random.PRNGKey(0), pts, 3, 32,
                       objective="kmeans_trimmed(10)")
    assert float(cs.weights.sum()) == pytest.approx(150.0, abs=1e-3)


def test_trimmed_solve_ignores_outliers_on_all_backends(outlier_mixture):
    pts = outlier_mixture
    key = jax.random.PRNGKey(0)
    for be in BACKENDS:
        c, cost = clustering.solve(key, pts, 3, restarts=3,
                                   objective="kmeans_trimmed(10)",
                                   backend=be)
        # every center lands on a true cluster (radius ~10), never on the
        # far field (radius ~100)
        assert float(jnp.abs(c).max()) < 20.0
        assert float(cost) < 100.0


def test_query_metric_matches_objective(outlier_mixture):
    pts = outlier_mixture
    ctr = pts[:3]
    a_km, d_km = backend_mod.query_assignments(pts, ctr, objective="kmeans")
    a_tr, d_tr = backend_mod.query_assignments(
        pts, ctr, objective="kmeans_trimmed(10)")
    a_md, d_md = backend_mod.query_assignments(pts, ctr,
                                               objective="kmedian")
    # queries are never trimmed: z=2 metric, identical to plain k-means
    np.testing.assert_array_equal(np.asarray(a_km), np.asarray(a_tr))
    np.testing.assert_array_equal(np.asarray(d_km), np.asarray(d_tr))
    np.testing.assert_allclose(np.asarray(d_md) ** 2, np.asarray(d_km),
                               rtol=1e-4, atol=1e-5)


def test_power_general_z_runs_dense():
    rng = np.random.default_rng(2)
    pts = jnp.asarray(rng.standard_normal((90, 4)).astype(np.float32))
    key = jax.random.PRNGKey(2)
    for z in (0.5, 3):
        c, cost = clustering.solve(key, pts, 3, lloyd_iters=4,
                                   objective=f"power({z})", backend="jnp")
        assert np.isfinite(float(cost))
        assert np.isfinite(np.asarray(c)).all()


# ---------------------------------------------------------------------------
# contamination acceptance (satellite: trimmed defeats contaminated_stream)
# ---------------------------------------------------------------------------

def _stream_recovery_cost(objective_name, engine, backend, contaminated,
                          seed=0):
    """Aggregate a (possibly contaminated) stream and score the recovered
    centers on the CLEAN stream's points in the plain k-means metric."""
    g = topology.ring(4)
    cfg = TreeConfig(k=5, t=48, d=10, batch_size=128,
                     objective=objective_name, backend=backend)
    ds = DistributedStream(g, cfg, key=jax.random.PRNGKey(3))
    gen = (contaminated_stream(12, 128, d=10, k=5, outlier_frac=0.05,
                               seed=seed)
           if contaminated else
           drifting_mixture_stream(12, 128, d=10, k=5, seed=seed))
    for i, b in enumerate(gen):
        ds.push(i % 4, b)
    res = ds.aggregate(5, 40, engine=engine)
    clean = np.concatenate(
        list(drifting_mixture_stream(12, 128, d=10, k=5, seed=seed)))
    return float(clustering.cost(jnp.asarray(clean), res.centers,
                                 objective="kmeans", backend=backend))


@pytest.mark.parametrize("engine", ["sim", "exec"])
def test_trimmed_defeats_contaminated_stream(engine):
    """At 5% far-field contamination, plain k-means exceeds 3x the
    clean-stream cost while kmeans_trimmed recovers within 1.5x -- for
    both the sim and exec aggregation engines."""
    base = _stream_recovery_cost("kmeans", engine, "jnp", False)
    plain = _stream_recovery_cost("kmeans", engine, "jnp", True)
    trimmed = _stream_recovery_cost("kmeans_trimmed(0.08)", engine, "jnp",
                                    True)
    assert plain > 3.0 * base
    assert trimmed < 1.5 * base


@pytest.mark.parametrize("backend", ["jnp_chunked", "pallas"])
def test_trimmed_contamination_recovery_all_backends(backend):
    """The acceptance contrast holds on the chunked and Pallas backends
    too (sim engine; the jnp case is the parametrized test above)."""
    base = _stream_recovery_cost("kmeans", "sim", backend, False)
    plain = _stream_recovery_cost("kmeans", "sim", backend, True)
    trimmed = _stream_recovery_cost("kmeans_trimmed(0.08)", "sim", backend,
                                    True)
    assert plain > 3.0 * base
    assert trimmed < 1.5 * base


def test_trimmed_through_graph_distributed(outlier_mixture):
    """kmeans_trimmed threads through graph_distributed_kmeans: sim and
    exec engines agree bit-for-bit and both avoid the far field."""
    perm = np.random.default_rng(7).permutation(160)
    pts = outlier_mixture[perm]      # spread the outliers across sites
    sp = pts.reshape(4, 40, 2)
    mask = jnp.ones((4, 40), bool)
    g = topology.ring(4)
    key = jax.random.PRNGKey(1)
    rs = graph_distributed_kmeans(key, sp, mask, 3, 24, g,
                                  objective="kmeans_trimmed(0.125)",
                                  engine="sim", backend="jnp")
    re = graph_distributed_kmeans(key, sp, mask, 3, 24, g,
                                  objective="kmeans_trimmed(0.125)",
                                  engine="exec", backend="jnp")
    np.testing.assert_array_equal(np.asarray(rs.centers),
                                  np.asarray(re.centers))
    assert rs.ledger.as_dict() == re.ledger.as_dict()
    assert float(jnp.abs(rs.centers).max()) < 20.0
