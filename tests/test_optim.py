"""AdamW, schedules and gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, adamw, compression, schedule


def _quadratic_problem(seed=0, d=20):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((d, d)).astype(np.float32)
    A = A @ A.T / d + np.eye(d, dtype=np.float32)
    b = rng.standard_normal(d).astype(np.float32)

    def loss(x):
        return 0.5 * x @ jnp.asarray(A) @ x - jnp.asarray(b) @ x

    x_star = np.linalg.solve(A, b)
    return loss, x_star


def test_adamw_matches_reference_math():
    """One step against a hand-rolled numpy AdamW."""
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip_norm=0.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    state = adamw.init(params)
    new_params, state, _ = adamw.update(grads, state, params,
                                        jnp.asarray(0.01), cfg)
    g = np.array([0.1, -0.2, 0.3])
    m = 0.1 * g
    v = 0.001 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = np.array([1.0, -2.0, 3.0]) - 0.01 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), want, rtol=1e-5)


def test_adamw_converges_on_quadratic():
    loss, x_star = _quadratic_problem()
    params = {"x": jnp.zeros(20)}
    state = adamw.init(params)
    cfg = AdamWConfig(weight_decay=0.0, grad_clip_norm=0.0, b2=0.999)
    for i in range(800):
        g = jax.grad(lambda p: loss(p["x"]))(params)
        params, state, _ = adamw.update(g, state, params,
                                        jnp.asarray(0.05), cfg)
    np.testing.assert_allclose(np.asarray(params["x"]), x_star, atol=0.05)


def test_grad_clip():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-5)
    assert float(norm) > 30.0


def test_weight_decay_skips_norms_and_biases():
    cfg = AdamWConfig()
    params = {"layer": {"w": jnp.ones((4, 4)), "b": jnp.ones((4,)),
                        "scale": jnp.ones((4,))}}
    mask = adamw._decay_mask(params, cfg)
    assert mask["layer"]["w"] == 1.0
    assert mask["layer"]["b"] == 0.0
    assert mask["layer"]["scale"] == 0.0


def test_warmup_cosine_schedule():
    lr0 = float(schedule.warmup_cosine(0, 1e-3, 100, 1000))
    lr_peak = float(schedule.warmup_cosine(100, 1e-3, 100, 1000))
    lr_end = float(schedule.warmup_cosine(1000, 1e-3, 100, 1000))
    assert lr0 == 0.0
    np.testing.assert_allclose(lr_peak, 1e-3, rtol=1e-5)
    np.testing.assert_allclose(lr_end, 1e-4, rtol=1e-4)


def test_int8_quantization_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    back = compression.qdq_int8(g)
    max_err = float(jnp.max(jnp.abs(back - g)))
    assert max_err <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6


def test_topk_keeps_largest():
    g = jnp.asarray(np.arange(100, dtype=np.float32) - 50)
    m = compression.topk_mask(g, 0.1)
    kept = np.nonzero(np.asarray(m))[0]
    assert len(kept) >= 10
    assert 0 in kept and 99 in kept  # largest magnitudes


def test_error_feedback_convergence():
    """SGD with int8-compressed grads + error feedback reaches the optimum
    of a quadratic (lossy but unbiased-in-the-limit updates)."""
    loss, x_star = _quadratic_problem(seed=1)
    x = {"x": jnp.zeros(20)}
    err = None
    for i in range(1500):
        g = jax.grad(lambda p: loss(p["x"]))(x)
        comp, err = compression.compress_with_feedback(g, err, scheme="int8")
        x = jax.tree.map(lambda p, c: p - 0.02 * c, x, comp)
    np.testing.assert_allclose(np.asarray(x["x"]), x_star, atol=0.05)


def test_topk_error_feedback_convergence():
    loss, x_star = _quadratic_problem(seed=2)
    x = {"x": jnp.zeros(20)}
    err = None
    for i in range(4000):
        g = jax.grad(lambda p: loss(p["x"]))(x)
        comp, err = compression.compress_with_feedback(
            g, err, scheme="topk", topk_frac=0.25)
        x = jax.tree.map(lambda p, c: p - 0.02 * c, x, comp)
    np.testing.assert_allclose(np.asarray(x["x"]), x_star, atol=0.08)
