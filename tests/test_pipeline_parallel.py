"""GPipe-style pipeline parallelism: schedule math + numerical equivalence
against the unpipelined stack (subprocess with 4 host devices)."""
import os
import subprocess
import sys
import textwrap

from repro.train.pipeline import PipelineSchedule


def test_schedule_bubble_math():
    s = PipelineSchedule(n_stages=4, n_microbatches=12)
    assert s.ticks == 15
    assert abs(s.bubble_fraction - 3 / 15) < 1e-9
    s2 = PipelineSchedule(n_stages=1, n_microbatches=8)
    assert s2.bubble_fraction == 0.0


PP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.train.pipeline import pipeline_forward

    S, M, mb, d = 4, 8, 2, 16
    rng = np.random.default_rng(0)
    Ws = rng.standard_normal((S, d, d)).astype(np.float32) / np.sqrt(d)
    xs = rng.standard_normal((M, mb, d)).astype(np.float32)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    # reference: sequential application of all stages
    ref = jnp.asarray(xs)
    for s in range(S):
        ref = jax.vmap(lambda x: stage_fn(jnp.asarray(Ws[s]), x))(ref)

    mesh = jax.make_mesh((4,), ("stage",))
    def run(w_all, mbs):
        return pipeline_forward(stage_fn, w_all[0], mbs, "stage", S)

    out = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P("stage")))(jnp.asarray(Ws), jnp.asarray(xs))
    # output lives on the last stage's shard
    got = out.reshape(4, M, mb, d)[-1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("PP_OK")
""")


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    cwd = os.path.dirname(os.path.dirname(__file__))
    out = subprocess.run([sys.executable, "-c", PP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=cwd)
    assert "PP_OK" in out.stdout, out.stdout + out.stderr
