"""Serving tests: generation determinism, engine continuous batching, and
engine output == straight generate()."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import init_params
from repro.serve import Engine, Request, generate


def _setup(arch="llama3_8b"):
    cfg = configs.get_reduced(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_greedy_generation_deterministic():
    cfg, params = _setup()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out1 = generate(params, cfg, prompt, n_new=12)
    out2 = generate(params, cfg, prompt, n_new=12)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 20)
    assert int(jnp.max(out1)) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["llama3_8b", "mamba2_370m",
                                  "recurrentgemma_2b", "gemma3_27b"])
def test_engine_matches_generate(arch):
    """Slot-engine output must equal straight greedy generation for each
    request, including when slots are shared across requests."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(3)]
    n_new = 6

    want = [np.asarray(generate(params, cfg,
                                jnp.asarray(p[None]), n_new))[0]
            for p in prompts]

    eng = Engine(params, cfg, n_slots=2, max_len=6 + n_new)
    reqs = [Request(prompt=p, max_new=n_new) for p in prompts]
    done = eng.run(reqs)
    for r, w in zip(done, want):
        np.testing.assert_array_equal(r.out, w)


def test_engine_more_requests_than_slots():
    cfg, params = _setup("mamba2_370m")
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32), max_new=5) for _ in range(5)]
    eng = Engine(params, cfg, n_slots=2, max_len=16)
    done = eng.run(reqs)
    assert len(done) == 5
    for r in done:
        assert len(r.out) == 9


def test_temperature_sampling_respects_vocab():
    cfg, params = _setup()
    prompt = jax.random.randint(jax.random.PRNGKey(2), (4, 4), 0,
                                cfg.vocab_size)
    out = generate(params, cfg, prompt, n_new=8, temperature=1.0,
                   key=jax.random.PRNGKey(3))
    assert int(jnp.max(out)) < cfg.vocab_size
