"""Multi-tenant serving tests: the stacked-center batched assignment
primitive (oracle + bit-parity vs a per-tenant serial loop on all three
backends), the ClusterServeEngine's continuous batching (ragged tenants,
empty tenants, bounded compiled specializations, budgeted refresh
scheduling), and the single-tenant service delegation."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as backend_mod
from repro.kernels import ops, ref
from repro.serve import ClusterServeEngine, StaticCenters
from repro.stream import ClusterQueryService, StreamState, TreeConfig

BACKENDS = ("jnp", "jnp_chunked", "pallas")

# (T, m, k, d): tenant count, queries/tenant, max centers, dim
SHAPES = [
    (1, 8, 4, 3),       # degenerate single tenant
    (5, 12, 8, 16),     # small multi-tenant
    (9, 33, 17, 7),     # ragged everywhere
]

# same tree shape as tests/test_stream.py -- shares the solve jit cache
SCFG = TreeConfig(k=4, t=60, d=6, batch_size=200, levels=12)


def _tenants(T, m, k, d, seed=0):
    """Random stacked queries/centers with ragged live center counts,
    sentinel-filled beyond each tenant's k_real (the masking contract)."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((T, m, d)).astype(np.float32)
    c = rng.standard_normal((T, k, d)).astype(np.float32)
    k_real = rng.integers(1, k + 1, size=T)
    mask = np.arange(k)[None, :] < k_real[:, None]
    c_sent = np.where(mask[..., None], c, ref.CENTER_SENTINEL)
    return (jnp.asarray(q), jnp.asarray(c), jnp.asarray(c_sent),
            jnp.asarray(mask), k_real)


# -- stacked-center primitive ------------------------------------------------

@pytest.mark.parametrize("T,m,k,d", SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_matches_oracle(T, m, k, d, backend):
    q, _, c_sent, _, _ = _tenants(T, m, k, d)
    md_ref, am_ref = ref.min_dist_argmin_batched_ref(q, c_sent)
    md, am = backend_mod.get_backend(backend).min_dist_argmin_batched(
        q, c_sent)
    assert md.shape == (T, m) and am.shape == (T, m)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(am_ref))
    np.testing.assert_allclose(np.asarray(md), np.asarray(md_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,m,k,d", SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_parity_vs_serial_loop(T, m, k, d, backend):
    """The acceptance contract: one stacked dispatch must reproduce a
    per-tenant serial loop over the same stacked buffers -- bit-exact on
    the jnp backends (vmap lowers each tenant slice to the identical
    arithmetic), <= 1e-6 on pallas (its padded-k tiling differs)."""
    q, _, c_sent, _, _ = _tenants(T, m, k, d, seed=1)
    be = backend_mod.get_backend(backend)
    md_b, am_b = be.min_dist_argmin_batched(q, c_sent)
    for t in range(T):
        md_s, am_s = be.min_dist_argmin(q[t], c_sent[t])
        if backend == "pallas":
            np.testing.assert_allclose(np.asarray(md_b[t]),
                                       np.asarray(md_s),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_array_equal(np.asarray(am_b[t]),
                                          np.asarray(am_s))
        else:
            np.testing.assert_array_equal(np.asarray(md_b[t]),
                                          np.asarray(md_s))
            np.testing.assert_array_equal(np.asarray(am_b[t]),
                                          np.asarray(am_s))


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_mask_matches_real_ragged_centers(backend):
    """query_assignments_batched with a live-row mask must agree with
    serial per-tenant queries against each tenant's REAL (sliced, ragged)
    center set: identical assignments, distances to ~f32 (different XLA
    shape lowerings may differ in the last bit)."""
    T, m, k, d = 6, 16, 9, 5
    q, c, _, mask, k_real = _tenants(T, m, k, d, seed=2)
    a, dist = backend_mod.query_assignments_batched(q, c, mask,
                                                    backend=backend)
    for t in range(T):
        a_s, d_s = backend_mod.query_assignments(
            q[t], c[t, :int(k_real[t])], backend=backend)
        np.testing.assert_array_equal(np.asarray(a[t]), np.asarray(a_s))
        np.testing.assert_allclose(np.asarray(dist[t]), np.asarray(d_s),
                                   rtol=1e-5, atol=1e-6)


def test_batched_chunked_backend_actually_chunks():
    """A chunk smaller than T*m forces the lax.map tenant-block path; the
    padded tenant blocks (sentinel centers) must not leak into results."""
    T, m, k, d = 7, 12, 5, 9
    q, _, c_sent, _, _ = _tenants(T, m, k, d, seed=3)
    tiny = backend_mod.JnpChunkedBackend(chunk=16, name="_test_tiny_chunk")
    md, am = tiny.min_dist_argmin_batched(q, c_sent)
    md_ref, am_ref = ref.min_dist_argmin_batched_ref(q, c_sent)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(am_ref))
    # the blocked lax.map lowering may differ from the serial loop in the
    # last f32 bit; bit-exactness is contractual only for the vmap path
    np.testing.assert_allclose(np.asarray(md), np.asarray(md_ref),
                               rtol=1e-6, atol=1e-6)


def test_kmedian_objective_reports_euclidean():
    T, m, k, d = 3, 8, 4, 6
    q, c, _, mask, _ = _tenants(T, m, k, d, seed=4)
    _, d_km = backend_mod.query_assignments_batched(q, c, mask,
                                                    objective="kmeans")
    _, d_md = backend_mod.query_assignments_batched(q, c, mask,
                                                    objective="kmedian")
    np.testing.assert_allclose(np.asarray(d_md),
                               np.sqrt(np.asarray(d_km)), rtol=1e-6)


# -- pad_queries cap / chunking (satellite) ---------------------------------

def test_pad_queries_max_bucket_caps_and_raises():
    pts = jnp.zeros((100, 4), jnp.float32)
    padded, n = ops.pad_queries(pts, max_bucket=128)
    assert padded.shape[0] == 128 and n == 100
    with pytest.raises(ValueError, match="chunk_queries"):
        ops.pad_queries(pts, max_bucket=64)
    with pytest.raises(ValueError, match="max_bucket"):
        ops.query_bucket(10, min_bucket=8, max_bucket=4)


def test_chunk_queries_covers_exactly_with_bounded_shapes():
    rng = np.random.default_rng(0)
    for n in [0, 1, 7, 8, 64, 65, 200, 1000]:
        pts = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
        chunks = ops.chunk_queries(pts, min_bucket=8, max_bucket=64)
        # exact coverage, in order, no overlap
        assert [c[2] for c in chunks] == \
            [sum(c[1] for c in chunks[:i]) for i in range(len(chunks))]
        assert sum(c[1] for c in chunks) == n
        for padded, nc, off in chunks:
            assert padded.shape[0] in (8, 16, 32, 64)
            assert nc <= padded.shape[0] <= 64
            np.testing.assert_array_equal(np.asarray(padded[:nc]),
                                          np.asarray(pts[off:off + nc]))


def test_compiled_shape_set_bounded_under_adversarial_sweep():
    """Regression for unbounded bucket growth: an adversarial sweep of
    batch sizes (every size 1..70 plus oversized bursts) must keep the
    engine's compiled-specialization set within the bounded bucket set."""
    rng = np.random.default_rng(5)
    eng = ClusterServeEngine(backend="jnp", min_bucket=8, max_bucket=64)
    c = rng.standard_normal((4, 8)).astype(np.float32)
    tid = eng.add_tenant(StaticCenters(c), k=4, d=8)
    for n in list(range(1, 71)) + [500, 1337]:
        eng.enqueue(tid, rng.standard_normal((n, 8)).astype(np.float32))
        eng.run()
    buckets = {s[1] for s in eng.compiled_shapes}
    assert buckets <= {8, 16, 32, 64}
    # specializations live on a pow2 grid in both the query bucket and the
    # stacked-tenant axis (multi-chunk bursts stack same-tenant chunks)
    assert all((s[0] & (s[0] - 1)) == 0 for s in eng.compiled_shapes)
    n_buckets = int(math.log2(64 / 8)) + 1
    assert len(eng.compiled_shapes) <= 2 * n_buckets


# -- ClusterServeEngine ------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_multi_tenant_parity(backend):
    """Fused multi-tenant serving == per-tenant serial query_assignments
    across a ragged k/d mix, including empty query batches and idle
    tenants."""
    rng = np.random.default_rng(7)
    eng = ClusterServeEngine(backend=backend, max_bucket=32, max_group=4)
    work = []
    for t in range(9):
        k = int(rng.integers(1, 9))
        d = int(rng.choice([4, 6, 8]))
        c = rng.standard_normal((k, d)).astype(np.float32)
        tid = eng.add_tenant(StaticCenters(c), k=k, d=d)
        n = [0, 1, 5, 40][t % 4]        # incl. empty batches
        q = rng.standard_normal((n, d)).astype(np.float32)
        work.append((eng.enqueue(tid, q), q, c))
    eng.add_tenant(StaticCenters(np.zeros((2, 4), np.float32)), k=2, d=4)
    served = eng.run()
    assert served == sum(q.shape[0] for _, q, _ in work)
    for ticket, q, c in work:
        assert ticket.done
        if q.shape[0] == 0:
            assert ticket.assign.shape == (0,)
            continue
        a_s, d_s = backend_mod.query_assignments(jnp.asarray(q),
                                                 jnp.asarray(c),
                                                 backend=backend)
        np.testing.assert_array_equal(ticket.assign, np.asarray(a_s))
        np.testing.assert_allclose(ticket.dist, np.asarray(d_s),
                                   rtol=1e-5, atol=1e-6)
    # fused: fewer device dispatches than tenant-chunks served
    assert eng.stats.n_dispatches < eng.stats.n_tenant_dispatches


def test_engine_empty_step_is_noop():
    eng = ClusterServeEngine(backend="jnp")
    eng.add_tenant(StaticCenters(np.zeros((3, 4), np.float32)), k=3, d=4)
    before = eng.stats.as_dict()
    assert eng.step() == 0
    assert eng.run() == 0
    assert eng.stats.as_dict() == before
    assert eng.compiled_shapes == set()


def test_engine_validation_errors():
    eng = ClusterServeEngine(backend="jnp")
    with pytest.raises(TypeError, match="center source"):
        eng.add_tenant(object(), k=3, d=4)
    tid = eng.add_tenant(StaticCenters(np.zeros((3, 4), np.float32)),
                         k=3, d=4)
    with pytest.raises(ValueError, match="already registered"):
        eng.add_tenant(StaticCenters(np.zeros((3, 4), np.float32)),
                       k=3, d=4, tenant_id=tid)
    with pytest.raises(ValueError, match="k >= 1"):
        eng.add_tenant(StaticCenters(np.zeros((1, 4), np.float32)),
                       k=0, d=4)
    with pytest.raises(KeyError, match="unknown tenant"):
        eng.enqueue(tid + 999, np.zeros((2, 4), np.float32))
    with pytest.raises(ValueError, match="query points"):
        eng.enqueue(tid, np.zeros((2, 5), np.float32))
    with pytest.raises(ValueError, match="max_bucket"):
        ClusterServeEngine(backend="jnp", min_bucket=64, max_bucket=8)


def _stream_service(seed, **kw):
    from repro.data.synthetic import drifting_mixture_stream
    stream = StreamState(SCFG, key=jax.random.PRNGKey(seed))
    batch = list(drifting_mixture_stream(1, SCFG.batch_size, d=SCFG.d, k=4,
                                         seed=seed))[0]
    stream.push(batch)
    return ClusterQueryService(stream, k=4, backend="jnp", **kw)


def test_engine_refresh_budget_amortizes_across_tenants():
    """With refresh_budget=1, one step re-solves at most one tenant; a
    never-solved tenant's queries wait for a later step (deferred, not
    dropped), while an already-solved stale tenant keeps serving its
    cached (stale) centers instead of blocking on its own re-solve."""
    eng = ClusterServeEngine(backend="jnp", refresh_budget=1)
    s1 = _stream_service(1, staleness_frac=0.0, tenant_id=101, engine=eng)
    s2 = _stream_service(2, staleness_frac=0.0, tenant_id=102, engine=eng)
    t1 = eng.add_tenant(s1, k=4, d=SCFG.d, tenant_id=101)
    t2 = eng.add_tenant(s2, k=4, d=SCFG.d, tenant_id=102)
    q = np.zeros((5, SCFG.d), np.float32)
    k1, k2 = eng.enqueue(t1, q), eng.enqueue(t2, q)
    served = eng.step()
    # one refresh ran, the other tenant (never solved) was deferred whole
    assert eng.stats.n_refreshes == 1
    assert eng.stats.n_deferred_refreshes == 1
    assert served == 5 and k1.done != k2.done
    served = eng.step()
    assert served == 5 and k1.done and k2.done
    assert eng.stats.n_refreshes == 2
    # both solved now; a stale tenant with cached centers is served
    # immediately even when its refresh is deferred by the budget
    s1.push(np.zeros((10, SCFG.d), np.float32))
    s2.push(np.zeros((10, SCFG.d), np.float32))
    assert s1.is_stale() and s2.is_stale()
    k1, k2 = eng.enqueue(t1, q), eng.enqueue(t2, q)
    served = eng.step()
    assert served == 10 and k1.done and k2.done
    assert eng.stats.n_refreshes == 3
    assert eng.stats.n_deferred_refreshes == 2


def test_service_delegation_matches_direct_and_counts_padding():
    svc = _stream_service(3, staleness_frac=None)
    rng = np.random.default_rng(9)
    q = rng.standard_normal((73, SCFG.d)).astype(np.float32)
    assign, dist = svc.query(q)
    a_s, d_s = backend_mod.query_assignments(jnp.asarray(q), svc.centers(),
                                             backend="jnp")
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(a_s))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(d_s),
                               rtol=1e-5, atol=1e-6)
    st = svc.stats.as_dict()
    assert st["n_queries"] == 73
    assert st["n_padded_queries"] == 128 - 73    # next bucket
    assert 0.0 < st["padded_frac"] < 1.0
    assert st["refresh_s"] > 0.0 and st["assign_s"] > 0.0
    assert st["n_refreshes"] == 1


def test_service_oversized_batch_chunks_instead_of_growing():
    svc = _stream_service(4, staleness_frac=None, max_bucket=64)
    rng = np.random.default_rng(11)
    q = rng.standard_normal((200, SCFG.d)).astype(np.float32)
    assign, dist = svc.query(q)
    assert assign.shape == (200,)
    a_s, _ = backend_mod.query_assignments(jnp.asarray(q), svc.centers(),
                                           backend="jnp")
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(a_s))
    buckets = {s[1] for s in svc._engine.compiled_shapes}
    assert buckets <= {8, 16, 32, 64}
    # query_load chunks the same way and keeps counts exact
    load = np.asarray(svc.query_load(q))
    np.testing.assert_allclose(load.sum(), 200.0, rtol=1e-5)
