"""First-class coreset-strategy layer (DESIGN.md Sec. 16).

Covers the registry boundary (unknown strategy names raise with the
registered names listed at every public API), the bit-compat discipline
(``"algorithm1"`` through the descriptor equals a frozen copy of the
pre-strategy-layer choreography bit for bit on all three backends, and
the sim/exec/tree/async engines all agree), the key-derivation
consolidation (every engine consumes the descriptor's one key table --
the sim, exec, and async paths used to re-derive it independently), the
per-strategy invariants as a hypothesis property (total coreset weight
preserved and ``sum(t_i) == t`` across ring/star/grid/ER/wan topologies
and sim/exec engines), and the communication claim that motivates the
mapreduce strategy: its single shuffle strictly undercuts Algorithm 1's
flood bytes.
"""
import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import strategy, topology
from repro.core.coreset import (DistributedCoreset, distributed_coreset,
                                proportional_allocation, round1_local_solves,
                                round2_local_samples)
from repro.core.distributed import (distributed_kmeans_tree,
                                    exec_algorithm1_rounds,
                                    graph_distributed_kmeans,
                                    spmd_distributed_kmeans_fn)
from repro.core.message_passing import gossip_schedule
from repro.core.topology import bfs_spanning_tree
from repro.stream.ingest import DistributedStream
from repro.stream.tree import TreeConfig
from repro.wan.faults import FaultPlan

BACKENDS = ("jnp", "jnp_chunked", "pallas")
STRATEGIES = strategy.available_strategies()

K, D, T = 3, 4, 48
N_SITES = 6


@pytest.fixture(scope="module")
def sites():
    """Well-separated 3-cluster mixture split over 6 uneven sites."""
    rng = np.random.default_rng(0)
    cs = 4.0 * rng.standard_normal((K, D))
    pts = np.concatenate([cs[i] + 0.25 * rng.standard_normal((120, D))
                          for i in range(K)]).astype(np.float32)
    rng.shuffle(pts)
    sp = jnp.asarray(pts.reshape(N_SITES, -1, D))
    sm = jnp.ones(sp.shape[:2], bool)
    return sp, sm


def _digest(*arrs) -> str:
    h = hashlib.sha256()
    for a in arrs:
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# registry boundary
# ---------------------------------------------------------------------------

def test_registry_lists_known_names():
    assert set(STRATEGIES) >= {"algorithm1", "cohen_addad", "mapreduce"}
    with pytest.raises(ValueError, match="unknown strategy"):
        strategy.resolve_name("algorithm_1")
    with pytest.raises(ValueError, match="mapreduce"):
        # the error must list the registered names
        strategy.resolve_name("algorithm_1")
    with pytest.raises(TypeError):
        strategy.resolve_name(3)
    assert strategy.resolve_name(None) == "algorithm1"
    assert strategy.resolve_name(strategy.ALGORITHM1) == "algorithm1"


def test_register_shadowing_raises():
    with pytest.raises(ValueError, match="already registered"):
        strategy.register_strategy(strategy.CoresetStrategy(
            name="algorithm1",
            exchange_spec_fn=strategy.MAPREDUCE.exchange_spec_fn))
    # re-registering the same instance is a no-op
    strategy.register_strategy(strategy.ALGORITHM1)


def test_unknown_strategy_raises_at_every_public_boundary(sites):
    sp, sm = sites
    key = jax.random.PRNGKey(0)
    g = topology.ring(N_SITES)
    with pytest.raises(ValueError, match="unknown strategy"):
        distributed_coreset(key, sp, sm, K, T, strategy="zigzag")
    with pytest.raises(ValueError, match="unknown strategy"):
        graph_distributed_kmeans(key, sp, sm, K, T, graph=g,
                                 strategy="zigzag")
    with pytest.raises(ValueError, match="unknown strategy"):
        distributed_kmeans_tree(key, sp, sm, K, T,
                                tree=bfs_spanning_tree(g, 0),
                                strategy="zigzag")
    with pytest.raises(ValueError, match="unknown strategy"):
        spmd_distributed_kmeans_fn("sites", N_SITES, K, T, T,
                                   strategy="zigzag")
    ds = DistributedStream(g, TreeConfig(d=D, k=K, t=32, batch_size=32))
    with pytest.raises(ValueError, match="unknown strategy"):
        ds.aggregate(k=K, t=T, strategy="zigzag")


def test_flood_exec_rejects_single_shuffle_strategies(sites):
    """The gossip flood engine has no scalar round to run for a
    single-shuffle strategy; the public API reroutes to the tree
    protocol, and the raw entry point must refuse loudly."""
    sp, sm = sites
    sched = gossip_schedule(topology.ring(N_SITES))
    with pytest.raises(ValueError, match="no exchange round"):
        exec_algorithm1_rounds(sched, jax.random.PRNGKey(0), sp,
                               sm.astype(sp.dtype), K, T, t_buffer=T,
                               objective="kmeans", lloyd_iters=2,
                               clip_negative=False, backend="jnp",
                               strategy="mapreduce")


# ---------------------------------------------------------------------------
# bit-compat: "algorithm1" through the descriptor == frozen pre-refactor code
# ---------------------------------------------------------------------------

def _frozen_reference_algorithm1(key, site_points, site_mask, k, t,
                                 backend) -> DistributedCoreset:
    """Verbatim copy of the pre-strategy-layer ``distributed_coreset``
    choreography (PR 8 state): any drift in the descriptor indirection
    shows up as a digest mismatch here."""
    n_sites = site_points.shape[0]
    w_site = site_mask.astype(site_points.dtype)
    keys = jax.random.split(key, n_sites * 2).reshape(n_sites, 2, -1)
    centers, m, assign, local_costs, w_eff = round1_local_solves(
        keys[:, 0], site_points, w_site, k=k, objective="kmeans",
        lloyd_iters=5, backend=backend)
    total_m = jnp.sum(local_costs)
    t_i = proportional_allocation(local_costs, t)
    portions = round2_local_samples(
        keys[:, 1], site_points, m, w_eff, assign, centers, t_i,
        jnp.broadcast_to(total_m, (n_sites,)), k=k, t=t, t_buffer=t,
        clip_negative=False)
    return DistributedCoreset(points=portions.points,
                              weights=portions.weights, t_i=t_i,
                              local_costs=local_costs)


@pytest.mark.parametrize("backend", BACKENDS)
def test_algorithm1_bit_identical_to_pre_refactor(sites, backend):
    sp, sm = sites
    key = jax.random.PRNGKey(11)
    ref = _frozen_reference_algorithm1(key, sp, sm, K, T, backend)
    for sel in (None, "algorithm1"):
        dc = distributed_coreset(key, sp, sm, K, T, backend=backend,
                                 strategy=sel)
        assert _digest(dc.points, dc.weights, dc.t_i, dc.local_costs) == \
            _digest(ref.points, ref.weights, ref.t_i, ref.local_costs)


def test_algorithm1_engines_agree_bit_for_bit(sites):
    """sim == exec on the flood graph, sim == exec on the tree, and the
    async runtime under a trivial fault plan -- all five centers/coreset
    digests equal (the engines share one strategy-owned key table)."""
    sp, sm = sites
    key = jax.random.PRNGKey(5)
    g = topology.erdos_renyi(N_SITES, 0.5, seed=2)
    tree = bfs_spanning_tree(g, root=0)
    runs = [
        graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine="sim"),
        graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine="exec"),
        distributed_kmeans_tree(key, sp, sm, K, T, tree=tree, engine="sim"),
        distributed_kmeans_tree(key, sp, sm, K, T, tree=tree, engine="exec"),
        graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine="async",
                                 wan_mode="full", faults=FaultPlan()),
    ]
    digests = {_digest(r.centers, np.sort(np.asarray(r.coreset.weights)))
               for r in runs}
    assert len(digests) == 1


# ---------------------------------------------------------------------------
# key-derivation consolidation (satellite: the engines used to re-derive)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", STRATEGIES)
def test_strategy_key_table_is_the_all_site_discipline(name):
    strat = strategy.get_strategy(name)
    for seed, n in ((0, 3), (7, 9)):
        key = jax.random.PRNGKey(seed)
        expect = jax.random.split(key, n * 2).reshape(n, 2, -1)
        np.testing.assert_array_equal(np.asarray(strat.keys(key, n)),
                                      np.asarray(expect))


@pytest.mark.parametrize("name", STRATEGIES)
def test_engines_consume_identical_keys(sites, name):
    """Same (seed, strategy) => every engine's Round-1 scalars are
    bit-equal: they all flow from the descriptor's single key table.
    (local_costs is a pure function of the Round-1 keys per site, so
    bit-equality here is exactly key-consumption equality.)"""
    sp, sm = sites
    key = jax.random.PRNGKey(3)
    g = topology.ring(N_SITES)
    tree = bfs_spanning_tree(g, root=0)
    runs = [
        graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine="sim",
                                 strategy=name),
        graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine="exec",
                                 strategy=name),
        distributed_kmeans_tree(key, sp, sm, K, T, tree=tree, engine="exec",
                                strategy=name),
        graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine="async",
                                 wan_mode="full", faults=FaultPlan(),
                                 strategy=name),
    ]
    base = np.asarray(runs[0].local_costs)
    for r in runs[1:]:
        np.testing.assert_array_equal(np.asarray(r.local_costs), base)


# ---------------------------------------------------------------------------
# per-strategy invariants (hypothesis property)
# ---------------------------------------------------------------------------

def _graph_for(kind: str, n: int):
    if kind == "ring":
        return topology.ring(n)
    if kind == "star":
        return topology.star(n)
    if kind == "grid":
        return topology.grid(2, n // 2)
    if kind == "er":
        return topology.erdos_renyi(n, 0.6, seed=4)
    return topology.wan_clusters(2, n // 2, cross_cost=4.0, cross_links=1,
                                 seed=0)


@settings(max_examples=10, deadline=None)
@given(name=st.sampled_from(STRATEGIES),
       kind=st.sampled_from(("ring", "star", "grid", "er", "wan")),
       engine=st.sampled_from(("sim", "exec")),
       seed=st.integers(0, 2 ** 16))
def test_every_strategy_preserves_weight_and_budget(sites, name, kind,
                                                    engine, seed):
    sp, sm = sites
    key = jax.random.PRNGKey(seed)
    dc = distributed_coreset(key, sp, sm, K, T, strategy=name)
    assert int(np.asarray(dc.t_i).sum()) == T
    g = _graph_for(kind, N_SITES)
    r = graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine=engine,
                                 strategy=name, lloyd_iters=3)
    total_in = float(jnp.sum(sm))
    total_out = float(jnp.sum(r.coreset.weights))
    assert total_out == pytest.approx(total_in, rel=1e-4)
    assert np.isfinite(np.asarray(r.centers)).all()


# ---------------------------------------------------------------------------
# the mapreduce communication claim + quality sanity
# ---------------------------------------------------------------------------

def test_mapreduce_strictly_undercuts_algorithm1_bytes(sites):
    sp, sm = sites
    key = jax.random.PRNGKey(9)
    wan = topology.wan_clusters(2, N_SITES // 2, cross_cost=8.0,
                                cross_links=1, seed=0)
    for g in (topology.ring(N_SITES), wan):
        a = graph_distributed_kmeans(key, sp, sm, K, T, graph=g,
                                     engine="sim", strategy="algorithm1")
        m = graph_distributed_kmeans(key, sp, sm, K, T, graph=g,
                                     engine="sim", strategy="mapreduce")
        assert m.ledger.bytes < a.ledger.bytes
        assert m.ledger.link_cost < a.ledger.link_cost
    # the async WAN runtime skips the scalar flood too
    a = graph_distributed_kmeans(key, sp, sm, K, T, graph=wan,
                                 engine="async", wan_mode="full",
                                 faults=FaultPlan(), strategy="algorithm1")
    m = graph_distributed_kmeans(key, sp, sm, K, T, graph=wan,
                                 engine="async", wan_mode="full",
                                 faults=FaultPlan(), strategy="mapreduce")
    assert m.ledger.bytes < a.ledger.bytes


@pytest.mark.parametrize("name", STRATEGIES)
def test_strategy_centers_are_competitive(sites, name):
    """Every strategy's centers land within 1.5x of the central solve on a
    well-separated mixture (the frontier benchmark tracks the fine-grained
    accuracy-vs-bytes tradeoff; this is the coarse sanity floor)."""
    from repro.core import clustering
    sp, sm = sites
    key = jax.random.PRNGKey(1)
    g = topology.erdos_renyi(N_SITES, 0.5, seed=2)
    r = graph_distributed_kmeans(key, sp, sm, K, T, graph=g, engine="sim",
                                 strategy=name)
    flat = np.asarray(sp).reshape(-1, D)
    central, _ = clustering.solve(jax.random.PRNGKey(2), jnp.asarray(flat),
                                  K, restarts=3)
    c_dist = float(clustering.cost(jnp.asarray(flat), r.centers))
    c_central = float(clustering.cost(jnp.asarray(flat), central))
    assert c_dist <= 1.5 * c_central


def test_streaming_aggregate_accepts_strategies(sites):
    """The resample round runs through the strategy layer on both engines;
    single-shuffle strategies reroute to tree transport with no Round-1
    phase in the ledger."""
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((N_SITES * 64, D)).astype(np.float32)
    results = {}
    for name in ("algorithm1", "mapreduce"):
        for eng in ("sim", "exec"):
            ds = DistributedStream(topology.ring(N_SITES),
                                   TreeConfig(d=D, k=K, t=32, batch_size=32),
                                   key=jax.random.PRNGKey(4))
            for i in range(N_SITES):
                ds.push(i, pts[i * 64:(i + 1) * 64])
            ar = ds.aggregate(k=K, t=T, mode="resample", engine=eng,
                              strategy=name)
            results[(name, eng)] = ar
            total = float(jnp.sum(ar.coreset.weights))
            assert total == pytest.approx(ds.total_weight(), rel=1e-3)
    # engine bit-parity holds per strategy through the streaming layer
    for name in ("algorithm1", "mapreduce"):
        s, e = results[(name, "sim")], results[(name, "exec")]
        np.testing.assert_array_equal(np.asarray(s.centers),
                                      np.asarray(e.centers))
    assert (results[("mapreduce", "sim")].ledger.bytes
            < results[("algorithm1", "sim")].ledger.bytes)
