"""Streaming coreset subsystem tests: merge-and-reduce tree invariants,
summary quality vs the offline pipeline on a drifting stream, the
distributed mode's ledger accounting, and the query service."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import backend as backend_mod
from repro.core import clustering
from repro.core.coreset import Coreset, build_coreset, merge_coresets
from repro.core.topology import grid
from repro.data.synthetic import drifting_mixture_stream
from repro.stream import (ClusterQueryService, CoresetTree, DistributedStream,
                          StreamState, TreeConfig)

KEY = jax.random.PRNGKey(0)

# one tree shape for the whole module: each distinct config costs jit
# compiles of the leaf/merge solves
CFG = TreeConfig(k=4, t=60, d=6, batch_size=200, levels=12)


def _stream(n_batches, seed=0, batch=CFG.batch_size, d=CFG.d):
    return list(drifting_mixture_stream(n_batches, batch, d=d, k=4,
                                        seed=seed))


# -- Coreset.concat / compact -----------------------------------------------

def test_concat_preserves_weight_and_order():
    a = Coreset(points=jnp.ones((3, 2)), weights=jnp.asarray([1., 0., 2.]))
    b = Coreset(points=jnp.zeros((2, 2)), weights=jnp.asarray([-0.5, 3.]))
    u = Coreset.concat(a, b)
    assert u.size == 5
    np.testing.assert_allclose(float(jnp.sum(u.weights)), 5.5)
    np.testing.assert_array_equal(np.asarray(u.weights),
                                  [1., 0., 2., -0.5, 3.])


def test_compact_moves_valid_slots_front_and_truncates():
    cs = Coreset(points=jnp.arange(10, dtype=jnp.float32)[:, None],
                 weights=jnp.asarray([0., 2., 0., 0., 1., 0., 3., 0., 0., 4.]))
    c = cs.compact(4)
    assert c.size == 4
    # stable: valid slots keep their relative order
    np.testing.assert_array_equal(np.asarray(c.weights), [2., 1., 3., 4.])
    np.testing.assert_array_equal(np.asarray(c.points[:, 0]), [1., 4., 6., 9.])
    np.testing.assert_allclose(float(jnp.sum(c.weights)),
                               float(jnp.sum(cs.weights)))


def test_merge_coresets_preserves_total_weight():
    pts = jnp.asarray(np.random.default_rng(0).standard_normal(
        (500, 6)).astype(np.float32))
    a = build_coreset(KEY, pts[:250], k=4, t=60)
    b = build_coreset(jax.random.PRNGKey(1), pts[250:], k=4, t=60)
    m = merge_coresets(jax.random.PRNGKey(2), a, b, k=4, t=60)
    assert m.size == 64
    np.testing.assert_allclose(float(jnp.sum(m.weights)), 500.0, rtol=1e-4)


# -- tree invariants ---------------------------------------------------------

def test_tree_binary_counter_occupancy():
    tree = CoresetTree(CFG)
    batches = _stream(11)
    for i, b in enumerate(batches, start=1):
        tree.push(jnp.asarray(b))
        assert tree.occupied_levels() == bin(i).count("1")
    assert tree.n_batches == 11


def test_tree_log_space_bound():
    tree = CoresetTree(CFG)
    for b in _stream(13):
        tree.push(jnp.asarray(b))
    n = 13 * CFG.batch_size
    max_levels = math.floor(math.log2(13)) + 1
    assert tree.occupied_levels() <= max_levels
    assert tree.max_summary_points() <= CFG.slot * max_levels
    assert int(tree.summary().effective_size()) <= CFG.slot * max_levels
    np.testing.assert_allclose(float(jnp.sum(tree.summary().weights)), n,
                               rtol=1e-4)
    # diagnostics surface: per-level sizes match occupancy; the compacted
    # view shrinks to the occupied capacity without losing mass
    sizes = tree.bucket_sizes()
    assert sum(1 for s in sizes if s > 0) == tree.occupied_levels()
    compact = tree.compact_summary()
    assert compact.size == tree.max_summary_points()
    np.testing.assert_allclose(float(jnp.sum(compact.weights)), n, rtol=1e-4)


def test_tree_overflow_keeps_memory_bounded():
    cfg = TreeConfig(k=4, t=60, d=6, batch_size=200, levels=2)
    tree = CoresetTree(cfg)
    for b in _stream(9, seed=3):
        tree.push(jnp.asarray(b))
    assert tree.occupied_levels() <= 2
    assert tree.summary().points.shape == (2 * cfg.slot, cfg.d)
    np.testing.assert_allclose(float(jnp.sum(tree.summary().weights)),
                               9 * 200, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(n_batches=st.integers(1, 9), tail=st.integers(0, 199),
       seed=st.integers(0, 2**31 - 1))
def test_property_summary_weight_equals_ingested(n_batches, tail, seed):
    """Property: for any stream length (including a partial batch), the
    summary's total weight equals the number of ingested points exactly --
    the signed center weights cancel the sampled mass at every merge."""
    stream = StreamState(CFG, key=jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal(
        (n_batches * CFG.batch_size + tail, CFG.d)).astype(np.float32)
    # ragged pushes: one big, then dribbles
    stream.push(pts[:len(pts) // 2])
    stream.push(pts[len(pts) // 2:])
    np.testing.assert_allclose(float(jnp.sum(stream.summary().weights)),
                               len(pts), rtol=1e-4)
    assert stream.pending() == tail
    np.testing.assert_allclose(stream.total_weight(), len(pts), rtol=1e-6)


def test_streaming_cost_within_factor_of_offline():
    """Streaming k-means on the drifting mixture stays within a constant
    factor of the offline coreset pipeline at equal summary size."""
    n_batches = 12
    batches = _stream(n_batches, seed=7)
    full = jnp.asarray(np.concatenate(batches))

    stream = StreamState(CFG)
    for b in batches:
        stream.push(b)
    s = stream.summary()
    c_stream, _ = clustering.solve(KEY, s.points, CFG.k, weights=s.weights,
                                   lloyd_iters=10)
    stream_cost = float(clustering.cost(full, c_stream))

    off = build_coreset(KEY, full, k=CFG.k,
                        t=int(s.effective_size()) - CFG.k)
    c_off, _ = clustering.solve(KEY, off.points, CFG.k, weights=off.weights,
                                lloyd_iters=10)
    offline_cost = float(clustering.cost(full, c_off))
    assert stream_cost <= 2.0 * offline_cost, (stream_cost, offline_cost)


# -- distributed mode --------------------------------------------------------

def test_distributed_stream_rounds_and_phase_ledger():
    g = grid(2, 2)
    ds = DistributedStream(g, CFG)
    batches = _stream(8, seed=11)
    for r in range(2):
        for i in range(g.n):
            ds.push(i, batches[r * g.n + i])
        res = ds.aggregate(k=4, t=120, mode="resample")
        # the aggregated global coreset preserves the total ingested mass
        np.testing.assert_allclose(float(jnp.sum(res.coreset.weights)),
                                   ds.total_weight(), rtol=1e-4)
        assert res.centers.shape == (4, CFG.d)
    d = ds.ledger.as_dict(by_phase=True)
    assert set(d["phases"]) == {"stream_round_0", "stream_round_1"}
    per_round = d["phases"]["stream_round_0"]
    # Round 1 floods n scalars over 2m edges; portions are points
    assert per_round["scalars"] == 2.0 * g.m * g.n
    assert per_round["points"] > 0
    assert per_round["bytes"] > 0
    totals = ds.ledger.as_dict()
    np.testing.assert_allclose(
        totals["points"],
        sum(p["points"] for p in d["phases"].values()))


def test_distributed_stream_union_round_is_exact():
    """When the summaries are smaller than a resample round's traffic, auto
    mode floods the union instead: exact (coreset == concat of summaries),
    no Round-1 scalars, points metered at effective size."""
    g = grid(2, 2)
    ds = DistributedStream(g, CFG)
    batches = _stream(4, seed=29)
    for i in range(g.n):
        ds.push(i, batches[i][:100])    # partial batches: tiny summaries
    res = ds.aggregate(k=4, t=600)      # budget >> support => union
    assert res.local_costs is None
    np.testing.assert_allclose(float(jnp.sum(res.coreset.weights)),
                               ds.total_weight(), rtol=1e-5)
    d = res.ledger.as_dict(by_phase=True)
    assert d["scalars"] == 0.0
    assert d["phases"]["stream_round_0"]["points"] == 2.0 * g.m * 400
    # every raw point is in the union with weight exactly 1 (no reduction
    # has happened anywhere yet)
    w = np.asarray(res.coreset.weights)
    assert set(np.unique(w)) == {0.0, 1.0}
    assert int((w == 1.0).sum()) == 400


def test_distributed_stream_uneven_sites():
    """Sites with wildly different arrival rates: allocation shifts samples
    to costly sites; empty sites are handled (zero local cost)."""
    g = grid(2, 2)
    ds = DistributedStream(g, CFG)
    batches = _stream(6, seed=13)
    for b in batches[:5]:
        ds.push(0, b)          # hot site
    ds.push(1, batches[5][:50])  # partial only
    res = ds.aggregate(k=4, t=100)
    assert np.isfinite(np.asarray(res.coreset.weights)).all()
    np.testing.assert_allclose(float(jnp.sum(res.coreset.weights)),
                               ds.total_weight(), rtol=1e-4)


def test_distributed_stream_push_rejects_bad_site():
    ds = DistributedStream(grid(2, 2), CFG)
    batch = _stream(1, seed=31)[0]
    with pytest.raises(ValueError, match="site index"):
        ds.push(4, batch)
    with pytest.raises(ValueError, match="site index"):
        ds.push(-1, batch)


@pytest.mark.parametrize("mode", ["union", "resample"])
def test_distributed_stream_exec_engine_matches_sim(mode):
    """engine="exec" runs the aggregation round through the topology
    execution engine: bit-identical coreset and centers, and the measured
    round ledger equals the analytic one exactly (per phase)."""
    g = grid(2, 2)
    key = jax.random.PRNGKey(41)
    ds_sim = DistributedStream(g, CFG, key=key)
    ds_ex = DistributedStream(g, CFG, key=key)
    batches = _stream(8, seed=37)
    for i, b in enumerate(batches):
        ds_sim.push(i % g.n, b)
        ds_ex.push(i % g.n, b)
    r_sim = ds_sim.aggregate(k=4, t=120, mode=mode)
    r_ex = ds_ex.aggregate(k=4, t=120, mode=mode, engine="exec")
    np.testing.assert_array_equal(np.asarray(r_sim.coreset.points),
                                  np.asarray(r_ex.coreset.points))
    np.testing.assert_array_equal(np.asarray(r_sim.coreset.weights),
                                  np.asarray(r_ex.coreset.weights))
    np.testing.assert_array_equal(np.asarray(r_sim.centers),
                                  np.asarray(r_ex.centers))
    sim_d, ex_d = r_sim.ledger.as_dict(), r_ex.ledger.as_dict()
    for unit in ("scalars", "points", "messages", "bytes"):
        assert sim_d[unit] == ex_d[unit], (mode, unit, sim_d, ex_d)
    # the measured ledger lands in the same cumulative phase bookkeeping
    d = ds_ex.ledger.as_dict(by_phase=True)
    assert set(d["phases"]) == {"stream_round_0"}


def test_distributed_stream_exec_engine_rejects_unknown():
    ds = DistributedStream(grid(2, 2), CFG)
    ds.push(0, _stream(1, seed=43)[0])
    with pytest.raises(ValueError, match="engine"):
        ds.aggregate(k=4, t=60, engine="warp")


# -- query service -----------------------------------------------------------

def test_service_query_matches_direct_argmin():
    stream = StreamState(CFG)
    for b in _stream(4, seed=17):
        stream.push(b)
    svc = ClusterQueryService(stream, k=4, staleness_frac=None,
                              backend="jnp")
    q = jnp.asarray(_stream(1, seed=18)[0][:73])
    assign, dist = svc.query(q)
    assert assign.shape == (73,) and dist.shape == (73,)
    centers = svc.centers()
    d2, am = backend_mod.get_backend("jnp").min_dist_argmin(q, centers)
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(am))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(d2), rtol=1e-5)


def test_service_staleness_refresh_policy():
    stream = StreamState(CFG)
    stream.push(_stream(1, seed=19)[0])
    svc = ClusterQueryService(stream, k=4, staleness_frac=0.5)
    q = np.zeros((5, CFG.d), np.float32)
    svc.query(q)
    assert svc.stats.n_refreshes == 1     # first query always solves
    svc.query(q)
    assert svc.stats.n_refreshes == 1     # fresh: no re-solve
    # ingest < 50% more: still fresh
    svc.push(_stream(1, seed=20)[0][:50])
    svc.query(q)
    assert svc.stats.n_refreshes == 1
    # ingest enough to cross the fraction: refresh on next query
    for b in _stream(2, seed=21):
        svc.push(b)
    svc.query(q)
    assert svc.stats.n_refreshes == 2
    assert svc.stats.n_batches == 4
    assert svc.stats.n_queries == 20


def test_service_query_load_histogram():
    stream = StreamState(CFG)
    stream.push(_stream(1, seed=23)[0])
    svc = ClusterQueryService(stream, k=4, backend="jnp")
    q = _stream(1, seed=24)[0]
    load = np.asarray(svc.query_load(q))
    assert load.shape == (4,)
    np.testing.assert_allclose(load.sum(), len(q), rtol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_service_empty_and_single_query_batches(backend):
    """Degenerate serving traffic: empty and single-point batches must pad
    up to the minimum bucket, not through it (pallas kernels need a
    nonzero shape)."""
    stream = StreamState(CFG)
    stream.push(_stream(1, seed=27)[0])
    svc = ClusterQueryService(stream, k=4, staleness_frac=None,
                              backend=backend)
    a, dist = svc.query(np.zeros((0, CFG.d), np.float32))
    assert a.shape == (0,) and dist.shape == (0,)
    a, dist = svc.query([])                               # ragged-empty list
    assert a.shape == (0,) and dist.shape == (0,)
    assert a.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(svc.query_load(np.zeros((0, CFG.d), np.float32))),
        np.zeros((4,), np.float32))
    a, dist = svc.query(np.zeros((CFG.d,), np.float32))   # 1-d single query
    assert a.shape == (1,) and dist.shape == (1,)
    with pytest.raises(ValueError, match="query points"):
        svc.query(np.zeros((3, CFG.d + 1), np.float32))   # wrong dimension
    with pytest.raises(ValueError, match="query points"):
        svc.query(np.zeros((3, 0), np.float32))           # zero-dim points
    with pytest.raises(ValueError, match="query points"):
        svc.query(np.zeros((0, CFG.d + 5), np.float32))   # empty, wrong d
    load = np.asarray(svc.query_load(np.zeros((3, CFG.d), np.float32),
                                     weights=np.asarray([1., 2., 3.],
                                                        np.float32)))
    np.testing.assert_allclose(load.sum(), 6.0, rtol=1e-6)


def test_service_default_seeds_decorrelated():
    """Two services built without explicit keys must not share a PRNG
    stream (the old shared-PRNGKey(0) default made every service replay
    identical restart draws): per-instance keys fold in the tenant id, so
    the k-means++ restart sequences decorrelate."""
    s1 = StreamState(CFG)
    s2 = StreamState(CFG)
    batch = _stream(1, seed=29)[0]
    s1.push(batch)
    s2.push(batch)
    svc1 = ClusterQueryService(s1, k=4, staleness_frac=None, backend="jnp")
    svc2 = ClusterQueryService(s2, k=4, staleness_frac=None, backend="jnp")
    assert svc1.tenant_id != svc2.tenant_id
    assert not np.array_equal(np.asarray(svc1._key), np.asarray(svc2._key))
    # the restart seeds drawn at refresh time differ too
    k1 = jax.random.split(svc1._key)[1]
    k2 = jax.random.split(svc2._key)[1]
    assert not np.array_equal(np.asarray(k1), np.asarray(k2))
    # explicit tenant ids pin the stream deterministically
    svc3 = ClusterQueryService(s1, k=4, tenant_id=svc1.tenant_id)
    np.testing.assert_array_equal(np.asarray(svc1._key),
                                  np.asarray(svc3._key))
    # an explicit key still wins over the derived default
    svc4 = ClusterQueryService(s1, k=4, key=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(svc4._key),
                                  np.asarray(jax.random.PRNGKey(7)))


@pytest.mark.parametrize("backend", ["jnp_chunked", "pallas"])
def test_service_backend_parity(backend):
    """Query assignments agree across backends (pallas runs in interpret
    mode on CPU) -- the bench_stream acceptance check, in miniature."""
    stream = StreamState(CFG)
    stream.push(_stream(1, seed=25)[0])
    svc_ref = ClusterQueryService(stream, k=4, staleness_frac=None,
                                  backend="jnp")
    centers = svc_ref.refresh()
    q = jnp.asarray(_stream(1, seed=26)[0][:64])
    a_ref, d_ref = backend_mod.query_assignments(q, centers, backend="jnp")
    a, d = backend_mod.query_assignments(q, centers, backend=backend)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), rtol=1e-5)


@pytest.mark.parametrize("mode", ["union", "resample"])
@pytest.mark.parametrize("routing", ["bfs", "min_cost"])
def test_distributed_stream_tree_transport_matches_sim(mode, routing):
    """transport="tree" runs the aggregation round over a spanning tree
    (gather to the root, broadcast the assembled coreset back): same
    bit-parity contract as the flood transport, under both routings, and
    the measured exec ledger equals the analytic tree ledger exactly --
    including the cost-weighted link_cost axis on heterogeneous links."""
    from repro.core.topology import wan_clusters
    g = wan_clusters(2, 2, cross_cost=16.0, cross_links=2, seed=3)
    key = jax.random.PRNGKey(47)
    ds_sim = DistributedStream(g, CFG, key=key)
    ds_ex = DistributedStream(g, CFG, key=key)
    batches = _stream(8, seed=53)
    for i, b in enumerate(batches):
        ds_sim.push(i % g.n, b)
        ds_ex.push(i % g.n, b)
    r_sim = ds_sim.aggregate(k=4, t=120, mode=mode, transport="tree",
                             routing=routing)
    r_ex = ds_ex.aggregate(k=4, t=120, mode=mode, transport="tree",
                           routing=routing, engine="exec")
    np.testing.assert_array_equal(np.asarray(r_sim.coreset.points),
                                  np.asarray(r_ex.coreset.points))
    np.testing.assert_array_equal(np.asarray(r_sim.coreset.weights),
                                  np.asarray(r_ex.coreset.weights))
    np.testing.assert_array_equal(np.asarray(r_sim.centers),
                                  np.asarray(r_ex.centers))
    sim_d, ex_d = r_sim.ledger.as_dict(), r_ex.ledger.as_dict()
    for unit in ("scalars", "points", "messages", "bytes", "link_cost"):
        assert sim_d[unit] == ex_d[unit], (mode, unit, sim_d, ex_d)


def test_distributed_stream_tree_transport_cheaper_than_flood():
    """A tree round moves O(sum_v depth_v) units instead of the flood's
    O(m n); on WAN links the min-cost tree also strictly beats the BFS
    tree on cost-weighted bytes (the broadcast pays one cross link per
    rack instead of one per shallow entry point)."""
    from repro.core.topology import wan_clusters
    g = wan_clusters(2, 3, cross_cost=16.0, cross_links=3, seed=0)
    key = jax.random.PRNGKey(59)
    ledgers = {}
    for transport, routing in [("flood", "bfs"), ("tree", "bfs"),
                               ("tree", "min_cost")]:
        ds = DistributedStream(g, CFG, key=key)
        for i, b in enumerate(_stream(8, seed=61)):
            ds.push(i % g.n, b)
        res = ds.aggregate(k=4, t=120, mode="resample", transport=transport,
                           routing=routing)
        ledgers[(transport, routing)] = res.ledger
    assert ledgers[("tree", "bfs")].link_cost \
        < ledgers[("flood", "bfs")].link_cost
    assert ledgers[("tree", "min_cost")].link_cost \
        < ledgers[("tree", "bfs")].link_cost


def test_distributed_stream_rejects_unknown_transport():
    ds = DistributedStream(grid(2, 2), CFG)
    ds.push(0, _stream(1, seed=43)[0])
    with pytest.raises(ValueError, match="transport"):
        ds.aggregate(k=4, t=60, transport="pigeon")
    with pytest.raises(ValueError, match="routing"):
        ds.aggregate(k=4, t=60, transport="tree", routing="warp")
