"""Topology execution engine tests (DESIGN.md Sec. 11).

The engine contract, asserted here for every topology generator:

* executed floods/tree routes deliver bit-identical payload copies to the
  nodes the protocol says should hold them;
* the *measured* CommLedger (counted transmission by transmission from the
  compiled schedule) equals the *analytic* ledger exactly;
* ``engine="exec"`` of Algorithm 2 is bit-identical to the host-simulation
  oracle on every node, for both objectives.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.comm import (flood_cost, tree_broadcast_cost,
                             tree_gather_cost, tree_up_cost)
from repro.core.distributed import (distributed_kmeans_tree,
                                    graph_distributed_kmeans)
from repro.core.message_passing import (GossipSchedule, TreeSchedule, flood,
                                        flood_exec, tree_broadcast_exec,
                                        tree_gather_exec, tree_scatter_exec,
                                        tree_up_sum_exec)
from repro.core.partition import pad_partition, partition_indices

KEY = jax.random.PRNGKey(0)

# every generator, all on 9 nodes so the end-to-end runs share jit caches
TOPOLOGIES = {
    "ring": lambda: topology.ring(9),
    "star": lambda: topology.star(9),
    "grid": lambda: topology.grid(3, 3),
    "er": lambda: topology.erdos_renyi(9, 0.3, seed=3),
    "preferential": lambda: topology.preferential(9, 2, seed=0),
}


def _graph(name):
    return TOPOLOGIES[name]()


@pytest.fixture(scope="module")
def site_data():
    rng = np.random.default_rng(0)
    k, d, n_sites = 3, 5, 9
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.2 * rng.standard_normal((150, d)) for i in range(k)]
    ).astype(np.float32)
    idx = partition_indices(pts, n_sites, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    return jnp.asarray(sp), jnp.asarray(sm), k


# -- generators --------------------------------------------------------------

def test_ring_star_shapes():
    r = topology.ring(6)
    assert r.m == 6 and all(len(a) == 2 for a in r.adjacency())
    assert topology.diameter(r) == 3
    s = topology.star(6)
    assert s.m == 5 and topology.diameter(s) == 2
    assert len(s.adjacency()[0]) == 5
    with pytest.raises(ValueError):
        topology.ring(1)
    with pytest.raises(ValueError):
        topology.star(1)


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_new_generators_flood_connected(name):
    g = _graph(name)
    res = flood(g)
    assert all(r == set(range(g.n)) for r in res.received)


# -- flood_exec: delivery, quiescence, measured == analytic ------------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_flood_exec_delivers_and_meters_exactly(name):
    g = _graph(name)
    vals = jnp.asarray(
        np.random.default_rng(1).standard_normal((g.n, 3)).astype(np.float32))
    tables, res = flood_exec(g, vals, unit_scalars=1.0)
    # every node holds every origin's payload, bit-identical
    for v in range(g.n):
        np.testing.assert_array_equal(np.asarray(tables[v]),
                                      np.asarray(vals))
    # quiescence: knowledge complete within diameter rounds
    assert res.rounds_to_complete <= topology.diameter(g)
    assert res.rounds == topology.diameter(g) + 1
    # measured == analytic, exactly
    analytic = flood_cost(g, n_messages=g.n, unit_scalars=1.0)
    assert res.ledger.scalars == analytic.scalars
    assert res.ledger.messages == analytic.messages == 2 * g.m * g.n
    assert sum(res.per_round_transmissions) == 2 * g.m * g.n
    # executed profile matches the host simulation round for round
    sim = flood(g)
    assert res.per_round_transmissions == sim.per_round_transmissions


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_flood_exec_per_origin_units(name):
    g = _graph(name)
    vals = jnp.zeros((g.n, 1))
    units = np.arange(g.n, dtype=np.float64)   # origin o ships o points
    _, res = flood_exec(g, vals, unit_points=units, dim=4)
    analytic = flood_cost(g, n_messages=1, unit_points=float(units.sum()),
                          dim=4)
    assert res.ledger.points == analytic.points == 2 * g.m * units.sum()
    assert res.ledger.dim == 4


def test_flood_exec_rejects_wrong_payload_length():
    g = topology.ring(5)
    with pytest.raises(ValueError):
        flood_exec(g, jnp.zeros((4, 1)))


def test_gossip_schedule_static_shapes():
    g = topology.star(7)
    sched = GossipSchedule.from_graph(g)
    assert sched.neighbors.shape == (7, 6)       # hub degree pads everyone
    assert sched.neighbor_mask.sum() == 2 * g.m
    assert sched.n_rounds == topology.diameter(g) + 1


# -- tree primitives ---------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_tree_gather_scatter_roundtrip_and_ledger(name):
    g = _graph(name)
    tree = topology.bfs_spanning_tree(g, root=0)
    sched = TreeSchedule.from_tree(tree)
    vals = jnp.asarray(
        np.random.default_rng(2).standard_normal((g.n, 2)).astype(np.float32))
    root_table, gres = tree_gather_exec(sched, vals, unit_scalars=1.0)
    np.testing.assert_array_equal(np.asarray(root_table), np.asarray(vals))
    analytic = tree_gather_cost(tree, unit_scalars_per_node=1.0)
    assert gres.ledger.scalars == analytic.scalars == sum(tree.depth)
    assert gres.ledger.messages == analytic.messages

    own, sres = tree_scatter_exec(sched, vals, unit_scalars=1.0)
    np.testing.assert_array_equal(np.asarray(own), np.asarray(vals))
    assert sres.ledger.scalars == analytic.scalars  # path symmetry


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_tree_up_sum_and_broadcast(name):
    g = _graph(name)
    tree = topology.bfs_spanning_tree(g, root=0)
    sched = TreeSchedule.from_tree(tree)
    vals = jnp.asarray(
        np.random.default_rng(3).standard_normal((g.n, 2)).astype(np.float32))
    totals, ures = tree_up_sum_exec(sched, vals, broadcast=True,
                                    unit_scalars=1.0)
    expect = np.asarray(vals.sum(axis=0))
    for v in range(g.n):
        np.testing.assert_allclose(np.asarray(totals[v]), expect, rtol=1e-5)
    # up n-1 sends + broadcast n-1 sends, one scalar-unit each
    assert ures.ledger.scalars == 2.0 * (g.n - 1)
    assert ures.ledger.messages == 2.0 * (g.n - 1)

    payload = jnp.asarray(np.random.default_rng(4).standard_normal(
        (4, 2)).astype(np.float32))
    out, bres = tree_broadcast_exec(sched, payload, unit_points=4.0, dim=2)
    for v in range(g.n):
        np.testing.assert_array_equal(np.asarray(out[v]),
                                      np.asarray(payload))
    analytic = tree_broadcast_cost(tree, unit_points=4.0, dim=2)
    assert bres.ledger.points == analytic.points == 4.0 * (g.n - 1)
    assert bres.ledger.messages == analytic.messages == g.n - 1


# -- Algorithm 2: engine == simulation, measured == analytic -----------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_graph_engine_matches_simulation(site_data, name):
    sp, sm, k = site_data
    g = _graph(name)
    t = 90
    sim = graph_distributed_kmeans(KEY, sp, sm, k, t=t, graph=g)
    ex = graph_distributed_kmeans(KEY, sp, sm, k, t=t, graph=g,
                                  engine="exec")
    # bit-identical centers and coreset
    np.testing.assert_array_equal(np.asarray(sim.centers),
                                  np.asarray(ex.centers))
    np.testing.assert_array_equal(np.asarray(sim.coreset.points),
                                  np.asarray(ex.coreset.points))
    np.testing.assert_array_equal(np.asarray(sim.coreset.weights),
                                  np.asarray(ex.coreset.weights))
    # measured ledger == analytic ledger, exactly
    assert ex.ledger.scalars == sim.ledger.scalars
    assert ex.ledger.points == sim.ledger.points
    assert ex.ledger.messages == sim.ledger.messages
    # every node assembled the identical global instance and allocation
    det = ex.exec_detail
    npts, nw = np.asarray(det.node_points), np.asarray(det.node_weights)
    alloc = np.asarray(det.node_alloc)
    for v in range(g.n):
        np.testing.assert_array_equal(npts[v], npts[0])
        np.testing.assert_array_equal(nw[v], nw[0])
        np.testing.assert_array_equal(alloc[v], alloc[0])
    assert alloc[0].sum() == t


def test_graph_engine_every_node_solves_identically(site_data):
    """Acceptance: every node, solving its own received copy, produces the
    same centers the engine reports."""
    sp, sm, k = site_data
    g = _graph("er")
    from repro.core.coreset import Coreset
    from repro.core.distributed import _solve_on_coreset
    ex = graph_distributed_kmeans(KEY, sp, sm, k, t=90, graph=g,
                                  engine="exec")
    _, k2 = jax.random.split(KEY)
    det = ex.exec_detail
    for v in range(g.n):
        cs_v = Coreset(det.node_points[v], det.node_weights[v])
        centers_v = _solve_on_coreset(k2, cs_v, k, "kmeans", 8, None)
        np.testing.assert_array_equal(np.asarray(centers_v),
                                      np.asarray(ex.centers))


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_tree_engine_matches_simulation(site_data, name):
    sp, sm, k = site_data
    g = _graph(name)
    tree = topology.bfs_spanning_tree(g, root=0)
    t = 90
    sim = distributed_kmeans_tree(KEY, sp, sm, k, t=t, tree=tree)
    ex = distributed_kmeans_tree(KEY, sp, sm, k, t=t, tree=tree,
                                 engine="exec")
    np.testing.assert_array_equal(np.asarray(sim.centers),
                                  np.asarray(ex.centers))
    np.testing.assert_array_equal(np.asarray(sim.coreset.points),
                                  np.asarray(ex.coreset.points))
    np.testing.assert_array_equal(np.asarray(sim.coreset.weights),
                                  np.asarray(ex.coreset.weights))
    assert ex.ledger.scalars == sim.ledger.scalars
    assert ex.ledger.points == sim.ledger.points
    assert ex.ledger.messages == sim.ledger.messages
    # the broadcast delivered the identical solution to every node
    nc = np.asarray(ex.exec_detail.node_centers)
    for v in range(g.n):
        np.testing.assert_array_equal(nc[v], np.asarray(ex.centers))
    assert np.asarray(ex.exec_detail.node_alloc).sum() == t


@pytest.mark.parametrize("objective", ["kmeans", "kmedian"])
def test_engine_both_objectives(site_data, objective):
    sp, sm, k = site_data
    g = _graph("grid")
    sim = graph_distributed_kmeans(KEY, sp, sm, k, t=60, graph=g,
                                   objective=objective, lloyd_iters=4)
    ex = graph_distributed_kmeans(KEY, sp, sm, k, t=60, graph=g,
                                  objective=objective, lloyd_iters=4,
                                  engine="exec")
    np.testing.assert_array_equal(np.asarray(sim.centers),
                                  np.asarray(ex.centers))
    tree = topology.bfs_spanning_tree(g, root=0)
    sim_t = distributed_kmeans_tree(KEY, sp, sm, k, t=60, tree=tree,
                                    objective=objective, lloyd_iters=4)
    ex_t = distributed_kmeans_tree(KEY, sp, sm, k, t=60, tree=tree,
                                   objective=objective, lloyd_iters=4,
                                   engine="exec")
    np.testing.assert_array_equal(np.asarray(sim_t.centers),
                                  np.asarray(ex_t.centers))


def test_unknown_engine_raises(site_data):
    sp, sm, k = site_data
    g = _graph("ring")
    with pytest.raises(ValueError):
        graph_distributed_kmeans(KEY, sp, sm, k, t=30, graph=g,
                                 engine="warp")
    with pytest.raises(ValueError):
        distributed_kmeans_tree(KEY, sp, sm, k, t=30,
                                tree=topology.bfs_spanning_tree(g),
                                engine="warp")
