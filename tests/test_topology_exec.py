"""Topology execution engine tests (DESIGN.md Sec. 11).

The engine contract, asserted here for every topology generator:

* executed floods/tree routes deliver bit-identical payload copies to the
  nodes the protocol says should hold them;
* the *measured* CommLedger (counted transmission by transmission from the
  compiled schedule) equals the *analytic* ledger exactly;
* ``engine="exec"`` of Algorithm 2 is bit-identical to the host-simulation
  oracle on every node, for both objectives.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.comm import (flood_cost, tree_broadcast_cost,
                             tree_gather_cost, tree_up_cost)
from repro.core.distributed import (distributed_kmeans_tree,
                                    graph_distributed_kmeans)
from repro.core.message_passing import (GossipSchedule, TreeSchedule, flood,
                                        flood_exec, tree_broadcast_exec,
                                        tree_gather_exec, tree_scatter_exec,
                                        tree_up_sum_exec)
from repro.core.partition import pad_partition, partition_indices

KEY = jax.random.PRNGKey(0)

# every generator, all on 9 nodes so the end-to-end runs share jit caches
# (wan is the heterogeneous-link one: integer 1.0/16.0 costs)
TOPOLOGIES = {
    "ring": lambda: topology.ring(9),
    "star": lambda: topology.star(9),
    "grid": lambda: topology.grid(3, 3),
    "er": lambda: topology.erdos_renyi(9, 0.3, seed=3),
    "preferential": lambda: topology.preferential(9, 2, seed=0),
    "wan": lambda: topology.wan_clusters(3, 3, cross_links=2, seed=0),
}

LEDGER_UNITS = ("scalars", "points", "messages", "link_cost")


def _graph(name):
    return TOPOLOGIES[name]()


@pytest.fixture(scope="module")
def site_data():
    rng = np.random.default_rng(0)
    k, d, n_sites = 3, 5, 9
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.2 * rng.standard_normal((150, d)) for i in range(k)]
    ).astype(np.float32)
    idx = partition_indices(pts, n_sites, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    return jnp.asarray(sp), jnp.asarray(sm), k


# -- generators --------------------------------------------------------------

def test_ring_star_shapes():
    r = topology.ring(6)
    assert r.m == 6 and all(len(a) == 2 for a in r.adjacency())
    assert topology.diameter(r) == 3
    s = topology.star(6)
    assert s.m == 5 and topology.diameter(s) == 2
    assert len(s.adjacency()[0]) == 5
    with pytest.raises(ValueError):
        topology.ring(1)
    with pytest.raises(ValueError):
        topology.star(1)


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_new_generators_flood_connected(name):
    g = _graph(name)
    res = flood(g)
    assert all(r == set(range(g.n)) for r in res.received)


# -- flood_exec: delivery, quiescence, measured == analytic ------------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_flood_exec_delivers_and_meters_exactly(name):
    g = _graph(name)
    vals = jnp.asarray(
        np.random.default_rng(1).standard_normal((g.n, 3)).astype(np.float32))
    tables, res = flood_exec(g, vals, unit_scalars=1.0)
    # every node holds every origin's payload, bit-identical
    for v in range(g.n):
        np.testing.assert_array_equal(np.asarray(tables[v]),
                                      np.asarray(vals))
    # quiescence: knowledge complete within diameter rounds
    assert res.rounds_to_complete <= topology.diameter(g)
    assert res.rounds == topology.diameter(g) + 1
    # measured == analytic, exactly (link_cost included: every message
    # crosses every link, priced by the weighted degree sum)
    analytic = flood_cost(g, n_messages=g.n, unit_scalars=1.0)
    assert res.ledger.scalars == analytic.scalars
    assert res.ledger.messages == analytic.messages == 2 * g.m * g.n
    assert res.ledger.link_cost == analytic.link_cost
    if g.is_uniform_cost:
        assert res.ledger.link_cost == res.ledger.bytes
    else:
        assert res.ledger.link_cost > res.ledger.bytes
    assert sum(res.per_round_transmissions) == 2 * g.m * g.n
    # executed profile matches the host simulation round for round
    sim = flood(g)
    assert res.per_round_transmissions == sim.per_round_transmissions


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_flood_exec_per_origin_units(name):
    g = _graph(name)
    vals = jnp.zeros((g.n, 1))
    units = np.arange(g.n, dtype=np.float64)   # origin o ships o points
    _, res = flood_exec(g, vals, unit_points=units, dim=4)
    analytic = flood_cost(g, n_messages=1, unit_points=float(units.sum()),
                          dim=4)
    assert res.ledger.points == analytic.points == 2 * g.m * units.sum()
    assert res.ledger.dim == 4


def test_flood_exec_rejects_wrong_payload_length():
    g = topology.ring(5)
    with pytest.raises(ValueError):
        flood_exec(g, jnp.zeros((4, 1)))


def test_gossip_schedule_static_shapes():
    g = topology.star(7)
    sched = GossipSchedule.from_graph(g)
    assert sched.neighbors.shape == (7, 6)       # hub degree pads everyone
    assert sched.neighbor_mask.sum() == 2 * g.m
    assert sched.n_rounds == topology.diameter(g) + 1


# -- tree primitives ---------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_tree_gather_scatter_roundtrip_and_ledger(name):
    g = _graph(name)
    tree = topology.bfs_spanning_tree(g, root=0)
    sched = TreeSchedule.from_tree(tree)
    vals = jnp.asarray(
        np.random.default_rng(2).standard_normal((g.n, 2)).astype(np.float32))
    root_table, gres = tree_gather_exec(sched, vals, unit_scalars=1.0)
    np.testing.assert_array_equal(np.asarray(root_table), np.asarray(vals))
    analytic = tree_gather_cost(tree, unit_scalars_per_node=1.0)
    assert gres.ledger.scalars == analytic.scalars == sum(tree.depth)
    assert gres.ledger.messages == analytic.messages
    assert gres.ledger.link_cost == analytic.link_cost \
        == 4.0 * tree.path_costs().sum()

    own, sres = tree_scatter_exec(sched, vals, unit_scalars=1.0)
    np.testing.assert_array_equal(np.asarray(own), np.asarray(vals))
    assert sres.ledger.scalars == analytic.scalars  # path symmetry
    assert sres.ledger.link_cost == analytic.link_cost


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_tree_up_sum_and_broadcast(name):
    g = _graph(name)
    tree = topology.bfs_spanning_tree(g, root=0)
    sched = TreeSchedule.from_tree(tree)
    vals = jnp.asarray(
        np.random.default_rng(3).standard_normal((g.n, 2)).astype(np.float32))
    totals, ures = tree_up_sum_exec(sched, vals, broadcast=True,
                                    unit_scalars=1.0)
    expect = np.asarray(vals.sum(axis=0))
    for v in range(g.n):
        np.testing.assert_allclose(np.asarray(totals[v]), expect, rtol=1e-5)
    # up n-1 sends + broadcast n-1 sends, one scalar-unit each
    assert ures.ledger.scalars == 2.0 * (g.n - 1)
    assert ures.ledger.messages == 2.0 * (g.n - 1)

    payload = jnp.asarray(np.random.default_rng(4).standard_normal(
        (4, 2)).astype(np.float32))
    out, bres = tree_broadcast_exec(sched, payload, unit_points=4.0, dim=2)
    for v in range(g.n):
        np.testing.assert_array_equal(np.asarray(out[v]),
                                      np.asarray(payload))
    analytic = tree_broadcast_cost(tree, unit_points=4.0, dim=2)
    assert bres.ledger.points == analytic.points == 4.0 * (g.n - 1)
    assert bres.ledger.messages == analytic.messages == g.n - 1
    assert bres.ledger.link_cost == analytic.link_cost \
        == 4.0 * 3.0 * 4.0 * tree.edge_cost_total()


# -- Algorithm 2: engine == simulation, measured == analytic -----------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_graph_engine_matches_simulation(site_data, name):
    sp, sm, k = site_data
    g = _graph(name)
    t = 90
    sim = graph_distributed_kmeans(KEY, sp, sm, k, t=t, graph=g)
    ex = graph_distributed_kmeans(KEY, sp, sm, k, t=t, graph=g,
                                  engine="exec")
    # bit-identical centers and coreset
    np.testing.assert_array_equal(np.asarray(sim.centers),
                                  np.asarray(ex.centers))
    np.testing.assert_array_equal(np.asarray(sim.coreset.points),
                                  np.asarray(ex.coreset.points))
    np.testing.assert_array_equal(np.asarray(sim.coreset.weights),
                                  np.asarray(ex.coreset.weights))
    # measured ledger == analytic ledger, exactly (all axes incl. link_cost)
    for unit in LEDGER_UNITS:
        assert getattr(ex.ledger, unit) == getattr(sim.ledger, unit), unit
    # every node assembled the identical global instance and allocation
    det = ex.exec_detail
    npts, nw = np.asarray(det.node_points), np.asarray(det.node_weights)
    alloc = np.asarray(det.node_alloc)
    for v in range(g.n):
        np.testing.assert_array_equal(npts[v], npts[0])
        np.testing.assert_array_equal(nw[v], nw[0])
        np.testing.assert_array_equal(alloc[v], alloc[0])
    assert alloc[0].sum() == t


def test_graph_engine_every_node_solves_identically(site_data):
    """Acceptance: every node, solving its own received copy, produces the
    same centers the engine reports."""
    sp, sm, k = site_data
    g = _graph("er")
    from repro.core.coreset import Coreset
    from repro.core.distributed import _solve_on_coreset
    ex = graph_distributed_kmeans(KEY, sp, sm, k, t=90, graph=g,
                                  engine="exec")
    _, k2 = jax.random.split(KEY)
    det = ex.exec_detail
    for v in range(g.n):
        cs_v = Coreset(det.node_points[v], det.node_weights[v])
        centers_v = _solve_on_coreset(k2, cs_v, k, "kmeans", 8, None)
        np.testing.assert_array_equal(np.asarray(centers_v),
                                      np.asarray(ex.centers))


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_tree_engine_matches_simulation(site_data, name):
    sp, sm, k = site_data
    g = _graph(name)
    tree = topology.bfs_spanning_tree(g, root=0)
    t = 90
    sim = distributed_kmeans_tree(KEY, sp, sm, k, t=t, tree=tree)
    ex = distributed_kmeans_tree(KEY, sp, sm, k, t=t, tree=tree,
                                 engine="exec")
    np.testing.assert_array_equal(np.asarray(sim.centers),
                                  np.asarray(ex.centers))
    np.testing.assert_array_equal(np.asarray(sim.coreset.points),
                                  np.asarray(ex.coreset.points))
    np.testing.assert_array_equal(np.asarray(sim.coreset.weights),
                                  np.asarray(ex.coreset.weights))
    for unit in LEDGER_UNITS:
        assert getattr(ex.ledger, unit) == getattr(sim.ledger, unit), unit
    # the broadcast delivered the identical solution to every node
    nc = np.asarray(ex.exec_detail.node_centers)
    for v in range(g.n):
        np.testing.assert_array_equal(nc[v], np.asarray(ex.centers))
    assert np.asarray(ex.exec_detail.node_alloc).sum() == t


@pytest.mark.parametrize("objective", ["kmeans", "kmedian"])
def test_engine_both_objectives(site_data, objective):
    sp, sm, k = site_data
    g = _graph("grid")
    sim = graph_distributed_kmeans(KEY, sp, sm, k, t=60, graph=g,
                                   objective=objective, lloyd_iters=4)
    ex = graph_distributed_kmeans(KEY, sp, sm, k, t=60, graph=g,
                                  objective=objective, lloyd_iters=4,
                                  engine="exec")
    np.testing.assert_array_equal(np.asarray(sim.centers),
                                  np.asarray(ex.centers))
    tree = topology.bfs_spanning_tree(g, root=0)
    sim_t = distributed_kmeans_tree(KEY, sp, sm, k, t=60, tree=tree,
                                    objective=objective, lloyd_iters=4)
    ex_t = distributed_kmeans_tree(KEY, sp, sm, k, t=60, tree=tree,
                                   objective=objective, lloyd_iters=4,
                                   engine="exec")
    np.testing.assert_array_equal(np.asarray(sim_t.centers),
                                  np.asarray(ex_t.centers))


def test_unknown_engine_raises(site_data):
    sp, sm, k = site_data
    g = _graph("ring")
    with pytest.raises(ValueError):
        graph_distributed_kmeans(KEY, sp, sm, k, t=30, graph=g,
                                 engine="warp")
    with pytest.raises(ValueError):
        distributed_kmeans_tree(KEY, sp, sm, k, t=30,
                                tree=topology.bfs_spanning_tree(g),
                                engine="warp")


# -- heterogeneous links: weighted ledgers and min-cost routing ---------------

def test_tree_exec_weighted_ledgers_exact_on_noninteger_costs():
    """Tree gather/scatter/broadcast pricing is structurally identical to
    the analytic path-cost summation, so measured == analytic bit-for-bit
    even for arbitrary float costs (floods only guarantee that for
    integer-valued costs; DESIGN.md Sec. 12)."""
    g = topology.heterogeneous(topology.grid(3, 3),
                               lambda i, j: 0.3 + 0.7 / (1 + i + j))
    tree = topology.mst_spanning_tree(g)
    sched = TreeSchedule.from_tree(tree)
    vals = jnp.asarray(np.random.default_rng(7).standard_normal(
        (g.n, 2)).astype(np.float32))
    units = np.arange(1.0, g.n + 1.0)
    _, gres = tree_gather_exec(sched, vals, unit_points=units, dim=2)
    analytic = tree_gather_cost(tree, unit_points_per_node=units, dim=2)
    assert gres.ledger.link_cost == analytic.link_cost
    _, sres = tree_scatter_exec(sched, vals, unit_points=units, dim=2)
    assert sres.ledger.link_cost == analytic.link_cost
    _, bres = tree_broadcast_exec(sched, vals[0], unit_points=2.0, dim=2)
    assert bres.ledger.link_cost == \
        tree_broadcast_cost(tree, unit_points=2.0, dim=2).link_cost


def test_flood_exec_weighted_per_origin_units():
    g = topology.wan_clusters(3, 3, cross_links=2, seed=0)
    vals = jnp.zeros((g.n, 1))
    units = np.arange(g.n, dtype=np.float64)
    _, res = flood_exec(g, vals, unit_points=units, dim=4)
    w = float(g.weighted_degrees().sum())
    # every message crosses every link: per-origin weighted price w * unit
    assert res.ledger.link_cost == 4.0 * 5.0 * w * units.sum()


@pytest.mark.parametrize("engine", ["sim", "exec"])
def test_min_cost_routing_beats_bfs_on_wan(site_data, engine):
    """Acceptance: on wan_clusters, routing="min_cost" strictly lowers the
    cost-weighted bytes vs routing="bfs", with identical centers, and the
    measured exec ledger equals the analytic min-cost ledger exactly."""
    sp, sm, k = site_data
    g = topology.wan_clusters(3, 3, cross_cost=16.0, cross_links=2, seed=0)
    t = 90
    res = {r: graph_distributed_kmeans(KEY, sp, sm, k, t=t, graph=g,
                                       routing=r, engine=engine)
           for r in ("bfs", "min_cost")}
    assert res["min_cost"].ledger.link_cost < res["bfs"].ledger.link_cost
    np.testing.assert_array_equal(np.asarray(res["bfs"].centers),
                                  np.asarray(res["min_cost"].centers))
    if engine == "exec":
        for routing in ("bfs", "min_cost"):
            sim = graph_distributed_kmeans(KEY, sp, sm, k, t=t, graph=g,
                                           routing=routing)
            for unit in LEDGER_UNITS:
                assert getattr(res[routing].ledger, unit) == \
                    getattr(sim.ledger, unit), (routing, unit)
    # the min-cost tree holds exactly n_racks - 1 cross links; BFS enters
    # remote racks through every shallow cross link it finds
    mst = topology.mst_spanning_tree(g)
    bfs = topology.bfs_spanning_tree(g)
    assert mst.edge_cost_total() < bfs.edge_cost_total()


def test_routing_knob_uniform_costs_match_bfs_exactly(site_data):
    """On a uniform-cost graph min-cost routing is the BFS tree, so the
    two routings produce bit-identical ledgers (PR 4 compatibility)."""
    sp, sm, k = site_data
    g = _graph("er")
    a = graph_distributed_kmeans(KEY, sp, sm, k, t=90, graph=g,
                                 routing="bfs")
    b = graph_distributed_kmeans(KEY, sp, sm, k, t=90, graph=g,
                                 routing="min_cost")
    assert a.ledger.as_dict() == b.ledger.as_dict()
    assert a.ledger.link_cost == a.ledger.bytes
    np.testing.assert_array_equal(np.asarray(a.centers),
                                  np.asarray(b.centers))


def test_routing_matches_explicit_tree_protocol(site_data):
    """The routing knob is sugar for the tree protocol on a spanning tree
    of the graph: same centers, same ledger."""
    sp, sm, k = site_data
    g = _graph("wan")
    via_knob = graph_distributed_kmeans(KEY, sp, sm, k, t=90, graph=g,
                                        routing="min_cost")
    tree = topology.mst_spanning_tree(g)
    direct = distributed_kmeans_tree(KEY, sp, sm, k, t=90, tree=tree)
    assert via_knob.ledger.as_dict() == direct.ledger.as_dict()
    np.testing.assert_array_equal(np.asarray(via_knob.centers),
                                  np.asarray(direct.centers))


def test_unknown_routing_raises(site_data):
    sp, sm, k = site_data
    with pytest.raises(ValueError, match="unknown routing"):
        graph_distributed_kmeans(KEY, sp, sm, k, t=30,
                                 graph=_graph("ring"), routing="warp")


def test_ledger_phase_breakdown_carries_link_cost(site_data):
    """Phase dicts expose the link_cost axis: every phase of an exec tree
    run prices its own transmissions (round1 scalars cheap, round2 points
    dominant), and phases decompose the total exactly."""
    sp, sm, k = site_data
    g = _graph("wan")
    ex = graph_distributed_kmeans(KEY, sp, sm, k, t=90, graph=g,
                                  routing="min_cost", engine="exec")
    d = ex.ledger.as_dict(by_phase=True)
    assert set(d["phases"]) == {"round1", "round2_gather",
                                "round2_broadcast"}
    for sub in d["phases"].values():
        assert "link_cost" in sub
    assert sum(p["link_cost"] for p in d["phases"].values()) \
        == pytest.approx(d["link_cost"])
    assert sum(p["points"] for p in d["phases"].values()) == d["points"]


def test_flood_exec_directed_follows_link_directions():
    """On a directed graph the executed flood must move payloads along
    out-links (receive = in-neighbor gather), not the transpose graph: on
    this asymmetric strongly-connected digraph the transpose has a
    different per-round profile, so profile equality with the (correct)
    host simulation catches any direction flip."""
    g = topology.Graph(4, ((0, 1), (1, 2), (1, 3), (2, 0), (3, 2)),
                       directed=True)
    vals = jnp.asarray(np.random.default_rng(5).standard_normal(
        (g.n, 2)).astype(np.float32))
    tables, res = flood_exec(g, vals, unit_scalars=1.0)
    for v in range(g.n):
        np.testing.assert_array_equal(np.asarray(tables[v]),
                                      np.asarray(vals))
    sim = flood(g)
    assert res.per_round_transmissions == sim.per_round_transmissions
    analytic = flood_cost(g, n_messages=g.n, unit_scalars=1.0)
    # directed: each message crosses each one-way link once => m per message
    assert res.ledger.messages == analytic.messages == g.m * g.n
    assert res.ledger.scalars == analytic.scalars
    assert res.ledger.link_cost == analytic.link_cost
    assert res.rounds_to_complete <= topology.diameter(g)


def test_tree_schedule_from_graph_routing():
    """TreeSchedule.from_graph compiles the routed spanning tree directly:
    identical schedule state to from_tree(spanning_tree(...))."""
    g = topology.wan_clusters(2, 3, cross_links=2, seed=1)
    for routing in ("bfs", "min_cost"):
        direct = TreeSchedule.from_graph(g, root=0, routing=routing)
        via_tree = TreeSchedule.from_tree(
            topology.spanning_tree(g, root=0, routing=routing))
        np.testing.assert_array_equal(direct.parent, via_tree.parent)
        np.testing.assert_array_equal(direct.parent_cost,
                                      via_tree.parent_cost)
        np.testing.assert_array_equal(direct.levels, via_tree.levels)


def test_directed_ring_relay_regression():
    """One-way ring: the tightest orientation regression for
    GossipSchedule.from_graph(directed=True). Every node has exactly one
    out-slot and one in-edge; payloads travel n-1 hops *with* the arrows
    (the transpose schedule would be caught by the asymmetric-digraph
    test above; this one pins the degenerate max_deg == 1 layout)."""
    n = 6
    g = topology.Graph(n, tuple((i, (i + 1) % n) for i in range(n)),
                       directed=True)
    sched = GossipSchedule.from_graph(g)
    assert sched.neighbors.shape == (n, 1) and sched.n_rounds >= n - 1
    np.testing.assert_array_equal(np.asarray(sched.in_neighbors)[:, 0],
                                  np.arange(-1, n - 1) % n)
    vals = jnp.arange(n, dtype=jnp.float32)[:, None] * 3.0 + 1.0
    tables, res = flood_exec(g, vals, unit_scalars=1.0)
    for v in range(n):
        np.testing.assert_array_equal(np.asarray(tables[v]),
                                      np.asarray(vals))
    sim = flood(g)
    m = min(len(res.per_round_transmissions),
            len(sim.per_round_transmissions))
    assert res.per_round_transmissions[:m] == \
        sim.per_round_transmissions[:m]
    assert res.rounds_to_complete == topology.diameter(g) == n - 1


def test_schedule_factories_cache_by_graph_value():
    """gossip_schedule / tree_schedule are lru-cached on the (hashable)
    Graph value: structurally equal graphs share one compiled schedule,
    different routings do not."""
    from repro.core.message_passing import gossip_schedule, tree_schedule
    g1 = topology.wan_clusters(2, 3, cross_links=2, seed=1)
    g2 = topology.Graph(g1.n, g1.edges, edge_costs=g1.edge_costs,
                        directed=g1.directed)
    assert g1 == g2 and hash(g1) == hash(g2)
    assert gossip_schedule(g1) is gossip_schedule(g2)
    assert tree_schedule(g1, root=0) is tree_schedule(g2, root=0)
    assert tree_schedule(g1, root=0, routing="bfs") is not \
        tree_schedule(g1, root=0, routing="min_cost")
    d = topology.Graph(3, ((0, 1), (1, 2), (2, 0)), directed=True)
    assert gossip_schedule(d) is gossip_schedule(
        topology.Graph(3, ((0, 1), (1, 2), (2, 0)), directed=True))
