"""Loss paths: chunked CE == full CE; bf16-param mixed precision trains."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import forward, init_params, make_positions
from repro.train import TrainConfig, init_state, make_train_step
from repro.train.loss import chunked_lm_loss, lm_loss


def test_chunked_ce_equals_full_ce():
    cfg = dataclasses.replace(configs.get_reduced("llama3_8b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                                cfg.vocab_size)
    pos = make_positions(tokens, cfg)
    logits, _, aux = forward(params, tokens, pos, cfg)
    full, m_full = lm_loss(logits, labels, cfg, aux=aux)
    hidden, _, aux2 = forward(params, tokens, pos, cfg, head=False)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    chunked, m_chunk = chunked_lm_loss(head, hidden, labels, cfg, chunk=16,
                                       aux=aux2)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)
    np.testing.assert_allclose(float(m_full["ce"]), float(m_chunk["ce"]),
                               rtol=1e-5)
    # gradients agree too (the checkpointed scan must backprop correctly)
    g_full = jax.grad(lambda p: lm_loss(
        forward(p, tokens, pos, cfg)[0], labels, cfg)[0])(params)
    g_chunk = jax.grad(lambda p: chunked_lm_loss(
        p["embed"] if cfg.tie_embeddings else p["lm_head"],
        forward(p, tokens, pos, cfg, head=False)[0], labels, cfg,
        chunk=16)[0])(params)
    la = jax.tree.leaves(g_full)
    lb = jax.tree.leaves(g_chunk)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_bf16_params_training_decreases_loss():
    cfg = configs.get_reduced("llama3_8b")
    tc = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=30,
                     remat="none", bf16_params=True, loss_chunk=16)
    params, opt = init_state(jax.random.PRNGKey(0), cfg, tc)
    assert jax.tree.leaves(params)[0].dtype == jnp.bfloat16
    assert "master" in opt
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    from repro.data import BigramLM
    data = BigramLM(cfg.vocab_size)
    losses = []
    for s in range(30):
        b = data.batch(s, 4, 32)
        params, opt, m = step_fn(params, opt, b, jnp.asarray(s, jnp.int32))
        losses.append(float(m["ce"]))
    assert losses[-1] < losses[0] - 0.005, losses[::6]
    # params stay bf16, master stays f32
    assert jax.tree.leaves(params)[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(opt["master"])[0].dtype == jnp.float32
