"""End-to-end WAN runtime tests: Algorithm 1 and the streaming
aggregation round under faults (DESIGN.md Sec. 14).

The contract: a fault-free asynchronous round is bit-identical to the
synchronous execution engine; a faulty round is bit-identical to the
host sim oracle restricted to the surviving sites; the stream layer
carries the same guarantees round by round, including on adversarially
contaminated streams.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.distributed import graph_distributed_kmeans
from repro.core.partition import pad_partition, partition_indices
from repro.data.synthetic import contaminated_stream, drifting_mixture_stream
from repro.stream.ingest import DistributedStream
from repro.stream.tree import TreeConfig
from repro.wan.faults import FaultPlan
from repro.wan.quiesce import certify_quiescence

KEY = jax.random.PRNGKey(17)
UNITS = ("scalars", "points", "messages", "bytes", "link_cost")
CFG = TreeConfig(k=4, t=60, d=6, batch_size=200, levels=12)


@pytest.fixture(scope="module")
def site_data():
    rng = np.random.default_rng(2)
    k, d, n_sites = 3, 5, 12
    centers = 3.0 * rng.standard_normal((k, d))
    pts = np.concatenate(
        [centers[i] + 0.2 * rng.standard_normal((140, d)) for i in range(k)]
    ).astype(np.float32)
    idx = partition_indices(pts, n_sites, "weighted", seed=1)
    sp, sm = pad_partition(pts, idx)
    return jnp.asarray(sp), jnp.asarray(sm), k


@pytest.fixture(scope="module")
def wan_graph():
    return topology.wan_clusters(3, 4, cross_links=2, seed=0)


# -- graph_distributed_kmeans ------------------------------------------------

def test_async_fault_free_full_mode_is_bit_identical_to_exec(site_data,
                                                             wan_graph):
    sp, sm, k = site_data
    r_ex = graph_distributed_kmeans(KEY, sp, sm, k, 48, wan_graph,
                                    engine="exec")
    r_as = graph_distributed_kmeans(KEY, sp, sm, k, 48, wan_graph,
                                    engine="async", wan_mode="full")
    np.testing.assert_array_equal(np.asarray(r_ex.coreset.points),
                                  np.asarray(r_as.coreset.points))
    np.testing.assert_array_equal(np.asarray(r_ex.coreset.weights),
                                  np.asarray(r_as.coreset.weights))
    np.testing.assert_array_equal(np.asarray(r_ex.centers),
                                  np.asarray(r_as.centers))
    ed, ad = r_ex.ledger.as_dict(), r_as.ledger.as_dict()
    for u in UNITS:
        assert ed[u] == ad[u], u
    assert ad["staleness"] == 0.0


def test_async_clock_mode_same_result_with_staleness(site_data, wan_graph):
    """Per-edge clocks reorder deliveries but relay bit-exact copies: the
    round result cannot depend on the schedule, only the staleness can."""
    sp, sm, k = site_data
    r_ex = graph_distributed_kmeans(KEY, sp, sm, k, 48, wan_graph,
                                    engine="exec")
    r_ck = graph_distributed_kmeans(KEY, sp, sm, k, 48, wan_graph,
                                    engine="async", wan_mode="clock")
    np.testing.assert_array_equal(np.asarray(r_ex.centers),
                                  np.asarray(r_ck.centers))
    d = r_ck.ledger.as_dict()
    assert d["staleness"] > 0.0          # 16x-cost cross links lag
    assert d["link_cost"] == r_ex.ledger.as_dict()["link_cost"]


def test_faulty_exec_certified_against_restricted_oracle(site_data,
                                                         wan_graph):
    sp, sm, k = site_data
    plan = FaultPlan(drop=((0, 1),), churn=((5, 1, 3), (9, 0, -1)), seed=3)
    for mode in ("full", "clock"):
        cert = certify_quiescence(wan_graph, plan, mode=mode, seed=4,
                                  check_clustering=True, key=KEY,
                                  site_points=sp, site_mask=sm, k=k, t=48)
        assert cert.ok, (mode, cert)
        assert cert.centers_match is True


def test_faulty_round_coreset_spans_survivors_only(site_data, wan_graph):
    sp, sm, k = site_data
    plan = FaultPlan(churn=((9, 0, -1),), seed=1)
    surv = plan.surviving_nodes(wan_graph.n)
    res = graph_distributed_kmeans(KEY, sp, sm, k, 48, wan_graph,
                                   engine="exec", faults=plan)
    detail = res.exec_detail
    assert np.array_equal(detail.surviving, surv)
    # one portion of t_i + k rows per surviving site, none for the dead
    assert detail.node_points.shape[0] == surv.size
    assert res.ledger.as_dict()["staleness"] >= 0.0


def test_faults_require_flood_routing(site_data, wan_graph):
    sp, sm, k = site_data
    with pytest.raises(ValueError, match="flood"):
        graph_distributed_kmeans(KEY, sp, sm, k, 48, wan_graph,
                                 engine="exec", routing="tree",
                                 faults=FaultPlan(seed=0))


# -- DistributedStream rounds ------------------------------------------------

def _feed(ds, batches):
    for i, b in enumerate(batches):
        ds.push(i % ds.graph.n, b)


@pytest.mark.parametrize("mode", ["union", "resample"])
def test_stream_async_round_matches_exec(mode):
    g = topology.grid(2, 2)
    key = jax.random.PRNGKey(41)
    batches = list(drifting_mixture_stream(8, 200, d=6, k=4, seed=37))
    ds_ex = DistributedStream(g, CFG, key=key)
    ds_as = DistributedStream(g, CFG, key=key)
    _feed(ds_ex, batches)
    _feed(ds_as, batches)
    r_ex = ds_ex.aggregate(k=4, t=120, mode=mode, engine="exec")
    r_as = ds_as.aggregate(k=4, t=120, mode=mode, engine="async",
                           wan_mode="full", wan_seed=0)
    np.testing.assert_array_equal(np.asarray(r_ex.coreset.points),
                                  np.asarray(r_as.coreset.points))
    np.testing.assert_array_equal(np.asarray(r_ex.coreset.weights),
                                  np.asarray(r_as.coreset.weights))
    np.testing.assert_array_equal(np.asarray(r_ex.centers),
                                  np.asarray(r_as.centers))
    ed, ad = r_ex.ledger.as_dict(), r_as.ledger.as_dict()
    for u in UNITS:
        assert ed[u] == ad[u], (mode, u)


def test_stream_faulty_union_round_keeps_survivor_mass(wan_graph):
    """S3: an adversarially contaminated stream (outlier bursts between
    rounds) aggregated under churn -- the surviving union preserves
    exactly the surviving sites' summary mass."""
    ds = DistributedStream(wan_graph, CFG, key=jax.random.PRNGKey(5))
    batches = contaminated_stream(12, 200, d=6, k=4, outlier_frac=0.05,
                                  burst_every=4, seed=5)
    _feed(ds, list(batches))
    plan = FaultPlan(drop=((0, 1),), churn=((5, 1, 3), (9, 0, -1)), seed=3)
    surv = plan.surviving_nodes(wan_graph.n)
    res = ds.aggregate(k=4, t=5000, mode="union", engine="async",
                       faults=plan)
    survivor_mass = sum(
        float(np.asarray(ds.sites[int(s)].summary().weights).sum())
        for s in surv)
    np.testing.assert_allclose(float(jnp.sum(res.coreset.weights)),
                               survivor_mass, rtol=1e-5)
    d = res.ledger.as_dict()
    assert d["staleness"] >= 0.0
    assert res.centers.shape == (4, CFG.d)


def test_stream_faulty_resample_round_runs_restricted(wan_graph):
    ds = DistributedStream(wan_graph, CFG, key=jax.random.PRNGKey(7))
    _feed(ds, list(contaminated_stream(12, 200, d=6, k=4, seed=9)))
    plan = FaultPlan(churn=((9, 0, -1),), seed=2)
    res = ds.aggregate(k=4, t=120, mode="resample", engine="exec",
                       faults=plan)
    # the coreset is the survivors' portions: (sum t_i + n'k) rows
    assert np.isfinite(np.asarray(res.coreset.points)).all()
    assert res.centers.shape == (4, CFG.d)
    d = ds.ledger.as_dict(by_phase=True)
    assert "stream_round_0" in d["phases"]


def test_stream_wan_validation():
    ds = DistributedStream(topology.grid(2, 2), CFG)
    ds.push(0, next(iter(drifting_mixture_stream(1, 200, d=6, seed=1))))
    with pytest.raises(ValueError, match="engine"):
        ds.aggregate(k=4, t=60, engine="sim", faults=FaultPlan(seed=0))
    with pytest.raises(ValueError, match="flood"):
        ds.aggregate(k=4, t=60, engine="async", transport="tree")


# -- contaminated_stream itself (S3) -----------------------------------------

def test_contaminated_stream_shares_inliers_with_base():
    clean = list(drifting_mixture_stream(4, 100, d=5, seed=3))
    dirty = list(contaminated_stream(4, 100, d=5, outlier_frac=0.1, seed=3))
    assert len(dirty) == 4
    for c, t in zip(clean, dirty):
        assert t.shape == c.shape and t.dtype == np.float32
        changed = np.any(c != t, axis=1)
        assert changed.sum() == 10               # exactly the outlier count
        np.testing.assert_array_equal(c[~changed], t[~changed])
        # outliers live far outside the mixture's 3-sigma shell
        assert np.linalg.norm(t[changed], axis=1).min() > 20.0


def test_contaminated_stream_burst_batches_are_fully_adversarial():
    dirty = list(contaminated_stream(4, 50, d=5, outlier_frac=0.0,
                                     burst_every=2, seed=3))
    radii = [np.linalg.norm(b, axis=1) for b in dirty]
    assert radii[1].min() > 20.0 and radii[3].min() > 20.0   # bursts
    assert radii[0].max() < 20.0 and radii[2].max() < 20.0   # clean
    with pytest.raises(ValueError, match="outlier_frac"):
        list(contaminated_stream(1, 10, outlier_frac=1.5))
