"""Asynchronous WAN runtime tests (DESIGN.md Sec. 14).

The runtime contract, asserted here:

* a trivial fault plan in mode ``"full"`` reproduces the synchronous
  execution engine transmission for transmission -- same tables, same
  per-round profile, same measured ledger;
* under drops / churn / duplication the tracked flood completes within
  the proved bound (horizon + period * surviving diameter), quiesces,
  and duplicate deliveries never change a relay table;
* per-edge-clock mode prices heterogeneous links into the new
  ``staleness`` ledger axis; randomized gossip is seed-deterministic and
  its budget doubling is prefix-stable;
* :func:`repro.wan.quiesce.certify_quiescence` signs off on every
  (topology, plan) pair tested, including generated plans.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.core.message_passing import flood, flood_exec
from repro.wan.faults import FaultPlan, random_fault_plan
from repro.wan.quiesce import certify_quiescence
from repro.wan.runtime import wan_flood_exec
from repro.wan.schedules import wan_schedule

UNITS = ("scalars", "points", "messages", "bytes", "link_cost")


def _payload(n, f=3):
    return (jnp.arange(n, dtype=jnp.float32)[:, None] * 10.0
            + jnp.arange(f, dtype=jnp.float32)[None, :])


# -- fault-free equivalence with the synchronous engine ----------------------

def test_trivial_plan_full_mode_matches_sync_engine():
    g = topology.grid(3, 3)
    pay = _payload(g.n)
    sync_tables, sync_res = flood_exec(g, pay, unit_scalars=1.0)
    wan_tables, wan_res = wan_flood_exec(g, pay, mode="full",
                                         unit_scalars=1.0)
    np.testing.assert_array_equal(np.asarray(sync_tables),
                                  np.asarray(wan_tables))
    # transmission-for-transmission: same profile modulo trailing zeros
    ns, nw = sync_res.per_round_transmissions, wan_res.per_round_transmissions
    m = min(len(ns), len(nw))
    assert ns[:m] == nw[:m]
    assert all(x == 0 for x in ns[m:] + nw[m:])
    sd, wd = sync_res.ledger.as_dict(), wan_res.ledger.as_dict()
    for u in UNITS:
        assert sd[u] == wd[u], u
    assert wd["staleness"] == 0.0
    assert wan_res.rounds_to_complete == topology.diameter(g)


def test_fault_free_quiesces_one_round_after_completion():
    g = topology.ring(8)
    _, res = wan_flood_exec(g, _payload(g.n), mode="full")
    assert res.rounds_to_complete == topology.diameter(g)
    # quiescence == the last obligations flushed; trailing rounds silent
    assert res.rounds_to_quiesce <= res.rounds_to_complete + 1
    assert all(t == 0 for t in
               res.per_round_transmissions[res.rounds_to_quiesce:])


# -- faults: completion, quiescence, idempotence -----------------------------

@pytest.fixture(scope="module")
def faulty_case():
    g = topology.wan_clusters(3, 4, cross_links=2, seed=0)
    plan = FaultPlan(drop=((0, 1),), churn=((5, 1, 3), (9, 0, -1)), seed=3)
    return g, plan


def test_faulty_flood_completes_within_bound(faulty_case):
    g, plan = faulty_case
    sub, _ = plan.surviving_graph(g)
    surv = plan.surviving_nodes(g.n)
    tables, res = wan_flood_exec(g, _payload(g.n), mode="full", faults=plan)
    assert res.rounds_to_complete <= plan.horizon() + topology.diameter(sub)
    assert res.rounds_to_quiesce <= res.rounds
    # every survivor holds every surviving origin, bit-exact
    t = np.asarray(tables)
    pay = np.asarray(_payload(g.n))
    for v in surv:
        np.testing.assert_array_equal(t[v][surv], pay[surv])
    # the dead node is excluded from tracking: nothing owes it delivery
    assert 9 not in surv


def test_duplicates_change_traffic_not_tables(faulty_case):
    g, plan = faulty_case
    surv = plan.surviving_nodes(g.n)
    base, bres = wan_flood_exec(g, _payload(g.n), mode="full", faults=plan)
    dup = dataclasses.replace(plan, dup_rate=0.4)
    dtab, dres = wan_flood_exec(g, _payload(g.n), mode="full", faults=dup)
    assert dres.ledger.messages > bres.ledger.messages
    np.testing.assert_array_equal(np.asarray(base)[surv][:, surv],
                                  np.asarray(dtab)[surv][:, surv])


def test_disconnecting_plan_raises():
    g = topology.star(5)          # hub 0 is a cut vertex
    plan = FaultPlan(churn=((0, 0, -1),))
    with pytest.raises(ValueError, match="disconnect"):
        wan_flood_exec(g, _payload(g.n), faults=plan)


def test_unknown_dropped_edge_raises():
    g = topology.ring(5)
    with pytest.raises(ValueError, match="not an edge"):
        wan_flood_exec(g, _payload(g.n), faults=FaultPlan(drop=((0, 2),)))


# -- per-edge clocks and staleness -------------------------------------------

def test_clock_mode_prices_slow_links_as_staleness():
    g = topology.wan_clusters(3, 3, cross_links=2, seed=0)
    ws = wan_schedule(g)
    assert ws.max_period > 1          # heterogeneous 1.0 / 16.0 costs
    _, res = wan_flood_exec(g, _payload(g.n), mode="clock")
    assert res.ledger.staleness > 0.0
    assert res.rounds_to_complete <= ws.max_period * topology.diameter(g)
    surv = np.arange(g.n)
    assert res.ledger.staleness == pytest.approx(
        float(res.staleness[surv].mean()))
    # uniform costs degenerate to the synchronous flood: no staleness
    _, uni = wan_flood_exec(topology.grid(3, 3), _payload(9), mode="clock")
    assert uni.ledger.staleness == 0.0


def test_ledger_round_phases_sum_to_totals():
    g = topology.wan_clusters(3, 3, cross_links=2, seed=0)
    _, res = wan_flood_exec(g, _payload(g.n), mode="clock",
                            unit_scalars=1.0)
    d = res.ledger.as_dict(by_phase=True)
    assert all(name.startswith("wan_round_") for name in d["phases"])
    for u in ("scalars", "messages", "link_cost"):
        assert d[u] == pytest.approx(
            sum(p[u] for p in d["phases"].values()))
    assert "staleness" in d


# -- randomized gossip -------------------------------------------------------

def test_random_mode_is_seed_deterministic():
    g = topology.grid(3, 3)
    t1, r1 = wan_flood_exec(g, _payload(g.n), mode="random", seed=7, p=0.4)
    t2, r2 = wan_flood_exec(g, _payload(g.n), mode="random", seed=7, p=0.4)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert r1.per_round_transmissions == r2.per_round_transmissions
    assert r1.rounds_to_quiesce == r2.rounds_to_quiesce
    # tables are relays of the originals regardless of the edge draws
    np.testing.assert_array_equal(
        np.asarray(t1), np.broadcast_to(np.asarray(_payload(g.n))[None],
                                        np.asarray(t1).shape))


def test_random_mode_budget_doubling_is_prefix_stable():
    """A sparse activation forces at least one doubling; the masks are
    seeded per absolute round, so the doubled run must agree with a run
    granted the final budget up front."""
    g = topology.ring(6)
    _, res = wan_flood_exec(g, _payload(g.n), mode="random", seed=1, p=0.05)
    _, direct = wan_flood_exec(g, _payload(g.n), mode="random", seed=1,
                               p=0.05, max_rounds=res.rounds)
    assert res.per_round_transmissions == direct.per_round_transmissions
    assert res.rounds_to_complete == direct.rounds_to_complete


# -- certification -----------------------------------------------------------

@pytest.mark.parametrize("mode", ["full", "clock", "random"])
def test_certify_quiescence_modes(faulty_case, mode):
    g, plan = faulty_case
    cert = certify_quiescence(g, plan, mode=mode, seed=2)
    assert cert.ok, cert
    assert cert.quiesced and cert.duplicates_idempotent
    if mode != "random":
        assert cert.bound is not None
        assert cert.rounds_to_complete <= cert.bound


@pytest.mark.parametrize("topo", ["ring", "grid", "wan"])
def test_certify_generated_plans(topo):
    g = {"ring": lambda: topology.ring(9),
         "grid": lambda: topology.grid(3, 3),
         "wan": lambda: topology.wan_clusters(3, 3, cross_links=2, seed=0),
         }[topo]()
    plan = random_fault_plan(g, seed=11, drop_frac=0.15, n_churn=2,
                             dead_frac=0.15, dup_rate=0.2)
    cert = certify_quiescence(g, plan, mode="full", seed=5)
    assert cert.ok, (topo, cert)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), plan_seed=st.integers(0, 10_000))
def test_property_connected_survivors_quiesce_within_bound(seed, plan_seed):
    """S4 property: any connected graph plus any fault plan whose
    survivors stay connected floods to completion within horizon +
    surviving diameter, and quiesces."""
    g = topology.erdos_renyi(8, 0.35, seed=seed % 97)
    plan = random_fault_plan(g, seed=plan_seed, drop_frac=0.2, n_churn=2,
                             churn_window=(1, 4), dead_frac=0.2)
    sub, _ = plan.surviving_graph(g)
    _, res = wan_flood_exec(g, _payload(g.n), mode="full", faults=plan,
                            seed=seed)
    assert res.rounds_to_complete <= plan.horizon() + topology.diameter(sub)
    assert res.rounds_to_quiesce <= res.rounds
